"""Kernel-layer honesty benchmark -> BENCH_kernels.json.

The kernel layer's standing risk is *silent* untruth: interpret-mode
parity quietly standing in for hardware numbers, or the fused TD kernel
regressing the default trainer it is supposed to leave untouched.  This
module makes each claim explicit and machine-checkable:

1. **Interpret parity** (always, gating): every Pallas kernel in the
   repo — the three conv dataflows, flash attention, the SSD scan, and
   both fused TD-update variants — runs in interpret mode against its
   oracle at a fixed tolerance.
2. **TD trajectory pin** (always, gating): 64 consecutive fused updates
   track ``dqn_td_update`` to <= 1e-5 on loss and every parameter.
3. **CPU trainer no-regression** (always, gating): the default
   (``td_kernel=False``) training episode must contain NO pallas_call in
   its jaxpr and must produce a jaxpr identical to the pre-seam trainer
   (structural no-regression — stronger than a timing, immune to machine
   noise); a timing of both paths is recorded for the humans.
4. **Compiled microbenchmark** (TPU/GPU + ``REPRO_KERNEL_COMPILED=1``
   only): the same kernels timed non-interpret vs their XLA oracles.
   On hosts without an accelerator this leg records an explicit
   ``skipped`` reason — it never silently greens.
5. **Interpret-mode trainer throughput** (report only): the honest
   number for what ``td_kernel=True`` costs on a CPU host, where the
   kernel body runs as unfused interpreted ops.

Host tuning env is stamped into the JSON (benchmarks.common).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

PARITY_TOL = 1e-4   # conv/attention/ssd f32 (existing test-suite tol)
TD_TOL = 1e-5       # the ISSUE-9 acceptance pin


# ---------------------------------------------------------------------------
# leg 1: interpret parity across every kernel
# ---------------------------------------------------------------------------

def _interpret_parity(interpret: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.flexai.dqn import (_adam_init, dqn_td_grads,
                                       dqn_td_update, init_qnet)
    from repro.kernels.conv_dataflow import conv2d, conv2d_ref
    from repro.kernels.dqn_update import (dqn_td_grads_fused,
                                          dqn_td_update_fused)
    from repro.kernels.flash_attention import attention_ref, flash_attention
    from repro.kernels.ssd_scan import ssd_ref, ssd_scan

    key = jax.random.PRNGKey(0)
    out = {}

    def record(name, err, tol):
        out[name] = {"max_err": float(err), "tol": tol,
                     "ok": bool(err <= tol)}

    # conv dataflows (incl. a prime-ho / prime-cin shape so the padded
    # tile paths are what gets gated, not just the divisible fast path)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (2, 15, 10, 11), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 11, 8), jnp.float32) * 0.2
    ref = conv2d_ref(x, w)
    for df in ("SconvOD", "SconvIC", "MconvMC"):
        o = conv2d(x, w, dataflow=df, interpret=interpret)
        record(f"conv/{df}", jnp.max(jnp.abs(o - ref)), PARITY_TOL)

    # flash attention
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 64, 4, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 4, 32), jnp.float32)
    o = flash_attention(q, kk, v, causal=True, block_q=32, block_k=32,
                        interpret=interpret)
    import math
    qf = q.transpose(0, 2, 1, 3).reshape(4, 64, 32)
    kf = kk.transpose(0, 2, 1, 3).reshape(4, 64, 32)
    vf = v.transpose(0, 2, 1, 3).reshape(4, 64, 32)
    aref = attention_ref(qf, kf, vf, causal=True,
                         scale=1 / math.sqrt(32))
    aref = aref.reshape(1, 4, 64, 32).transpose(0, 2, 1, 3)
    record("flash_attention", jnp.max(jnp.abs(o - aref)), PARITY_TOL)

    # ssd scan
    ks = jax.random.split(key, 4)
    u = jax.random.normal(ks[0], (1, 32, 2, 8), jnp.float32) * 0.3
    a = -jnp.abs(jax.random.normal(ks[1], (1, 32, 2))) * 0.2
    Bm = jax.random.normal(ks[2], (1, 32, 4), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[3], (1, 32, 4), jnp.float32) * 0.5
    y, _ = ssd_scan(u, a, Bm, Cm, chunk=8, interpret=interpret)
    uf = u.transpose(0, 2, 1, 3).reshape(2, 32, 8)
    af = a.transpose(0, 2, 1).reshape(2, 32)
    Bf = jnp.repeat(Bm[:, None], 2, 1).reshape(2, 32, 4)
    Cf = jnp.repeat(Cm[:, None], 2, 1).reshape(2, 32, 4)
    yr, _ = ssd_ref(uf, af, Bf, Cf)
    yr = yr.reshape(1, 2, 32, 8).transpose(0, 2, 1, 3)
    record("ssd_scan", jnp.max(jnp.abs(y - yr)), PARITY_TOL)

    # fused TD update, both variants (B=40, tile=16 -> masked tail block)
    D, A = 18, 3
    ep = init_qnet(key, D, A)
    tp = init_qnet(jax.random.fold_in(key, 9), D, A)
    ks = jax.random.split(key, 5)
    batch = {"s": jax.random.normal(ks[0], (40, D)),
             "a": jax.random.randint(ks[1], (40,), 0, A),
             "r": jax.random.normal(ks[2], (40,)) * 3,
             "s_next": jax.random.normal(ks[3], (40, D)),
             "done": (jax.random.uniform(ks[4], (40,)) < 0.2)
             .astype(jnp.float32)}
    l0, g0 = dqn_td_grads(ep, tp, batch)
    l1, g1 = dqn_td_grads_fused(ep, tp, batch, batch_tile=16,
                                interpret=interpret)
    err = max(abs(float(l0) - float(l1)),
              max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(g0, g1)))
    record("dqn_td_grads", err, TD_TOL)
    opt = _adam_init(ep)
    p0, o0, ul0 = dqn_td_update(ep, tp, opt, batch)
    p1, o1, ul1 = dqn_td_update_fused(ep, tp, opt, batch, batch_tile=16,
                                      interpret=interpret)
    err = max(abs(float(ul0) - float(ul1)),
              max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(p0, p1)),
              max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(o0.mu, o1.mu)))
    record("dqn_td_update", err, TD_TOL)
    out["all_ok"] = all(v["ok"] for k, v in out.items() if k != "all_ok")
    return out


# ---------------------------------------------------------------------------
# leg 2: TD trajectory pin (the ISSUE-9 acceptance criterion)
# ---------------------------------------------------------------------------

def _td_trajectory(updates: int, interpret: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.flexai.dqn import _adam_init, dqn_td_update, init_qnet
    from repro.kernels.dqn_update import dqn_td_update_fused

    key = jax.random.PRNGKey(77)
    D, A, B = 18, 3, 32
    ep = init_qnet(key, D, A)
    p_ref = p_ker = ep
    t_ref = t_ker = ep
    o_ref, o_ker = _adam_init(ep), _adam_init(ep)
    upd_ref = jax.jit(dqn_td_update)
    upd_ker = jax.jit(lambda e, t, o, b: dqn_td_update_fused(
        e, t, o, b, interpret=interpret))
    max_l = max_p = 0.0
    for i in range(updates):
        ks = jax.random.split(jax.random.fold_in(key, i), 5)
        batch = {"s": jax.random.normal(ks[0], (B, D)),
                 "a": jax.random.randint(ks[1], (B,), 0, A),
                 "r": jax.random.normal(ks[2], (B,)) * 2,
                 "s_next": jax.random.normal(ks[3], (B, D)),
                 "done": (jax.random.uniform(ks[4], (B,)) < 0.1)
                 .astype(jnp.float32)}
        p_ref, o_ref, l_ref = upd_ref(p_ref, t_ref, o_ref, batch)
        p_ker, o_ker, l_ker = upd_ker(p_ker, t_ker, o_ker, batch)
        if (i + 1) % 20 == 0:
            t_ref, t_ker = p_ref, p_ker
        max_l = max(max_l, abs(float(l_ref) - float(l_ker)))
        max_p = max(max_p, max(
            float(jnp.max(jnp.abs(a - b))) for a, b in zip(p_ref, p_ker)))
    return {"updates": updates, "max_loss_diff": max_l,
            "max_param_diff": max_p, "tol": TD_TOL,
            "ok": bool(max_l <= TD_TOL and max_p <= TD_TOL)}


# ---------------------------------------------------------------------------
# leg 3: default-path no-regression + report-only trainer timings
# ---------------------------------------------------------------------------

def _trainer_no_regression(tasks: int) -> dict:
    import jax

    from benchmarks.common import platform, timer
    from repro.core.flexai import FlexAIConfig
    from repro.core.flexai.engine import make_train_fn, train_init
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import tasks_to_arrays
    from benchmarks.training_throughput import _routes

    plat = platform()
    spec = spec_from_platform(plat)
    cfg = FlexAIConfig(lr=1e-3, gamma=0.98, batch_size=32, min_replay=64,
                       update_every=2, eps_decay_steps=2000,
                       target_sync_every=200, replay_capacity=4096, seed=7)
    state_dim = 3 + 5 * plat.n
    ta = tasks_to_arrays(_routes(1, tasks)[0])
    ts0 = train_init(jax.random.PRNGKey(cfg.seed), state_dim, plat.n,
                     cfg.replay_capacity)

    # structural no-regression: the default trace is pallas-free and the
    # explicit off-switch trace is IDENTICAL to it, so td_kernel=False
    # cannot cost anything by construction.  jvp_jaxpr_thunk params print
    # as `<function ... at 0x...>` — normalize the addresses, they are
    # per-trace closure identities, not structure.
    import re

    def trace(**kw):
        s = str(jax.make_jaxpr(make_train_fn(spec, cfg, **kw))(ts0, ta))
        return re.sub(r"0x[0-9a-f]+", "0x0", s)

    jaxpr_default = trace()
    jaxpr_off = trace(td_kernel=False)
    jaxpr_on = trace(td_kernel=True)
    pallas_free = "pallas_call" not in jaxpr_default
    off_identical = jaxpr_off == jaxpr_default
    on_has_kernel = "pallas_call" in jaxpr_on

    # timings (reported for humans; the gate is the structural check)
    fn_off = make_train_fn(spec, cfg)
    fn_on = make_train_fn(spec, cfg, td_kernel=True)
    _, t_off = timer(
        lambda: jax.block_until_ready(fn_off(ts0, ta)[0].eval_p), iters=3)
    _, t_on = timer(
        lambda: jax.block_until_ready(fn_on(ts0, ta)[0].eval_p), iters=3)
    return {
        "tasks": tasks,
        "default_pallas_free": bool(pallas_free),
        "off_jaxpr_identical_to_default": bool(off_identical),
        "on_jaxpr_has_pallas_call": bool(on_has_kernel),
        "off_env_steps_per_s": round(tasks / t_off, 1),
        "on_env_steps_per_s": round(tasks / t_on, 1),
        "on_vs_off_ratio": round(t_off / t_on, 3),
        "ok": bool(pallas_free and off_identical and on_has_kernel),
        "note": "the on-path number is interpret-mode Pallas executing "
                "the kernel body as plain XLA ops on CPU — it says "
                "nothing about hardware kernel speed in either "
                "direction; the compiled ratio is only measured on "
                "accelerator hardware (see the compiled leg / its skip "
                "reason), so this ratio is reported, never gated",
    }


# ---------------------------------------------------------------------------
# leg 4: compiled microbenchmark (hardware only — explicit skip otherwise)
# ---------------------------------------------------------------------------

def _compiled_leg(quick: bool) -> dict:
    from repro.kernels.protocol import (accelerator_platform,
                                        compiled_available,
                                        compiled_requested, status)
    if not compiled_available():
        if accelerator_platform() is None:
            reason = ("no TPU/GPU accelerator on this host — compiled "
                      "Mosaic/Triton execution is impossible; interpret "
                      "parity above is the only claim made")
        elif not compiled_requested():
            reason = ("accelerator present but REPRO_KERNEL_COMPILED=1 "
                      "not set — compiled run not requested")
        else:
            reason = "REPRO_KERNEL_COMPILED=0 forced interpret mode"
        return {"skipped": True, "reason": reason, "protocol": status()}

    # hardware run: parity AND timing, non-interpret
    import jax

    from benchmarks.common import timer
    import jax.numpy as jnp
    from repro.core.flexai.dqn import _adam_init, dqn_td_update, init_qnet
    from repro.kernels.dqn_update import dqn_td_update_fused

    parity = _interpret_parity(interpret=False)
    key = jax.random.PRNGKey(5)
    D, A, B = 18, 3, 128
    ep = init_qnet(key, D, A)
    tp = init_qnet(jax.random.fold_in(key, 1), D, A)
    opt = _adam_init(ep)
    ks = jax.random.split(key, 5)
    batch = {"s": jax.random.normal(ks[0], (B, D)),
             "a": jax.random.randint(ks[1], (B,), 0, A),
             "r": jax.random.normal(ks[2], (B,)),
             "s_next": jax.random.normal(ks[3], (B, D)),
             "done": jnp.zeros((B,))}
    oracle = jax.jit(dqn_td_update)
    fused = jax.jit(lambda e, t, o, b: dqn_td_update_fused(
        e, t, o, b, interpret=False))
    iters = 10 if quick else 50
    _, t_o = timer(lambda: jax.block_until_ready(
        oracle(ep, tp, opt, batch)[0].w1), warmup=2, iters=iters)
    _, t_f = timer(lambda: jax.block_until_ready(
        fused(ep, tp, opt, batch)[0].w1), warmup=2, iters=iters)
    return {"skipped": False, "protocol": status(), "parity": parity,
            "td_update_us": {"oracle_xla": round(t_o * 1e6, 2),
                             "fused_kernel": round(t_f * 1e6, 2),
                             "speedup": round(t_o / t_f, 2)}}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = True) -> list:
    from benchmarks.common import host_tuning, row, save
    from repro.kernels.protocol import status

    t0 = time.time()
    parity = _interpret_parity(interpret=True)
    trajectory = _td_trajectory(64, interpret=True)
    trainer = _trainer_no_regression(tasks=256 if quick else 384)
    compiled = _compiled_leg(quick)

    gate_ok = bool(parity["all_ok"] and trajectory["ok"] and trainer["ok"]
                   and (compiled.get("skipped")
                        or compiled["parity"]["all_ok"]))
    summary = {
        "protocol": status(),
        "interpret_parity": parity,
        "td_trajectory": trajectory,
        "cpu_trainer": trainer,
        "compiled": compiled,
        "gate": {
            "ok": gate_ok,
            "parity_ok": parity["all_ok"],
            "trajectory_ok": trajectory["ok"],
            "trainer_no_regression_ok": trainer["ok"],
            "compiled_leg": ("skipped: " + compiled["reason"])
            if compiled.get("skipped") else "ran",
        },
        "host_tuning": host_tuning(),
        "wall_s": round(time.time() - t0, 1),
    }
    with open(os.path.join(os.getcwd(), "BENCH_kernels.json"), "w") as f:
        json.dump(summary, f, indent=1)

    rows = [
        row("kernels/interpret_parity_ok", 0.0, parity["all_ok"]),
        row("kernels/td_trajectory_max_param_diff", 0.0,
            f"{trajectory['max_param_diff']:.2e}"),
        row("kernels/default_path_pallas_free", 0.0,
            trainer["default_pallas_free"]),
        row("kernels/td_kernel_on_vs_off_ratio_interpret", 0.0,
            f"{trainer['on_vs_off_ratio']}x"),
        row("kernels/compiled_leg", 0.0,
            "ran" if not compiled.get("skipped") else "skipped"),
        row("kernels/gate_ok", 0.0, gate_ok),
    ]
    save("kernels", rows)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    for r in run(quick=not args.full):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
