"""Table 8: per-accelerator FPS for YOLO/SSD/GOTURN.

The published FPS are the calibrated constants of the HMAI analytic model;
this benchmark (a) reports them, (b) cross-checks that the *relative*
ordering of the three Pallas conv-dataflow kernels on a representative conv
workload is consistent with the archetypes' affinities (MconvMC/MXU best on
channel-heavy convs; SconvOD competitive on wide spatial maps), using
wall-clock on the XLA-compiled kernels' reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, timer

PAPER_TABLE8 = {
    "SconvOD": {"yolo": 170.37, "ssd": 74.99, "goturn": 352.69},
    "SconvIC": {"yolo": 132.54, "ssd": 82.94, "goturn": 350.34},
    "MconvMC": {"yolo": 149.32, "ssd": 82.57, "goturn": 500.54},
}


def run(quick: bool = True) -> list:
    from repro.core.hmai import ACCELERATOR_SPECS
    rows = []
    for name, spec in ACCELERATOR_SPECS.items():
        for kind, fps in spec.fps.items():
            rows.append(row(
                f"table8/{name}/{kind}_fps", 1e6 / fps, fps,
                paper=PAPER_TABLE8[name][kind]))

    # best-accelerator mapping sanity (drives the heterogeneity argument)
    best = {kind: max(ACCELERATOR_SPECS, key=lambda n:
                      ACCELERATOR_SPECS[n].fps[kind])
            for kind in ("yolo", "ssd", "goturn")}
    rows.append(row("table8/best_accel_map", 0.0, str(best)))

    # kernel-level cross-check (tiny shapes, interpret mode -> relative only)
    if not quick:
        from repro.kernels.conv_dataflow import conv2d
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 16, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 32)) * 0.1
        for df in ("SconvOD", "SconvIC", "MconvMC"):
            out, dt = timer(lambda d=df: jax.block_until_ready(
                conv2d(x, w, dataflow=d, interpret=True)), iters=2)
            rows.append(row(f"table8/kernel_{df}_interpret", dt * 1e6,
                            "interpret-mode (relative only)"))
    save("table8_accelerator_perf", rows)
    return rows
