"""Figure 11: FlexAI RL-agent training-loss curve (urban area).

Reproduces the qualitative claim: loss stabilizes after the first episodes
because queue composition is similar across episodes — the trained agent
transfers.  The loss history comes from the device-resident fused trainer
(``ScanFlexAI`` via ``common.trained_flexai``): when a checkpoint is
loaded instead of retrained, the curve is read from the loss-history
sidecar written next to it."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, save, trained_flexai


def run(quick: bool = True) -> list:
    agent = trained_flexai("UB", quick=quick)
    losses = np.asarray(agent.losses, dtype=np.float64)
    rows = []
    if len(losses) < 10:
        rows.append(row("fig11/no_loss_history", 0.0,
                        "checkpoint loaded without loss sidecar"))
    else:
        k = len(losses) // 5
        for i in range(5):
            seg = losses[i * k:(i + 1) * k]
            rows.append(row(f"fig11/loss_phase{i}", 0.0,
                            round(float(np.mean(seg)), 4)))
        early = float(np.mean(losses[: 2 * k]))
        late = float(np.mean(losses[-k:]))
        rows.append(row("fig11/loss_stabilizes", 0.0, bool(late <= early * 3),
                        early=round(early, 4), late=round(late, 4)))
    save("fig11_training_loss", rows)
    return rows
