"""Kernel microbenchmarks: Pallas kernels vs jnp oracles.

On this CPU container interpret-mode timings measure the Python interpreter,
not the TPU — so the *correctness deltas* and the XLA-compiled oracle
timings are what we report; absolute kernel perf comes from the roofline
analysis of the lowered HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, save, timer


def run(quick: bool = True) -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    # conv oracle (XLA-compiled) + kernel correctness deltas
    from repro.kernels.conv_dataflow import conv2d, conv2d_ref
    x = jax.random.normal(key, (2, 16, 16, 8))
    w = jax.random.normal(key, (3, 3, 8, 16)) * 0.2
    ref_jit = jax.jit(conv2d_ref)
    ref, dt = timer(lambda: jax.block_until_ready(ref_jit(x, w)), iters=5)
    rows.append(row("kernel/conv_ref_xla", dt * 1e6, "oracle"))
    for df in ("SconvOD", "SconvIC", "MconvMC"):
        out = conv2d(x, w, dataflow=df, interpret=True)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append(row(f"kernel/conv_{df}_maxerr", 0.0, f"{err:.2e}"))

    # flash attention
    from repro.kernels.flash_attention import attention_ref, flash_attention
    import math
    b, s, h, d = 2, 128, 4, 32
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(key, (b, s, h, d))
    v = jax.random.normal(key, (b, s, h, d))
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref_fn = jax.jit(lambda a, b_, c: attention_ref(
        a, b_, c, causal=True, scale=1 / math.sqrt(d)))
    ref, dt = timer(lambda: jax.block_until_ready(ref_fn(qf, kf, vf)),
                    iters=5)
    rows.append(row("kernel/attention_ref_xla", dt * 1e6, "oracle"))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref4 = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    rows.append(row("kernel/flash_attention_maxerr", 0.0,
                    f"{float(jnp.max(jnp.abs(out - ref4))):.2e}"))

    # ssd scan
    from repro.kernels.ssd_scan import ssd_ref, ssd_scan
    b, s, h, p, n = 2, 64, 2, 16, 8
    u = jax.random.normal(key, (b, s, h, p)) * 0.3
    a = -jnp.abs(jax.random.normal(key, (b, s, h))) * 0.2
    Bm = jax.random.normal(key, (b, s, n)) * 0.5
    Cm = jax.random.normal(key, (b, s, n)) * 0.5
    uf = u.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    af = a.transpose(0, 2, 1).reshape(b * h, s)
    Bf = jnp.repeat(Bm[:, None], h, 1).reshape(b * h, s, n)
    Cf = jnp.repeat(Cm[:, None], h, 1).reshape(b * h, s, n)
    ref_fn = jax.jit(ssd_ref)
    (yr, hr), dt = timer(lambda: jax.block_until_ready(
        ref_fn(uf, af, Bf, Cf)), iters=5)
    rows.append(row("kernel/ssd_ref_xla", dt * 1e6, "oracle"))
    y, sfin = ssd_scan(u, a, Bm, Cm, chunk=16, interpret=True)
    yr4 = yr.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    rows.append(row("kernel/ssd_scan_maxerr", 0.0,
                    f"{float(jnp.max(jnp.abs(y - yr4))):.2e}"))
    save("kernel_micro", rows)
    return rows
