"""Shared benchmark plumbing.

Every benchmark module exposes ``run(quick: bool) -> list[dict]`` where each
dict has at least {"name", "us_per_call", "derived"}; ``benchmarks/run.py``
prints them as CSV (one row per measured quantity) and writes the full JSON
to experiments/bench/.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("BENCH_RESULTS_DIR", "experiments/bench")

# load-matched subsampling (see HMAIPlatform.capacity_scale)
RATE_SCALE = 0.05

# ---------------------------------------------------------------------------
# XLA host tuning (recorded in every BENCH_*.json)
# ---------------------------------------------------------------------------

# Keeps the per-step host marker out of the compiled region, so scan-heavy
# dispatches are not split at arbitrary points by profiling markers.
STEP_MARKER_FLAG = "--xla_step_marker_location=STEP_MARK_AT_ENTRY"

_TCMALLOC_GLOBS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc*.so*",
    "/usr/lib/libtcmalloc*.so*",
    "/usr/local/lib/libtcmalloc*.so*",
)


def find_tcmalloc():
    """First tcmalloc shared object on this host, or None.  Preloading it
    cuts allocator contention on many-core hosts; it can only take effect
    via LD_PRELOAD *before* process start, so callers record availability
    here and scripts/ci.sh / spawned children do the actual preload."""
    import glob
    for pat in _TCMALLOC_GLOBS:
        hits = sorted(glob.glob(pat))
        if hits:
            return hits[0]
    return None


def host_tuning(devices: int | None = None) -> dict:
    """The XLA host-tuning flags in effect for this process, as recorded
    in each ``BENCH_*.json`` — so a result file says which knobs were on
    when its numbers were measured (forced host device count, step-marker
    placement, tcmalloc preload)."""
    import re
    flags = os.environ.get("XLA_FLAGS", "")
    forced = re.findall(r"--xla_force_host_platform_device_count=(\d+)",
                        flags)
    tc = find_tcmalloc()
    return {
        "nproc": os.cpu_count(),
        "xla_force_host_platform_device_count":
            int(forced[-1]) if forced
            else (devices if devices is not None else 1),
        "step_marker_at_entry": STEP_MARKER_FLAG in flags,
        "tcmalloc_path": tc,
        "tcmalloc_active": bool(tc)
            and "tcmalloc" in os.environ.get("LD_PRELOAD", ""),
    }


def tuned_child_env(devices: int) -> dict:
    """Environment for a multi-device benchmark child: forced host device
    count (must precede jax import — last flag wins inside XLA_FLAGS),
    step markers at entry, and tcmalloc preloaded when the host has it."""
    env = dict(os.environ)
    base = env.get("XLA_FLAGS", "")
    if STEP_MARKER_FLAG not in base:
        base = f"{base} {STEP_MARKER_FLAG}".strip()
    env["XLA_FLAGS"] = (f"{base} "
                        f"--xla_force_host_platform_device_count={devices}")
    tc = find_tcmalloc()
    if tc and "tcmalloc" not in env.get("LD_PRELOAD", ""):
        env["LD_PRELOAD"] = tc + (os.pathsep + env["LD_PRELOAD"]
                                  if env.get("LD_PRELOAD") else "")
    return env


def timer(fn, *args, warmup: int = 1, iters: int = 3, **kwargs):
    """Returns (last_result, seconds_per_call)."""
    for _ in range(warmup):
        result = fn(*args, **kwargs)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kwargs)
    return result, (time.perf_counter() - t0) / iters


def row(name: str, us_per_call: float, derived, **extra) -> dict:
    r = {"name": name, "us_per_call": round(float(us_per_call), 3),
         "derived": derived}
    r.update(extra)
    return r


def save(module: str, rows: list) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{module}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def spawn_forced_device_child(module: str, devices: int, args: list,
                              result_tag: str, timeout: int = 1200) -> dict:
    """Run ``python -m benchmarks.<module> --child ...`` in a subprocess
    with ``--xla_force_host_platform_device_count`` (which must be set
    before jax imports) and parse the tagged JSON result line — the
    shared protocol of the multi-device benchmark children."""
    import subprocess
    import sys
    env = tuned_child_env(devices)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", f"benchmarks.{module}", "--child",
           "--devices", str(devices)] + [str(a) for a in args]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=timeout, cwd=root)
    if out.returncode != 0:
        raise RuntimeError(f"{module} child (devices={devices}) failed:\n"
                           + out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith(result_tag)][0]
    return json.loads(line[len(result_tag):])


def queues_for(area: str, n: int, km: float, seed0: int = 0):
    from repro.core.environment import Area, EnvironmentParams, build_task_queue
    return [build_task_queue(EnvironmentParams(
        area=Area(area), route_km=km, rate_scale=RATE_SCALE, seed=seed0 + s))
        for s in range(n)]


def platform():
    from repro.core.hmai import HMAIPlatform
    return HMAIPlatform(capacity_scale=RATE_SCALE)


_AGENT_CACHE = {}


def flexai_ckpt_path(area: str, quick: bool = False) -> str:
    """Per-area checkpoint; quick-mode checkpoints carry a ``_quick``
    suffix so a short smoke train can never masquerade as the full
    "well-trained agent" in a later quick=False run."""
    suffix = "_quick" if quick else ""
    return os.path.join("experiments", "flexai",
                        f"agent_{area.lower()}{suffix}.npz")


def trained_flexai(area: str = "UB", episodes: int = 25, quick: bool = True):
    """Train (or load) a FlexAI agent for an area; cached per process.

    If a usable pre-trained checkpoint for *this area* exists (written by
    a previous benchmark process or the ``launch.train --flexai`` offline
    run), load it — the paper's "well-trained agent".  Full runs only
    accept the full checkpoint; quick runs prefer it but fall back to the
    quick one.  Otherwise train device-resident (``ScanFlexAI`` fused
    episodes with eval-based model selection), export the weights to the
    Python-loop wrapper the figure modules consume, and write the
    checkpoint (plus a loss-history sidecar, so fig11 still has a curve
    when a later process loads the checkpoint instead of retraining).
    """
    key = (area, quick)
    if key in _AGENT_CACHE:
        return _AGENT_CACHE[key]
    from repro.core.flexai import FlexAIAgent, FlexAIConfig, ScanFlexAI
    plat = platform()
    cfg = FlexAIConfig(
        lr=1e-3, gamma=0.98, min_replay=256, update_every=2,
        eps_decay_steps=40000, target_sync_every=500)
    candidates = [flexai_ckpt_path(area)]
    if quick:
        candidates.append(flexai_ckpt_path(area, quick=True))
    ckpt = next((c for c in candidates if os.path.exists(c)), None)
    if ckpt is not None:
        losses_path = ckpt[: -len(".npz")] + "_losses.npy"
        agent = FlexAIAgent(plat, cfg)
        agent.load_weights(ckpt)
        if os.path.exists(losses_path):
            agent.losses = np.load(losses_path).tolist()
    else:
        ckpt = flexai_ckpt_path(area, quick=quick)
        losses_path = ckpt[: -len(".npz")] + "_losses.npy"
        queues = queues_for(area, 4, km=0.15)
        val_q = queues_for(area, 1, km=0.15, seed0=50)[0]
        trainer = ScanFlexAI(plat, cfg)
        trainer.train(queues, episodes=episodes if not quick else 12,
                      eval_queue=val_q, eval_every=4)
        agent = trainer.to_agent(plat)
        os.makedirs(os.path.dirname(ckpt), exist_ok=True)
        agent.save_weights(ckpt)
        np.save(losses_path, np.asarray(trainer.losses, np.float64))
    _AGENT_CACHE[key] = agent
    return agent
