"""Shared benchmark plumbing.

Every benchmark module exposes ``run(quick: bool) -> list[dict]`` where each
dict has at least {"name", "us_per_call", "derived"}; ``benchmarks/run.py``
prints them as CSV (one row per measured quantity) and writes the full JSON
to experiments/bench/.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("BENCH_RESULTS_DIR", "experiments/bench")

# load-matched subsampling (see HMAIPlatform.capacity_scale)
RATE_SCALE = 0.05


def timer(fn, *args, warmup: int = 1, iters: int = 3, **kwargs):
    """Returns (last_result, seconds_per_call)."""
    for _ in range(warmup):
        result = fn(*args, **kwargs)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kwargs)
    return result, (time.perf_counter() - t0) / iters


def row(name: str, us_per_call: float, derived, **extra) -> dict:
    r = {"name": name, "us_per_call": round(float(us_per_call), 3),
         "derived": derived}
    r.update(extra)
    return r


def save(module: str, rows: list) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{module}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def queues_for(area: str, n: int, km: float, seed0: int = 0):
    from repro.core.environment import Area, EnvironmentParams, build_task_queue
    return [build_task_queue(EnvironmentParams(
        area=Area(area), route_km=km, rate_scale=RATE_SCALE, seed=seed0 + s))
        for s in range(n)]


def platform():
    from repro.core.hmai import HMAIPlatform
    return HMAIPlatform(capacity_scale=RATE_SCALE)


_AGENT_CACHE = {}


def trained_flexai(area: str = "UB", episodes: int = 25, quick: bool = True):
    """Train (or load) a FlexAI agent for an area; cached per process.

    If a pre-trained checkpoint exists (the long offline run in
    experiments/flexai/), load it — the paper's "well-trained agent".
    Quick mode otherwise trains a small number of episodes.
    """
    key = (area, quick)
    if key in _AGENT_CACHE:
        return _AGENT_CACHE[key]
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    plat = platform()
    agent = FlexAIAgent(plat, FlexAIConfig(
        lr=1e-3, gamma=0.98, min_replay=256, update_every=2,
        eps_decay_steps=40000, target_sync_every=500))
    ckpt = os.path.join("experiments", "flexai", "agent_ub.npz")
    if os.path.exists(ckpt):
        agent.load_weights(ckpt)
    else:
        queues = queues_for(area, 4, km=0.15)
        val_q = queues_for(area, 1, km=0.15, seed0=50)[0]
        agent.train(plat, queues, episodes=episodes if not quick else 12,
                    eval_queue=val_q, eval_every=4)
    _AGENT_CACHE[key] = agent
    return agent
