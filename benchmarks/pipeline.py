"""Pipeline parallelism over the heterogeneous mesh (ISSUE 7 tentpole).

One deep perception route becomes a stage DAG; stages are placed on
accelerator *groups* (``core.pipeline.build_stage_plan``) and executed as
a micro-batched wavefront, either flattened on one device or stage-sharded
over a 2-D ``("stages", "routes")`` mesh with ``lax.ppermute`` resharding
at every stage boundary.

The contract this module gates (CI reads ``BENCH_pipeline.json``):

* **makespan**: on a drain workload (all tasks queued at t=0, deadlines
  waived) over deep UB routes, EFT placement with >= 2 stage groups must
  finish strictly earlier than single-stage placement over the SAME 11
  accelerators — pipelining wins by keeping each group busy on its own
  stage instead of serializing whole tasks.  Measured on the simulated
  platform clock (``makespan_s``), which is host-independent; wall times
  ride along as info on this oversubscribed CI host.
* **parity, flat vs reference**: the flattened wavefront engine must be
  bit-exact against the unpipelined task-major reference.
* **parity, sharded vs flat**: the shard_map'd engine on the (2, 2) mesh
  (4 forced host devices) must reproduce the flattened records and the
  combined final platform state bit-exactly — the mesh run is a pure
  re-layout.

Runs in a subprocess because ``--xla_force_host_platform_device_count``
must be set before jax imports.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

RESULT_TAG = "PIPELINE_RESULT "


def _child_main(args) -> None:
    import time

    import jax
    import numpy as np

    from benchmarks.common import RATE_SCALE
    from repro.core.environment import Area, EnvironmentParams, \
        build_task_queue
    from repro.core.hmai import HMAIPlatform
    from repro.core.pipeline import (build_stage_plan, combine_stage_states,
                                     make_pipeline_reference_fn,
                                     make_pipeline_schedule_fn,
                                     make_sharded_pipeline_fn,
                                     pipeline_summarize)
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import TaskArrays, stack_task_arrays, \
        tasks_to_arrays
    from repro.launch.mesh import make_platform_mesh

    n_dev = len(jax.devices())
    assert n_dev == args.devices, (n_dev, args.devices)
    S = args.stages

    def drain(ta: TaskArrays, tasks: int) -> TaskArrays:
        """First ``tasks`` rows as a drain workload: everything queued at
        t=0, deadlines waived — makespan is then a pure throughput
        measure of the placement."""
        ta = TaskArrays(*[np.asarray(f)[:tasks] for f in ta])
        return ta._replace(arrival=np.zeros_like(ta.arrival),
                           safety=np.full_like(ta.safety, 1e9))

    routes = []
    for s in range(args.routes):
        q = build_task_queue(EnvironmentParams(
            area=Area.UB, route_km=0.04, rate_scale=RATE_SCALE,
            seed=700 + s))
        assert len(q) >= args.tasks, (len(q), args.tasks)
        routes.append(drain(tasks_to_arrays(q), args.tasks))
    batch = stack_task_arrays(routes)

    plat = HMAIPlatform(capacity_scale=RATE_SCALE)
    spec = spec_from_platform(plat)

    def best_of(fn, iters):
        result = fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            result = fn()
            best = min(best, time.perf_counter() - t0)
        return result, best

    def mean_makespan(plan, final, recs):
        ms = []
        for lane in range(args.routes):
            f, r = jax.tree_util.tree_map(
                lambda a, l=lane: a[l], (final, recs))
            ms.append(pipeline_summarize(spec, f, r)["makespan_s"])
        return float(np.mean(ms))

    # single-stage baseline: same engine, S=1 (== the task-level scan
    # engine bit-exactly; tests/test_pipeline.py), every accelerator
    # eligible for every task
    plan1 = build_stage_plan(plat, 1)
    single = make_pipeline_schedule_fn(spec, plan1, policy="eft",
                                       batched=True)
    (f1, _, r1), t_single = best_of(
        lambda: jax.block_until_ready(single(None, batch)), args.iters)
    mk_single = mean_makespan(plan1, f1, r1)

    # pipelined: stage groups partition the same 11 accelerators
    planS = build_stage_plan(plat, S)
    flat = make_pipeline_schedule_fn(spec, planS, policy="eft",
                                     batched=True)
    (fS, _, rS), t_flat = best_of(
        lambda: jax.block_until_ready(flat(None, batch)), args.iters)
    mk_pipe = mean_makespan(planS, fS, rS)

    # parity 1: flattened wavefront == unpipelined task-major reference
    ref = jax.vmap(make_pipeline_reference_fn(spec, planS, policy="eft"),
                   in_axes=(None, 0))
    fR, _, rR = jax.jit(ref)(None, batch)
    flat_vs_ref = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves((fS, rS)),
                        jax.tree_util.tree_leaves((fR, rR))))

    # parity 2: stage-sharded mesh run == flattened (records and combined
    # final state bit-exact; ring hops via ppermute)
    mesh = make_platform_mesh(S)
    sharded = make_sharded_pipeline_fn(spec, planS, mesh, policy="eft")
    (stS, _, rcS), t_shard = best_of(
        lambda: jax.block_until_ready(sharded(None, batch)), args.iters)
    recs_ok = all(
        np.array_equal(np.asarray(a).transpose(1, 2, 0), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(rcS),
                        jax.tree_util.tree_leaves(rS)))
    comb = combine_stage_states(planS, stS)
    state_ok = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(comb),
                        jax.tree_util.tree_leaves(fS)))

    n_tasks = int(np.asarray(batch.valid).sum())
    print(RESULT_TAG + json.dumps({
        "devices": n_dev,
        "stages": S,
        "mesh_shape": [S, n_dev // S],
        "routes": args.routes,
        "tasks_per_route": args.tasks,
        "makespan_single_stage_s": round(mk_single, 4),
        "makespan_pipeline_s": round(mk_pipe, 4),
        "makespan_gain": round(mk_single / mk_pipe, 4),
        "pipeline_beats_single_stage": bool(mk_pipe < mk_single),
        "parity_flat_vs_reference": bool(flat_vs_ref),
        "parity_sharded_vs_flat": bool(recs_ok and state_ok),
        "wall_single_s": round(t_single, 4),
        "wall_flat_s": round(t_flat, 4),
        "wall_sharded_s": round(t_shard, 4),
        "scheduled_tasks_per_s_flat": round(n_tasks / t_flat, 1),
    }))


def _spawn(devices: int, stages: int, routes: int, tasks: int,
           iters: int) -> dict:
    from benchmarks.common import spawn_forced_device_child
    return spawn_forced_device_child(
        "pipeline", devices,
        ["--stages", stages, "--routes", routes, "--tasks", tasks,
         "--iters", iters],
        RESULT_TAG)


def run(quick: bool = True) -> list:
    from benchmarks.common import host_tuning, row, save

    tasks = 768 if quick else 2048
    res = _spawn(devices=4, stages=2, routes=2, tasks=tasks, iters=1)

    summary = {
        "child": res,
        "gate": {
            "pipeline_beats_single_stage":
                res["pipeline_beats_single_stage"],
            "parity_flat_vs_reference": res["parity_flat_vs_reference"],
            "parity_sharded_vs_flat": res["parity_sharded_vs_flat"],
        },
        "host_tuning": host_tuning(devices=4),
    }
    with open(os.path.join(os.getcwd(), "BENCH_pipeline.json"), "w") as f:
        json.dump(summary, f, indent=1)

    rows = [
        row("pipeline/makespan_single_stage", 0.0,
            f"{res['makespan_single_stage_s']:.2f} s"),
        row("pipeline/makespan_2stage", 0.0,
            f"{res['makespan_pipeline_s']:.2f} s"),
        row("pipeline/makespan_gain", 0.0, res["makespan_gain"],
            paper="stage groups must beat single-stage at equal devices"),
        row("pipeline/parity_flat_vs_reference", 0.0,
            res["parity_flat_vs_reference"]),
        row("pipeline/parity_sharded_vs_flat", 0.0,
            res["parity_sharded_vs_flat"]),
    ]
    save("pipeline", rows)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--routes", type=int, default=2)
    ap.add_argument("--tasks", type=int, default=768)
    ap.add_argument("--iters", type=int, default=1)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        _child_main(args)
        return 0
    for r in run(quick=not args.full):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
