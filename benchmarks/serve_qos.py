"""Serving QoS benchmark: EDF-with-aging vs bucket-FIFO wave admission.

The paper's serving claim is a *deadline* guarantee ("basically 100% of
tasks ... processed within their required period"), so this benchmark
measures the serving layer where that claim lives: requests are driving
routes with Table-5-derived deadlines arriving over a virtual timeline,
served by ``repro.serve.qos.QoSPlacementEngine`` under the two admission
policies at three offered-load levels (under-, at-, and over-capacity).

Reported per (load, policy): deadline-miss rate (late + shed), p50/p99
completion slack, shed count, preemption count, and the mean STM rate of
the schedules actually produced.  Everything is on the virtual serving
clock with a fixed seed, so the numbers are deterministic — CI gates on
EDF's miss rate being no worse at every load and strictly better at the
highest one.

Emits the standard benchmark rows *and* ``BENCH_serving.json`` (repo
root), like the other BENCH_* modules.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import RATE_SCALE, host_tuning, row, save

LOADS = (0.5, 1.0, 2.0)


def _requests(n: int, seed0: int = 200):
    """Mixed-size route requests (two length buckets so cross-bucket aging
    is actually exercised)."""
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.tasks import tasks_to_arrays
    queues = []
    for i in range(n):
        km = 0.004 if i % 2 else 0.012
        queues.append(tasks_to_arrays(build_task_queue(EnvironmentParams(
            route_km=km, rate_scale=RATE_SCALE, seed=seed0 + i,
            max_times_turn=1, max_times_reverse=1,
            max_duration_turn=2.0, max_duration_reverse=3.0))))
    return queues


def _serve(queues, policy: str, load: float, *, slots: int, plat=None,
           agent=None, seed: int = 0):
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.hmai import HMAIPlatform
    from repro.serve.qos import QoSConfig, QoSPlacementEngine

    if plat is None:
        plat = HMAIPlatform(capacity_scale=RATE_SCALE)
    if agent is None:
        agent = FlexAIAgent(plat, FlexAIConfig(seed=seed))
    cfg = QoSConfig(policy=policy, slots=slots, chunk=16, min_bucket=16)
    eng = QoSPlacementEngine(plat, agent.learner.eval_p, cfg,
                             backlog_scale=agent.cfg.backlog_scale)
    # offered load = solo service demand / arrival window; the wave engine
    # serves up to ``slots`` same-bucket requests per service pass, so
    # capacity sits between 1x and slots x the solo rate — load 2.0 is
    # firmly overloaded, 0.5 is comfortable
    mean_service = float(np.mean(
        [eng._bucket(q.num_tasks) for q in queues])) * eng.svc
    gap = mean_service / load
    rng = np.random.default_rng(seed)
    t = 0.0
    for q in queues:
        eng.submit(q, arrival=t)
        t += float(gap * rng.uniform(0.5, 1.5))
    eng.run_until_done()
    return eng.stats()


def run(quick: bool = True) -> list:
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.hmai import HMAIPlatform
    n_req = 10 if quick else 24
    slots = 2
    queues = _requests(n_req)
    # one platform/agent pair for every (load, policy) run: the engine
    # never mutates either, only the params are read
    plat = HMAIPlatform(capacity_scale=RATE_SCALE)
    agent = FlexAIAgent(plat, FlexAIConfig(seed=0))
    rows, result = [], {"loads": {}, "n_requests": n_req,
                        "rate_scale": RATE_SCALE, "slots": slots}
    for load in LOADS:
        result["loads"][str(load)] = {}
        for policy in ("edf", "fifo"):
            s = _serve(queues, policy, load, slots=slots, plat=plat,
                       agent=agent)
            result["loads"][str(load)][policy] = s
            rows.append(row(f"serve_qos/load{load}/{policy}/miss_rate",
                            0.0, round(s["miss_rate"], 4)))
            rows.append(row(f"serve_qos/load{load}/{policy}/p50_slack_s",
                            0.0, round(s["p50_slack_s"], 4)))
            rows.append(row(f"serve_qos/load{load}/{policy}/p99_slack_s",
                            0.0, round(s["p99_slack_s"], 4)))
            rows.append(row(f"serve_qos/load{load}/{policy}/shed",
                            0.0, s["shed"]))
    by = result["loads"]
    result["edf_never_worse"] = all(
        by[k]["edf"]["miss_rate"] <= by[k]["fifo"]["miss_rate"] + 1e-9
        for k in by)
    top = str(max(LOADS))
    result["edf_strictly_better_at_high_load"] = (
        by[top]["edf"]["miss_rate"] < by[top]["fifo"]["miss_rate"])
    rows.append(row("serve_qos/edf_never_worse", 0.0,
                    result["edf_never_worse"]))
    rows.append(row("serve_qos/edf_strictly_better_at_high_load", 0.0,
                    result["edf_strictly_better_at_high_load"],
                    paper="EDF admission must beat bucket-FIFO when "
                          "overloaded"))
    save("serve_qos", rows)
    result["host_tuning"] = host_tuning()
    with open(os.path.join(os.getcwd(), "BENCH_serving.json"), "w") as f:
        json.dump(result, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(quick=os.environ.get("BENCH_FULL", "") != "1"):
        print(r["name"], r["derived"])
