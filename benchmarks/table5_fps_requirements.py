"""Table 5: urban-area FPS requirements per scenario (DET/TRA and the
YOLO/SSD/GOTURN split) derived from the camera model."""
from __future__ import annotations

from benchmarks.common import row, save

PAPER = {  # scenario -> (DET, TRA, YOLO, SSD, GOTURN)
    "GS": (870, 840, 435, 435, 840),
    "TL": (950, 920, 475, 475, 920),
    "RE": (740, 740, 370, 370, 740),
}


def run(quick: bool = True) -> list:
    from repro.core.environment import Area, CAMERA_GROUPS, Scenario, camera_hz
    rows = []
    for sc_name, paper in PAPER.items():
        sc = Scenario(sc_name)
        det = sum(g.count * camera_hz(Area.UB, sc, g.name)
                  for g in CAMERA_GROUPS)
        tra = sum(g.count * camera_hz(Area.UB, sc, g.name)
                  for g in CAMERA_GROUPS
                  if g.name != "RC" or sc == Scenario.RE)
        rows.append(row(f"table5/{sc_name}/det_fps", 0.0, det,
                        paper=paper[0], match=abs(det - paper[0]) < 1e-6))
        rows.append(row(f"table5/{sc_name}/tra_fps", 0.0, tra,
                        paper=paper[1], match=abs(tra - paper[1]) < 1e-6))
        rows.append(row(f"table5/{sc_name}/yolo_fps", 0.0, det / 2,
                        paper=paper[2]))
        rows.append(row(f"table5/{sc_name}/goturn_fps", 0.0, tra,
                        paper=paper[4]))
    save("table5_fps_requirements", rows)
    return rows
