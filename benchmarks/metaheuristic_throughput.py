"""Device vs Python-loop GA/SA: scheduled-tasks/sec + fitness parity
(the ISSUE-3 perf tentpole).

Compares the windowed metaheuristic baselines at *equal population /
generations / iterations*: the NumPy loop (`GAScheduler` / `SAScheduler`,
one Python platform simulation per individual per generation per window)
against the device path (`make_metaheuristic_fn`: max-plus window fitness,
on-device evolution, one scan dispatch per route — or per route *batch*).

Also checks the fixed-seed fitness parity of the device ``window_fitness``
against the NumPy ``ga._evaluate`` oracle on a warm mid-route snapshot.

Emits the standard benchmark rows *and* ``BENCH_metaheuristics.json``
(repo root) so the trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (RATE_SCALE, host_tuning, platform, row,
                               save)


def _routes(n: int, km: float):
    from repro.core.environment import EnvironmentParams, build_task_queue
    return [build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RATE_SCALE, seed=200 + s))
        for s in range(n)]


def _time(fn, iters: int = 3):
    """Best-of-iters, applied identically to the loop and device paths:
    the shared CI host is noisy and min is the standard read of the
    machine's capability (same policy as ``sharded_engine.best_of``)."""
    fn()  # warmup (includes compile for the jitted paths)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _fitness_parity(plat, spec, queue, n_windows: int = 8) -> float:
    """Max relative |device - oracle| window fitness over random
    assignments evaluated from a warm mid-route snapshot."""
    from repro.core.platform_jax import state_from_platform
    from repro.core.schedulers import window_fitness
    from repro.core.schedulers.ga import _evaluate
    from repro.core.tasks import tasks_to_arrays
    rng = np.random.default_rng(0)
    if len(queue) < 70:
        raise ValueError(
            f"parity check needs a >= 70-task route, got {len(queue)} — "
            "an empty window would report parity vacuously")
    for t in queue[:40]:
        plat.execute(t, int(rng.integers(0, plat.n)))
    snap = state_from_platform(plat)
    window = queue[40:70]
    wa = tasks_to_arrays(window)
    worst = 0.0
    for _ in range(n_windows):
        assign = rng.integers(0, plat.n, len(window))
        ref = _evaluate(plat, window, assign)
        dev = float(window_fitness(spec, snap, wa,
                                   np.asarray(assign, np.int32)))
        worst = max(worst, abs(dev - ref) / max(abs(ref), 1e-12))
    return worst


def run(quick: bool = True) -> list:
    import jax

    from repro.core.platform_jax import spec_from_platform
    from repro.core.schedulers import (GAConfig, SAConfig, get_scheduler,
                                       make_metaheuristic_fn)
    from repro.core.tasks import stack_task_arrays, tasks_to_arrays

    km = 0.06 if quick else 0.15
    n_routes = 8 if quick else 16
    routes = _routes(n_routes, km)
    arrays = [tasks_to_arrays(q) for q in routes]
    batch = stack_task_arrays(arrays)
    n_tasks = len(routes[0])
    batch_tasks = sum(len(q) for q in routes)

    plat = platform()
    spec = spec_from_platform(plat)
    ga_cfg, sa_cfg = GAConfig(), SAConfig(chains=1)
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, batch.arrival.shape[0])

    results = {
        "n_tasks_per_route": n_tasks,
        "n_routes": n_routes,
        "rate_scale": RATE_SCALE,
        "ga": {"window": ga_cfg.window, "population": ga_cfg.population,
               "generations": ga_cfg.generations},
        "sa": {"window": sa_cfg.window, "iters": sa_cfg.iters,
               "chains": sa_cfg.chains},
    }
    rows = []
    for name, cfg in (("ga", ga_cfg), ("sa", sa_cfg)):
        # 1) the NumPy per-task loop (the pre-tentpole hot path)
        loop_sched = get_scheduler(name)
        t_loop = _time(lambda: loop_sched.schedule(platform(), routes[0]))
        loop_tps = n_tasks / t_loop
        # 2) fused device search, one dispatch per route
        fn = make_metaheuristic_fn(spec, name, cfg)
        t_dev = _time(lambda: jax.block_until_ready(fn(key, arrays[0])))
        dev_tps = n_tasks / t_dev
        # 3) vmapped multi-route batch, one dispatch for all routes
        fnb = make_metaheuristic_fn(spec, name, cfg, batched=True)
        t_batch = _time(lambda: jax.block_until_ready(fnb(keys, batch)))
        batch_tps = batch_tasks / t_batch
        results[name].update({
            "loop_tasks_per_s": round(loop_tps, 1),
            "device_tasks_per_s": round(dev_tps, 1),
            "device_batch_tasks_per_s": round(batch_tps, 1),
            "speedup_device_vs_loop": round(dev_tps / loop_tps, 2),
            "speedup_batch_vs_loop": round(batch_tps / loop_tps, 2),
        })
        rows += [
            row(f"metaheuristics/{name}/loop", t_loop / n_tasks * 1e6,
                f"{loop_tps:.0f} tasks/s"),
            row(f"metaheuristics/{name}/device", t_dev / n_tasks * 1e6,
                f"{dev_tps:.0f} tasks/s"),
            row(f"metaheuristics/{name}/device_batch",
                t_batch / batch_tasks * 1e6,
                f"{batch_tps:.0f} tasks/s over {n_routes} routes"),
            row(f"metaheuristics/{name}/speedup_device_vs_loop", 0.0,
                results[name]["speedup_device_vs_loop"]),
        ]

    parity = _fitness_parity(platform(), spec, routes[0])
    results["fitness_max_rel_diff"] = parity
    results["fitness_parity_ok"] = bool(parity <= 1e-4)
    results["meets_20x_ga"] = bool(
        results["ga"]["speedup_device_vs_loop"] >= 20.0
        or results["ga"]["speedup_batch_vs_loop"] >= 20.0)
    results["host_tuning"] = host_tuning()
    with open(os.path.join(os.getcwd(), "BENCH_metaheuristics.json"),
              "w") as f:
        json.dump(results, f, indent=1)

    rows.append(row("metaheuristics/fitness_max_rel_diff", 0.0,
                    f"{parity:.2e}"))
    rows.append(row("metaheuristics/meets_20x_ga", 0.0,
                    results["meets_20x_ga"]))
    save("metaheuristic_throughput", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=os.environ.get("BENCH_FULL", "") != "1"):
        print(r)
