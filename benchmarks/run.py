"""Benchmark driver: one module per paper table/figure + kernel micro +
roofline.  Prints ``name,us_per_call,derived`` CSV per row and writes the
full JSON per module to experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only MOD]
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "table1_cnn_features",
    "table5_fps_requirements",
    "table8_accelerator_perf",
    "fig2_platform_comparison",
    "fig10_hmai_vs_baselines",
    "fig11_training_loss",
    "fig12_scheduler_comparison",
    "fig13_stmrate",
    "fig14_braking_distance",
    "scheduler_throughput",
    "serve_qos",
    "serve_load",
    "metaheuristic_throughput",
    "sharded_engine",
    "training_throughput",
    "pipeline",
    "kernel_micro",
    "kernels",
    "roofline",
    "recovery",
    "scenarios",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size queues / all areas (slow)")
    ap.add_argument("--only", default=None, help="run a single module")
    args = ap.parse_args(argv)
    quick = not args.full

    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(quick=quick)
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod_name},0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
