"""FlexAI training throughput: Python-loop vs fused scan vs data-parallel.

Three trainers over identical routes and hyperparameters:

* **loop** — ``FlexAIAgent.train``: one Python iteration (plus 1-2 jit
  dispatches) per task, the seed implementation;
* **fused** — ``ScanFlexAI`` single lane: the whole episode (act, platform
  step, reward, replay write, TD update) in one ``lax.scan`` dispatch;
* **dp** — ``make_dp_train_fn``: one synchronized agent over a route
  batch, per-step gradient all-reduce, sharded over forced host devices
  (subprocess children, since ``--xla_force_host_platform_device_count``
  must be set before jax imports).  Each multi-device child re-times the
  *unsharded* DP runner on the same global batch in the same process, so
  the scaling factor compares like with like, and asserts loss/parameter
  parity between the two before reporting.

A separate equal-episode quality run (eval-based model selection on both
paths, averaged over seeds) records final held-out-queue STM so the
fused path's placement quality is auditable against the loop trainer's.

Honesty note: on this CPU host both trainers share the TD-update matmul
compute (~0.5 ms/update), so the full-trainer ratio cannot approach the
~29x inference-only ratio — the ``acting_*`` rows isolate the per-task
host overhead the fused engine does remove.  On accelerator hardware the
update compute shrinks and the ratio becomes dispatch-bound again.

Emits the standard benchmark rows plus ``BENCH_training.json`` (repo
root) with the speedup and parity columns.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DP_DEVICE_COUNTS = (1, 4)
RESULT_TAG = "TRAINING_RESULT "


def _cfg(seed: int = 7, **over):
    from repro.core.flexai import FlexAIConfig
    kw = dict(lr=1e-3, gamma=0.98, batch_size=32, min_replay=128,
              update_every=2, eps_decay_steps=2000, target_sync_every=200,
              replay_capacity=8192, seed=seed)
    kw.update(over)
    return FlexAIConfig(**kw)


def _dp_cfg():
    """DP config: per-lane batches kept small (the global batch is
    lanes x batch_size) so the unsharded baseline is dispatch-bound
    rather than intra-op-threaded — the regime route sharding targets."""
    from repro.core.flexai import FlexAIConfig
    return FlexAIConfig(lr=1e-3, gamma=0.98, batch_size=32, min_replay=128,
                        update_every=1, eps_decay_steps=2000,
                        target_sync_every=200, replay_capacity=1024, seed=7)


def _routes(n: int, tasks: int, seed0: int = 70):
    """n unique routes trimmed to exactly ``tasks`` tasks each (Task lists
    for the loop trainer; callers convert to TaskArrays for the engines)."""
    from benchmarks.common import queues_for
    return [q[:tasks] for q in queues_for("UB", n, km=0.05, seed0=seed0)]


# ---------------------------------------------------------------------------
# loop vs fused (in-process, single device)
# ---------------------------------------------------------------------------

def _time_pair(cfg, queues, episodes: int, reps: int = 3
               ) -> tuple[float, float]:
    """(loop_s, fused_s) for ``episodes`` from-scratch episodes at equal
    config.  Compiles are warmed out of band (a throwaway learner warms
    the module-level ``dqn_update``; each timing agent's per-instance
    ``q_values`` jit warms on a dummy state, which mutates nothing); the
    fused side times the raw engine fn — wrapper summaries are host-side
    reporting, not training.  The two variants alternate for ``reps``
    fresh-state repetitions and each keeps its best window (the
    container's CPU budget swings at the multi-second scale)."""
    import jax
    import numpy as np

    from benchmarks.common import platform
    from repro.core.flexai import FlexAIAgent
    from repro.core.flexai.engine import make_train_fn, train_init
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import tasks_to_arrays

    plat = platform()
    spec = spec_from_platform(plat)
    state_dim = 3 + 5 * plat.n

    if cfg.min_replay < 10**9:
        warm = FlexAIAgent(platform(), cfg)
        warm.learner.update({
            "s": np.zeros((cfg.batch_size, state_dim), np.float32),
            "a": np.zeros(cfg.batch_size, np.int32),
            "r": np.zeros(cfg.batch_size, np.float32),
            "s_next": np.zeros((cfg.batch_size, state_dim), np.float32),
            "done": np.zeros(cfg.batch_size, np.float32)})
    routes = [tasks_to_arrays(q) for q in queues]
    fn = make_train_fn(spec, cfg)
    key = jax.random.PRNGKey(cfg.seed)
    warm_ts = train_init(key, state_dim, plat.n, cfg.replay_capacity)
    jax.block_until_ready(fn(warm_ts, routes[0])[0].eval_p)

    t_loop, t_fused = float("inf"), float("inf")
    for _ in range(reps):
        agent = FlexAIAgent(platform(), cfg)
        agent.learner.q_values(np.zeros((1, state_dim), np.float32))
        p = platform()
        t0 = time.perf_counter()
        agent.train(p, queues, episodes=episodes)
        t_loop = min(t_loop, time.perf_counter() - t0)

        ts = train_init(key, state_dim, plat.n, cfg.replay_capacity)
        t0 = time.perf_counter()
        for ep in range(episodes):
            ts = fn(ts, routes[ep % len(routes)])[0]
        jax.block_until_ready(ts.eval_p)
        t_fused = min(t_fused, time.perf_counter() - t0)
    return t_loop, t_fused


def _loop_vs_fused(tasks: int, episodes: int, quality_episodes: int,
                   quality_seeds) -> dict:
    import numpy as np

    from benchmarks.common import platform
    from repro.core.flexai import FlexAIAgent, ScanFlexAI

    queues = _routes(3, tasks)
    val_q = _routes(1, tasks, seed0=90)[0]
    steps = tasks * episodes

    # -- timing at equal episodes and equal config.  Two cadences:
    # the full trainer (TD update every update_every steps — both paths
    # share the ~0.5 ms TD-update matmul compute, which floors the
    # achievable ratio on a CPU host), and the acting path alone
    # (min_replay never reached), which isolates the per-task host
    # overhead the fused engine actually eliminates.
    t_loop, t_fused = _time_pair(_cfg(), queues, episodes)
    t_loop_act, t_fused_act = _time_pair(
        _cfg(min_replay=10**9), queues, episodes)

    # -- quality at equal episodes: eval-based model selection on both
    # paths, averaged over seeds (single-seed DQN outcomes swing by
    # +-0.1 STM on these short runs)
    def tail_loss(losses):
        tail = np.asarray(losses[-max(len(losses) // 4, 1):], np.float64)
        return float(tail.mean()) if len(tail) else np.nan

    loop_stms, fused_stms = [], []
    loop_tails, fused_tails = [], []
    for seed in quality_seeds:
        cfg_q = _cfg(seed=seed)
        plat_q = platform()
        loop_q = FlexAIAgent(plat_q, cfg_q)
        loop_q.train(plat_q, queues, episodes=quality_episodes,
                     eval_queue=val_q, eval_every=2)
        loop_stms.append(loop_q.schedule_scan(platform(),
                                              val_q)["stm_rate"])
        loop_tails.append(tail_loss(loop_q.losses))
        fused_q = ScanFlexAI(platform(), cfg_q)
        fused_q.train(queues, episodes=quality_episodes,
                      eval_queue=val_q, eval_every=2)
        fused_stms.append(fused_q.schedule(val_q)["stm_rate"])
        fused_tails.append(tail_loss(fused_q.losses))
    loop_stm = float(np.mean(loop_stms))
    fused_stm = float(np.mean(fused_stms))

    return {
        "tasks_per_route": tasks,
        "episodes": episodes,
        "loop": {"train_s": round(t_loop, 3),
                 "env_steps_per_s": round(steps / t_loop, 1),
                 "acting_env_steps_per_s": round(steps / t_loop_act, 1),
                 "eval_stm_mean": round(loop_stm, 4),
                 "eval_stm_by_seed": [round(s, 4) for s in loop_stms],
                 "tail_mean_loss": float(np.nanmean(loop_tails))},
        "fused": {"train_s": round(t_fused, 3),
                  "env_steps_per_s": round(steps / t_fused, 1),
                  "acting_env_steps_per_s": round(steps / t_fused_act, 1),
                  "eval_stm_mean": round(fused_stm, 4),
                  "eval_stm_by_seed": [round(s, 4) for s in fused_stms],
                  "tail_mean_loss": float(np.nanmean(fused_tails))},
        "fused_speedup_vs_loop": round(t_loop / t_fused, 2),
        "acting_speedup_vs_loop": round(t_loop_act / t_fused_act, 2),
        "note": "both trainers share the TD-update matmul compute "
                "(~0.5 ms/update on this CPU host), which bounds the "
                "full-trainer ratio; the acting-path ratio shows the "
                "per-task host overhead the fused engine removes "
                "(cf. the ~29x inference-only ratio in BENCH_scheduler)",
        # model selection keeps the best-eval weights on both paths, so
        # "no worse" is checked on the seed mean with a small tolerance
        "eval_parity_ok": bool(fused_stm >= loop_stm - 0.02),
    }


# ---------------------------------------------------------------------------
# fused TD-update kernel arm (report-only on CPU hosts)
# ---------------------------------------------------------------------------

def _td_kernel_arm(tasks: int, episodes: int, reps: int = 3) -> dict:
    """Times the fused engine with ``td_kernel=True`` against the default
    XLA TD update on identical routes/config, and checks loss parity.

    On a CPU host the kernel runs in interpret mode (the Pallas body
    lowered to plain XLA ops), so the ratio here is NOT a hardware kernel
    claim in either direction — it is reported, never gated.  The
    compiled ratio lives in ``BENCH_kernels.json``'s compiled leg, which
    only runs on a TPU/GPU host under ``REPRO_KERNEL_COMPILED=1``."""
    import jax
    import numpy as np

    from benchmarks.common import platform
    from repro.core.flexai.engine import make_train_fn, train_init
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import tasks_to_arrays

    cfg = _cfg()
    plat = platform()
    spec = spec_from_platform(plat)
    state_dim = 3 + 5 * plat.n
    routes = [tasks_to_arrays(q) for q in _routes(3, tasks)]
    key = jax.random.PRNGKey(cfg.seed)

    def episode_time(fn):
        ts0 = train_init(key, state_dim, plat.n, cfg.replay_capacity)
        jax.block_until_ready(fn(ts0, routes[0])[0].eval_p)   # warm compile
        best = float("inf")
        last = None
        for _ in range(reps):
            ts = train_init(key, state_dim, plat.n, cfg.replay_capacity)
            t0 = time.perf_counter()
            for ep in range(episodes):
                ts = fn(ts, routes[ep % len(routes)])[0]
            jax.block_until_ready(ts.eval_p)
            best = min(best, time.perf_counter() - t0)
            last = ts
        return best, last

    t_off, ts_off = episode_time(make_train_fn(spec, cfg))
    t_on, ts_on = episode_time(make_train_fn(spec, cfg, td_kernel=True))
    max_p = max(float(jnp_abs_max(a, b))
                for a, b in zip(ts_off.eval_p, ts_on.eval_p))
    steps = tasks * episodes
    return {
        "env_steps_per_s_off": round(steps / t_off, 1),
        "env_steps_per_s_on": round(steps / t_on, 1),
        "on_vs_off_ratio": round(t_off / t_on, 3),
        "final_param_max_diff": max_p,
        "parity_ok": bool(max_p <= 1e-5),
        "mode": "interpret (CPU host)" if _interpret_mode()
                else "compiled",
        "note": "interpret-mode Pallas on a CPU host executes the kernel "
                "body as plain XLA ops — this ratio says nothing about "
                "hardware kernel speed; see BENCH_kernels.json compiled "
                "leg for the honest accelerator number (reported, not "
                "gated)",
    }


def jnp_abs_max(a, b):
    import jax.numpy as jnp
    return jnp.max(jnp.abs(a - b))


def _interpret_mode() -> bool:
    from repro.compat import pallas_interpret_default
    return pallas_interpret_default()


# ---------------------------------------------------------------------------
# data-parallel child (forced host devices)
# ---------------------------------------------------------------------------

def _child_main(args) -> None:
    import jax
    import numpy as np

    from benchmarks.common import platform
    from repro.compat import make_mesh
    from repro.core.flexai import dp_train_init, make_dp_train_fn
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import stack_task_arrays, tasks_to_arrays

    n_dev = len(jax.devices())
    assert n_dev == args.devices, (n_dev, args.devices)
    cfg = _dp_cfg()
    plat = platform()
    spec = spec_from_platform(plat)
    lanes = args.dp_lanes
    uniq = _routes(min(lanes, 8), args.tasks)
    batch = stack_task_arrays(
        [tasks_to_arrays(uniq[i % len(uniq)]) for i in range(lanes)])
    state_dim = 3 + 5 * plat.n
    key = jax.random.PRNGKey(cfg.seed)
    ts0 = dp_train_init(key, state_dim, plat.n, cfg.replay_capacity, lanes)
    steps = int(np.asarray(batch.valid).sum())

    def best_of(fn, iters):
        result = fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            result = fn()
            best = min(best, time.perf_counter() - t0)
        return result, best

    fn_u = make_dp_train_fn(spec, cfg, lanes)
    result = {
        "devices": n_dev,
        "lanes": lanes,
        "tasks_per_lane": args.tasks,
    }
    if n_dev == 1:
        _, t_u = best_of(
            lambda: jax.block_until_ready(fn_u(ts0, batch)), args.iters)
        result["unsharded_env_steps_per_s"] = round(steps / t_u, 1)
    else:
        from repro.core.flexai import FlexAIConfig

        mesh = make_mesh((n_dev,), ("routes",))
        fn_s = make_dp_train_fn(spec, cfg, lanes, mesh=mesh)
        jax.block_until_ready(fn_u(ts0, batch))  # compile warmups
        jax.block_until_ready(fn_s(ts0, batch))
        # interleaved best-of timing: the container's CPU budget swings
        # at the multi-second scale, so unsharded/sharded runs alternate
        # and each variant keeps its best window (the sharded_engine
        # convention for this noisy host)
        t_u, t_s = float("inf"), float("inf")
        for _ in range(max(args.iters, 3)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn_u(ts0, batch))
            t_u = min(t_u, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fn_s(ts0, batch))
            t_s = min(t_s, time.perf_counter() - t0)
        result["unsharded_env_steps_per_s"] = round(steps / t_u, 1)

        # Parity runs on a dedicated short-route / fast-epsilon-decay
        # segment: over long routes the policy feedback loop amplifies
        # ulp-level fp differences (pmean reduction order vs the local
        # lane mean) into diverged action trajectories, so trajectory
        # equality is only a meaningful contract before that drift can
        # compound.  Same init + same batch -> identical placements,
        # params/losses to accumulated-fp32 tolerance.
        p_cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=2,
                             eps_decay_steps=500, replay_capacity=2048,
                             seed=7)
        p_uniq = _routes(min(lanes, 8), 128)
        p_batch = stack_task_arrays(
            [tasks_to_arrays(p_uniq[i % len(p_uniq)]) for i in range(lanes)])
        p_ts = dp_train_init(key, state_dim, plat.n, p_cfg.replay_capacity,
                             lanes)
        p_u = jax.block_until_ready(
            make_dp_train_fn(spec, p_cfg, lanes)(p_ts, p_batch))
        p_s = jax.block_until_ready(
            make_dp_train_fn(spec, p_cfg, lanes, mesh=mesh)(p_ts, p_batch))
        rel = 0.0
        for a, b in zip(p_u[0].eval_p, p_s[0].eval_p):
            a, b = np.asarray(a), np.asarray(b)
            rel = max(rel, float(np.max(np.abs(a - b))
                                 / max(np.max(np.abs(a)), 1e-9)))
        loss_diff = float(np.max(np.abs(np.asarray(p_u[3])
                                        - np.asarray(p_s[3]))))
        placements_equal = bool(np.array_equal(
            np.asarray(p_u[2].action), np.asarray(p_s[2].action)))
        assert placements_equal, \
            "sharded DP action trajectory diverges from unsharded"
        assert rel < 5e-3 and loss_diff < 1e-3, \
            f"sharded/unsharded DP divergence: params {rel} loss {loss_diff}"
        assert int(p_u[0].env_steps) == int(p_s[0].env_steps)
        result.update({
            "sharded_env_steps_per_s": round(steps / t_s, 1),
            "sharded_speedup_vs_unsharded": round(t_u / t_s, 2),
            "parity_placements_equal": placements_equal,
            "parity_params_rel_diff": rel,
            "parity_loss_max_diff": loss_diff,
            "parity_ok": True,
        })
    print(RESULT_TAG + json.dumps(result))


def _spawn(devices: int, lanes: int, tasks: int, iters: int) -> dict:
    from benchmarks.common import spawn_forced_device_child
    return spawn_forced_device_child(
        "training_throughput", devices,
        ["--dp-lanes", lanes, "--tasks", tasks, "--iters", iters],
        RESULT_TAG)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = True) -> list:
    from benchmarks.common import host_tuning, row, save

    tasks = 384 if quick else 1024
    episodes = 2 if quick else 4
    quality_episodes = 8 if quick else 16
    quality_seeds = (7, 8, 9) if quick else (7, 8, 9, 10, 11)
    dp_lanes = 64
    dp_tasks = 192 if quick else 384

    base = _loop_vs_fused(tasks, episodes, quality_episodes, quality_seeds)
    tdk = _td_kernel_arm(tasks, episodes)
    dp = {d: _spawn(d, dp_lanes, dp_tasks, iters=3 if quick else 5)
          for d in DP_DEVICE_COUNTS}
    # headline scaling is the 4-device child's paired in-process ratio
    # (cross-child comparisons see different machine-noise windows)
    dp_speedup = dp[4]["sharded_speedup_vs_unsharded"]

    summary = dict(base)
    summary["td_kernel"] = tdk
    summary["dp"] = {
        "lanes": dp_lanes,
        "tasks_per_lane": dp_tasks,
        "by_device_count": dp,
        "speedup_4dev_vs_1dev": dp_speedup,
        "parity_ok": bool(dp[4].get("parity_ok", False)),
        "note": "this container exposes 2 physical cores, so 4 forced "
                "host devices oversubscribe 2:1; scaling saturates near "
                "the measured ratio and clears 1.5x only on hosts with "
                ">= 4 cores (collective cost is negligible: an "
                "axis-free shard_map variant times the same)",
    }
    summary["host_tuning"] = host_tuning(devices=4)
    with open(os.path.join(os.getcwd(), "BENCH_training.json"), "w") as f:
        json.dump(summary, f, indent=1)

    rows = [
        row("training/loop_env_steps_per_s", 0.0,
            base["loop"]["env_steps_per_s"]),
        row("training/fused_env_steps_per_s", 0.0,
            base["fused"]["env_steps_per_s"]),
        row("training/fused_speedup_vs_loop", 0.0,
            f"{base['fused_speedup_vs_loop']}x"),
        row("training/acting_speedup_vs_loop", 0.0,
            f"{base['acting_speedup_vs_loop']}x"),
        row("training/eval_parity_ok", 0.0, base["eval_parity_ok"],
            loop_stm=base["loop"]["eval_stm_mean"],
            fused_stm=base["fused"]["eval_stm_mean"]),
        row("training/dp_1dev_env_steps_per_s", 0.0,
            dp[1]["unsharded_env_steps_per_s"]),
        row("training/dp_4dev_env_steps_per_s", 0.0,
            dp[4]["sharded_env_steps_per_s"]),
        row("training/dp_speedup_4dev_vs_1dev", 0.0, f"{dp_speedup}x"),
        row("training/dp_parity_ok", 0.0,
            summary["dp"]["parity_ok"]),
        row("training/td_kernel_env_steps_per_s", 0.0,
            tdk["env_steps_per_s_on"], mode=tdk["mode"]),
        row("training/td_kernel_on_vs_off_ratio", 0.0,
            f"{tdk['on_vs_off_ratio']}x", mode=tdk["mode"]),
        row("training/td_kernel_parity_ok", 0.0, tdk["parity_ok"]),
    ]
    save("training_throughput", rows)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--dp-lanes", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=256)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        _child_main(args)
        return 0
    for r in run(quick=not args.full):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
