"""Scheduled-tasks/sec: per-task Python loop vs fused lax.scan vs vmapped
multi-route batch (the ISSUE-1 perf tentpole).

Emits the standard benchmark rows *and* ``BENCH_scheduler.json`` (repo
root) so the throughput trajectory is tracked across PRs.  The paper's bar
(Table 5): the scheduler must keep up with 870-950 decisions/sec aggregate.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (RATE_SCALE, host_tuning, platform, row,
                               save)


def _routes(n: int, km: float):
    from repro.core.environment import EnvironmentParams, build_task_queue
    return [build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RATE_SCALE, seed=100 + s))
        for s in range(n)]


def _time(fn, iters: int = 3):
    fn()  # warmup (includes compile for the jitted paths)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(quick: bool = True) -> list:
    import jax
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.flexai.engine import make_schedule_fn
    from repro.core.platform_jax import spec_from_platform
    from repro.core.schedulers import get_scan_scheduler, get_scheduler
    from repro.core.tasks import stack_task_arrays, tasks_to_arrays

    km = 0.1 if quick else 0.25
    n_routes = 4 if quick else 8
    routes = _routes(n_routes, km)
    n_tasks = len(routes[0])
    arrays = [tasks_to_arrays(q) for q in routes]
    batch = stack_task_arrays(arrays)

    plat = platform()
    agent = FlexAIAgent(plat, FlexAIConfig())
    spec = spec_from_platform(plat)

    # 1) per-task Python loop (the pre-tentpole hot path)
    t_loop = _time(lambda: agent.schedule(platform(), routes[0]),
                   iters=2 if quick else 3)
    loop_tps = n_tasks / t_loop

    # 2) fused scan, one dispatch per route
    sched = make_schedule_fn(spec, agent.cfg.backlog_scale)
    params = agent.learner.eval_p
    t_scan = _time(
        lambda: jax.block_until_ready(sched(params, arrays[0])))
    scan_tps = n_tasks / t_scan

    # 3) vmapped multi-route batch, one dispatch per batch
    sched_b = make_schedule_fn(spec, agent.cfg.backlog_scale, batched=True)
    t_batch = _time(lambda: jax.block_until_ready(sched_b(params, batch)))
    batch_tasks = sum(len(q) for q in routes)
    batch_tps = batch_tasks / t_batch

    # 4) heuristics through the same array path (context row)
    ata_loop = _time(lambda: get_scheduler("ata").schedule(
        platform(), routes[0]), iters=2 if quick else 3)
    ata_fn = get_scan_scheduler("ata")
    t_ata = _time(lambda: jax.block_until_ready(ata_fn(spec, arrays[0])))

    results = {
        "n_tasks_per_route": n_tasks,
        "n_routes": n_routes,
        "rate_scale": RATE_SCALE,
        "loop_tasks_per_s": round(loop_tps, 1),
        "scan_tasks_per_s": round(scan_tps, 1),
        "vmap_batch_tasks_per_s": round(batch_tps, 1),
        "ata_loop_tasks_per_s": round(len(routes[0]) / ata_loop, 1),
        "ata_scan_tasks_per_s": round(len(routes[0]) / t_ata, 1),
        "speedup_scan_vs_loop": round(scan_tps / loop_tps, 2),
        "speedup_batch_vs_loop": round(batch_tps / loop_tps, 2),
        "meets_table5_950fps": bool(scan_tps >= 950.0),
    }
    results["host_tuning"] = host_tuning()
    with open(os.path.join(os.getcwd(), "BENCH_scheduler.json"), "w") as f:
        json.dump(results, f, indent=1)

    rows = [
        row("sched_throughput/loop", t_loop / n_tasks * 1e6,
            f"{loop_tps:.0f} tasks/s"),
        row("sched_throughput/scan", t_scan / n_tasks * 1e6,
            f"{scan_tps:.0f} tasks/s"),
        row("sched_throughput/vmap_batch", t_batch / batch_tasks * 1e6,
            f"{batch_tps:.0f} tasks/s over {n_routes} routes"),
        row("sched_throughput/speedup_scan_vs_loop", 0.0,
            results["speedup_scan_vs_loop"]),
        row("sched_throughput/speedup_batch_vs_loop", 0.0,
            results["speedup_batch_vs_loop"]),
        row("sched_throughput/ata_scan_vs_loop", 0.0,
            round(ata_loop / t_ata, 2)),
    ]
    save("scheduler_throughput", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=os.environ.get("BENCH_FULL", "") != "1"):
        print(r)
