"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch x shape x mesh) cell from the dry-run records.

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_bytes / (chips x 50 GB/s/link ICI)

HLO_FLOPs / bytes / collective bytes come from the dry-run's probe
(scan-trip-corrected; see launch/dryrun.py) and are PER-DEVICE, so the
"chips x" in the denominators is already applied.  MODEL_FLOPS = 6*N*D
(dense) or 6*N_active*D (MoE) for train cells; 2*N*(tokens) for inference.
"""
from __future__ import annotations

import json
import os
from collections import defaultdict

from benchmarks.common import row, save

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

DRYRUN_RESULTS = os.environ.get("DRYRUN_RESULTS",
                                "experiments/dryrun/results.jsonl")


def load_records(path: str = DRYRUN_RESULTS) -> list:
    if not os.path.exists(path):
        return []
    # keep the latest record per (arch, shape, mesh, rules)
    latest = {}
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"),
                   rec.get("rules", "default"))
            latest[key] = rec
    return list(latest.values())


def model_flops(rec: dict) -> float:
    """Useful-model FLOPs for the cell (global)."""
    n_active = rec.get("active_param_count", 0)
    tokens = rec.get("tokens", 0)
    if rec["shape"].startswith("train"):
        return 6.0 * n_active * tokens
    if rec["shape"].startswith("prefill"):
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * tokens


def roofline_terms(rec: dict) -> dict:
    probe = rec.get("probe") or {}
    if "error" in probe or "flops_per_device" not in probe:
        # fall back to the (scan-undercounted) raw compile numbers
        flops = rec.get("flops_per_device", 0.0)
        bytes_acc = rec.get("bytes_accessed_per_device", 0.0)
        coll = rec.get("collectives", {}).get("total_operand_bytes", 0.0)
        corrected = False
    else:
        flops = max(0.0, probe["flops_per_device"])
        bytes_acc = max(0.0, probe["bytes_accessed_per_device"])
        coll = max(0.0, probe["collective_operand_bytes"])
        corrected = True
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    bottleneck = max(terms, key=terms.get)
    devices = rec.get("devices", 256)
    mf = model_flops(rec)
    mf_per_device = mf / devices
    useful_ratio = mf_per_device / flops if flops else 0.0
    # roofline fraction: useful FLOP/s achieved if the dominant term set the
    # step time, vs peak
    step_time = max(terms.values())
    roofline_frac = (mf_per_device / step_time) / PEAK_FLOPS if step_time else 0.0
    return {
        **{k: float(v) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_global": mf,
        "useful_flops_ratio": float(useful_ratio),
        "roofline_fraction": float(roofline_frac),
        "trip_corrected": corrected,
    }


def suggest(rec: dict, terms: dict) -> str:
    b = terms["bottleneck"]
    if b == "collective":
        if "moe" in rec["arch"] or rec["arch"].startswith(("qwen3", "moonshot",
                                                           "jamba")):
            return ("stage MoE dispatch as explicit all-to-all over the "
                    "expert axis (shard_map) instead of GSPMD scatter "
                    "resharding")
        if rec["shape"].startswith("decode"):
            return ("keep new-KV writes local to the sequence shard and "
                    "reduce only the per-head partial softmax stats")
        return ("turn TP all-reduces into reduce-scatter + all-gather pairs "
                "(sequence-parallel residual is already sharded)")
    if b == "memory":
        if rec["shape"].startswith("decode"):
            return "quantize/shrink KV reads (GQA cache already minimal)"
        return "fuse/reshape to cut activation round-trips; larger microbatch"
    return "reduce remat recompute (save-dots policy) / skip masked attn work"


def markdown_table(records: list) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "bottleneck | useful ratio | roofline frac | what would move it |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for rec in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                              r["mesh"])):
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | skipped | — | — | {rec['reason']} |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | FAILED | — | — | {rec.get('error','')[:60]} |")
            continue
        t = roofline_terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['bottleneck']} "
            f"| {t['useful_flops_ratio']:.2f} | {t['roofline_fraction']:.3f} "
            f"| {suggest(rec, t)} |")
    return "\n".join(lines)


def run(quick: bool = True) -> list:
    records = load_records()
    rows = []
    singles = [r for r in records if r.get("mesh") == "pod16x16"
               and r.get("rules", "default") == "default"]
    for rec in singles:
        if rec.get("status") != "ok":
            continue
        t = roofline_terms(rec)
        rows.append(row(
            f"roofline/{rec['arch']}/{rec['shape']}",
            max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
            t["bottleneck"],
            compute_s=round(t["compute_s"], 6),
            memory_s=round(t["memory_s"], 6),
            collective_s=round(t["collective_s"], 6),
            useful_ratio=round(t["useful_flops_ratio"], 3),
            roofline_fraction=round(t["roofline_fraction"], 4)))
    n_ok = len([r for r in records if r.get("status") == "ok"])
    n_skip = len([r for r in records if r.get("status") == "skipped"])
    n_fail = len([r for r in records if r.get("status") == "failed"])
    rows.append(row("roofline/cells_ok", 0.0, n_ok, skipped=n_skip,
                    failed=n_fail))
    save("roofline", rows)
    return rows


if __name__ == "__main__":
    print(markdown_table(load_records()))
