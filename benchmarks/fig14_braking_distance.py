"""Figure 14: braking distance + total-braking-time breakdown per scheduler.

Setup (§8.4): after 1 km of route, the forward camera sees an obstacle 250 m
away at 60 km/h.  Total braking time = T_wait + T_schedule + T_compute +
T_data (1 ms CAN) + T_mech (19 ms); the braking distance is Eq. (1)
evaluated at rho = total response time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import RATE_SCALE, platform, queues_for, row, save, \
    trained_flexai

T_DATA = 0.001   # CAN bus (Yu et al. MICRO'20)
T_MECH = 0.019   # mechanical actuation
V = 60.0 / 3.6   # m/s


def _braking(sched_fn, queue, brake_task):
    """Run the queue, then schedule the braking detection task and measure
    its end-to-end response."""
    from repro.core.criteria import rss_safe_distance
    p = platform()
    summ = sched_fn(p, queue)
    t_sched = summ["schedule_time_per_task_s"]
    rec_before = len(p.records)
    summ2 = sched_fn(p, [brake_task])
    rec = p.records[rec_before]
    # undo capacity subsampling for absolute times
    t_wait = rec.wait * RATE_SCALE
    t_compute = rec.exec_time * RATE_SCALE
    total = t_wait + t_sched + t_compute + T_DATA + T_MECH
    dist = rss_safe_distance(V, V, total)
    return {
        "t_wait_ms": t_wait * 1e3,
        "t_schedule_ms": t_sched * 1e3,
        "t_compute_ms": t_compute * 1e3,
        "t_data_ms": T_DATA * 1e3,
        "t_mech_ms": T_MECH * 1e3,
        "total_s": total,
        "braking_distance_m": dist,
    }


def run(quick: bool = True) -> list:
    from repro.core.criteria import camera_safety_time
    from repro.core.schedulers import get_scheduler
    from repro.core.tasks import Task, TaskKind
    queue = queues_for("UB", 1, km=0.08 if quick else 0.15, seed0=90)[0]
    t_end = queue[-1].arrival_time
    brake_task = Task(uid=10**9, kind=TaskKind.YOLO, camera_group="FC",
                      camera_id=0, arrival_time=t_end,
                      safety_time=camera_safety_time("FC", "UB", "GS"))
    agent = trained_flexai("UB", quick=quick)
    rows = []
    dists = {}
    scheds = {n: get_scheduler(n).schedule for n in
              ("minmin", "ata", "ga", "sa", "worst")}
    scheds["flexai"] = agent.schedule
    for name, fn in scheds.items():
        res = _braking(fn, queue, brake_task)
        dists[name] = res["braking_distance_m"]
        rows.append(row(f"fig14/{name}/braking_distance_m", 0.0,
                        round(res["braking_distance_m"], 2),
                        breakdown={k: round(v, 3) for k, v in res.items()
                                   if k.endswith("_ms")}))
    worst = max(dists.values())
    best = dists["flexai"]
    rows.append(row("fig14/flexai_reduction_vs_worst", 0.0,
                    f"{(1 - best / worst) * 100:.0f}%",
                    paper="up to 96% reduction"))
    rows.append(row("fig14/flexai_below_250m_safe", 0.0,
                    bool(best < 250.0)))
    save("fig14_braking_distance", rows)
    return rows
