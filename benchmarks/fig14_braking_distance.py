"""Figure 14: braking distance + total-braking-time breakdown per scheduler.

Setup (§8.4): after 1 km of route, the forward camera sees an obstacle 250 m
away at 60 km/h.  Total braking time = T_wait + T_schedule + T_compute +
T_data (1 ms CAN) + T_mech (19 ms); the braking distance is Eq. (1)
evaluated at rho = total response time.

Every family runs on the device-resident path: the route is one scan
dispatch, then the braking detection task is scheduled *from the final
``PlatformState``* (the ``state0`` resume seam of the scan/metaheuristic
engines) so the brake decision sees the route's accumulated backlog
exactly as the per-task loop did.  T_schedule is the warm per-task
dispatch rate — compile time is excluded by warming both shapes first.

Beyond the single-event Fig-14 bars, each family also reports a p50/p99
end-to-end latency distribution over many brake events (one per route
seed, routes padded to one static shape so every event reuses a single
compiled dispatch): the paper's safety claim rests on the *tail* of the
response time, not its warm-path mean — ROADMAP's braking-distance-
fidelity item.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import RATE_SCALE, platform, queues_for, row, save, \
    trained_flexai

T_DATA = 0.001   # CAN bus (Yu et al. MICRO'20)
T_MECH = 0.019   # mechanical actuation
V = 60.0 / 3.6   # m/s


def _braking(run_fn, ta_queue, ta_brake):
    """``run_fn(tasks, state0) -> (final_state, records)``; runs the route,
    then the braking task from the route's final state, and measures the
    brake record's end-to-end response."""
    import jax

    from repro.core.criteria import rss_safe_distance
    # warm both shapes so T_schedule reads steady-state dispatch rate
    final, _ = run_fn(ta_queue, None)
    jax.block_until_ready(run_fn(ta_brake, final))
    t0 = time.perf_counter()
    final, _ = jax.block_until_ready(run_fn(ta_queue, None))
    t_sched = (time.perf_counter() - t0) / max(ta_queue.num_tasks, 1)
    _, recs = jax.block_until_ready(run_fn(ta_brake, final))
    # undo capacity subsampling for absolute times
    t_wait = float(recs.wait[0]) * RATE_SCALE
    t_compute = float(recs.exec_time[0]) * RATE_SCALE
    total = t_wait + t_sched + t_compute + T_DATA + T_MECH
    dist = rss_safe_distance(V, V, total)
    return {
        "t_wait_ms": t_wait * 1e3,
        "t_schedule_ms": t_sched * 1e3,
        "t_compute_ms": t_compute * 1e3,
        "t_data_ms": T_DATA * 1e3,
        "t_mech_ms": T_MECH * 1e3,
        "total_s": total,
        "braking_distance_m": dist,
    }


def _latency_distribution(run_fn, routes, brakes):
    """End-to-end brake latency (seconds) over one brake event per route:
    run each route to its final ``PlatformState``, schedule that route's
    brake task from it, and time the warm brake dispatch itself.  Routes
    share one padded shape, so every event after the first reuses the
    compiled executables."""
    import jax
    # warm both shapes
    final, _ = run_fn(routes[0], None)
    jax.block_until_ready(run_fn(brakes[0], final))
    totals = []
    for ta_route, ta_brake in zip(routes, brakes):
        final, _ = jax.block_until_ready(run_fn(ta_route, None))
        t0 = time.perf_counter()
        _, recs = jax.block_until_ready(run_fn(ta_brake, final))
        t_sched = time.perf_counter() - t0
        t_wait = float(recs.wait[0]) * RATE_SCALE
        t_compute = float(recs.exec_time[0]) * RATE_SCALE
        totals.append(t_wait + t_sched + t_compute + T_DATA + T_MECH)
    return np.asarray(totals)


def run(quick: bool = True) -> list:
    import jax

    from repro.core.criteria import camera_safety_time, rss_safe_distance
    from repro.core.flexai.engine import make_schedule_fn
    from repro.core.platform_jax import spec_from_platform
    from repro.core.schedulers import (get_scan_scheduler,
                                       make_metaheuristic_fn)
    from repro.core.tasks import (Task, TaskKind, pad_task_arrays,
                                  tasks_to_arrays)
    queue = queues_for("UB", 1, km=0.08 if quick else 0.15, seed0=90)[0]
    t_end = queue[-1].arrival_time
    brake_task = Task(uid=10**9, kind=TaskKind.YOLO, camera_group="FC",
                      camera_id=0, arrival_time=t_end,
                      safety_time=camera_safety_time("FC", "UB", "GS"))
    ta_queue = tasks_to_arrays(queue)
    ta_brake = tasks_to_arrays([brake_task])
    agent = trained_flexai("UB", quick=quick)
    spec = spec_from_platform(platform())

    # many-event set: one brake per route seed, padded to a shared shape
    n_events = 8 if quick else 24
    event_queues = queues_for("UB", n_events, km=0.08 if quick else 0.15,
                              seed0=400)
    t_max = max(len(q) for q in event_queues)
    event_routes = [pad_task_arrays(tasks_to_arrays(q), t_max)
                    for q in event_queues]
    event_brakes = [tasks_to_arrays([Task(
        uid=10**9 + i, kind=TaskKind.YOLO, camera_group="FC", camera_id=0,
        arrival_time=q[-1].arrival_time,
        safety_time=camera_safety_time("FC", "UB", "GS"))])
        for i, q in enumerate(event_queues)]

    scheds = {}
    for name in ("minmin", "ata", "worst"):
        fn = get_scan_scheduler(name)
        scheds[name] = lambda ta, st, fn=fn: fn(spec, ta, st)
    key = jax.random.PRNGKey(0)
    for name in ("ga", "sa"):
        fn = make_metaheuristic_fn(spec, name)
        scheds[name] = lambda ta, st, fn=fn: fn(key, ta, st)
    flex_fn = make_schedule_fn(spec, agent.cfg.backlog_scale)
    params = agent.learner.eval_p
    scheds["flexai"] = lambda ta, st: flex_fn(params, ta, st)

    rows = []
    dists = {}
    for name, fn in scheds.items():
        res = _braking(fn, ta_queue, ta_brake)
        dists[name] = res["braking_distance_m"]
        rows.append(row(f"fig14/{name}/braking_distance_m", 0.0,
                        round(res["braking_distance_m"], 2),
                        breakdown={k: round(v, 3) for k, v in res.items()
                                   if k.endswith("_ms")}))
        lat = _latency_distribution(fn, event_routes, event_brakes)
        p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
        rows.append(row(
            f"fig14/{name}/latency_p50_ms", 0.0, round(p50 * 1e3, 3),
            p99_ms=round(p99 * 1e3, 3), events=len(lat),
            braking_distance_p99_m=round(rss_safe_distance(V, V, p99), 2)))
    worst = max(dists.values())
    best = dists["flexai"]
    rows.append(row("fig14/flexai_reduction_vs_worst", 0.0,
                    f"{(1 - best / worst) * 100:.0f}%",
                    paper="up to 96% reduction"))
    rows.append(row("fig14/flexai_below_250m_safe", 0.0,
                    bool(best < 250.0)))
    save("fig14_braking_distance", rows)
    return rows
