"""Open-loop serving-load benchmark: continuous batching vs drain waves.

Drives ``repro.serve.qos.QoSPlacementEngine`` with seeded open-loop
arrival streams from ``repro.serve.loadgen`` (Poisson over the scenario
families) at offered loads 0.5 / 1.0 / 2.0, and reports what production
provisioning actually looks at: p50/p99/p99.9 response latency
(finish - arrival), goodput (deadline-met completions per virtual
second), and shed rate — per load, for drain-wave EDF vs
continuous-batching EDF at identical devices and config.

Also runs the sharded-wave parity trace: the same workload served with
the wave's lane axis shard_mapped over a ``("routes",)`` mesh must
reproduce the single-device serving digest bit-exactly (placements,
finish times, wave log, clock) in both drain and continuous modes.

Everything rides the deterministic virtual clock (measured service
times are reported as a calibration info arm, never gated), so CI can
gate hard: continuous goodput strictly above drain at load 2.0, no p99
regression at load 0.5, parity flag true.

Emits the standard benchmark rows *and* ``BENCH_load.json`` (repo root).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import RATE_SCALE, host_tuning, row, save

LOADS = (0.5, 1.0, 2.0)


def _base_route():
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.tasks import tasks_to_arrays
    return tasks_to_arrays(build_task_queue(EnvironmentParams(
        route_km=0.008, rate_scale=RATE_SCALE, seed=321,
        max_times_turn=1, max_times_reverse=1,
        max_duration_turn=2.0, max_duration_reverse=3.0)))


def _engine(plat, agent, *, continuous: bool, slots: int, mesh=None,
            measured: bool = False):
    from repro.serve.qos import QoSConfig, QoSPlacementEngine
    cfg = QoSConfig(policy="edf", slots=slots, chunk=8, min_bucket=16,
                    continuous=continuous, measured_svc=measured)
    return QoSPlacementEngine(plat, agent.learner.eval_p, cfg,
                              backlog_scale=agent.cfg.backlog_scale,
                              mesh=mesh)


def _metrics(eng) -> dict:
    s = eng.stats()
    lat = np.asarray([r.finish - r.arrival for r in eng.completed],
                     np.float64)
    met = sum(1 for r in eng.completed if r.slack >= 0.0)
    span = max(s["virtual_time_s"], 1e-12)
    pct = (lambda q: float(np.percentile(lat, q)) if lat.size else 0.0)
    return {
        "p50_latency_s": pct(50), "p99_latency_s": pct(99),
        "p999_latency_s": pct(99.9),
        "goodput_rps": met / span,
        "shed_rate": (s["shed"] / s["resolved"]) if s["resolved"] else 0.0,
        "completed": s["completed"], "shed": s["shed"],
        "refills": s["refills"], "waves": s["waves"],
        "miss_rate": s["miss_rate"], "virtual_time_s": s["virtual_time_s"],
    }


def _serve(trace, plat, agent, *, continuous: bool, slots: int, mesh=None):
    from repro.serve.loadgen import submit_trace
    eng = _engine(plat, agent, continuous=continuous, slots=slots,
                  mesh=mesh)
    submit_trace(eng, trace)
    eng.run_until_done()
    return eng


def run(quick: bool = True) -> list:
    import jax

    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.hmai import HMAIPlatform
    from repro.serve.durability import digests_equal, serving_digest
    from repro.serve.loadgen import LoadGenConfig, generate

    n_req = 18 if quick else 48
    slots = 4
    plat = HMAIPlatform(capacity_scale=RATE_SCALE)
    agent = FlexAIAgent(plat, FlexAIConfig(seed=0))
    base = _base_route()
    probe = _engine(plat, agent, continuous=False, slots=slots)
    mean_service = probe._bucket(base.num_tasks) * probe.svc

    rows, result = [], {"loads": {}, "n_requests": n_req, "slots": slots,
                        "rate_scale": RATE_SCALE,
                        "mean_service_s": mean_service}
    for load in LOADS:
        trace = generate(base, plat.n, LoadGenConfig(
            process="poisson", n_requests=n_req, offered_load=load,
            seed=11), mean_service / slots)
        arms = {}
        for name, continuous in (("drain", False), ("continuous", True)):
            m = _metrics(_serve(trace, plat, agent, continuous=continuous,
                                slots=slots))
            arms[name] = m
            for k in ("p50_latency_s", "p99_latency_s", "p999_latency_s",
                      "goodput_rps", "shed_rate"):
                rows.append(row(f"serve_load/load{load}/{name}/{k}", 0.0,
                                round(m[k], 5)))
        result["loads"][str(load)] = arms

    # bursty info arm (Gamma arrivals at the top load, both modes)
    btrace = generate(base, plat.n, LoadGenConfig(
        process="gamma", burstiness=4.0, n_requests=n_req,
        offered_load=max(LOADS), seed=12), mean_service / slots)
    result["bursty"] = {
        name: _metrics(_serve(btrace, plat, agent, continuous=c,
                              slots=slots))
        for name, c in (("drain", False), ("continuous", True))}

    # sharded-wave parity: same trace, lane axis over the routes mesh
    # (slots=3 exercises the pad-to-mesh-and-trim path on >1 devices)
    from repro.compat import make_mesh
    mesh = make_mesh((len(jax.devices()),), ("routes",))
    ptrace = generate(base, plat.n, LoadGenConfig(
        process="poisson", n_requests=min(n_req, 12), offered_load=1.5,
        seed=13), mean_service / 3)
    parity = {}
    for name, continuous in (("drain", False), ("continuous", True)):
        single = _serve(ptrace, plat, agent, continuous=continuous,
                        slots=3)
        sharded = _serve(ptrace, plat, agent, continuous=continuous,
                         slots=3, mesh=mesh)
        parity[name] = digests_equal(serving_digest(single),
                                     serving_digest(sharded))
    result["sharded_parity_devices"] = len(jax.devices())
    result["sharded_parity"] = {k: bool(v) for k, v in parity.items()}

    # measured-service-time calibration (info only: wall-clock EMA of a
    # CPU host's jit dispatch — never gated, the virtual clock is)
    meng = _engine(plat, agent, continuous=False, slots=slots,
                   measured=True)
    for r in generate(base, plat.n, LoadGenConfig(
            n_requests=6, offered_load=1.0, seed=14), mean_service / slots):
        meng.submit(r.tasks, arrival=r.arrival, deadline=r.arrival + 1e9)
    meng.run_until_done()
    result["measured_svc"] = {
        "virtual_svc_per_task_s": probe.svc,
        "ema_per_slot_s": {f"{b}x{s}": v for (b, s), v
                           in sorted(meng._svc_measured.items())},
        "wall_time_s": meng.now}

    top, low = str(max(LOADS)), str(min(LOADS))
    by = result["loads"]
    gate = {
        "continuous_goodput_wins_overload": (
            by[top]["continuous"]["goodput_rps"]
            > by[top]["drain"]["goodput_rps"]),
        "no_p99_regression_underload": (
            by[low]["continuous"]["p99_latency_s"]
            <= by[low]["drain"]["p99_latency_s"] * 1.05 + 1e-9),
        "sharded_parity": all(parity.values()),
    }
    result["gate"] = gate
    for k, v in gate.items():
        rows.append(row(f"serve_load/{k}", 0.0, v))
    save("serve_load", rows)
    result["host_tuning"] = host_tuning()
    with open(os.path.join(os.getcwd(), "BENCH_load.json"), "w") as f:
        json.dump(result, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(quick=os.environ.get("BENCH_FULL", "") != "1"):
        print(r["name"], r["derived"])
