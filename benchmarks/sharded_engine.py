"""Sharded FlexAI engine: scheduled-tasks/sec vs forced host device count.

The scan engine is embarrassingly parallel over routes, so the shard_map
variant should scale until the per-device lane width stops covering the
scan-step overhead.  Each measurement runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax imports.

Every child also replays the same batch through the plain single-device
vmapped scan and checks fp32 parity (identical placements, metrics within
fp32 tolerance) — the multi-device engine must be a pure re-layout.

Emits the standard benchmark rows *and* ``BENCH_sharded_engine.json``
(repo root) with the 1->4 device scaling factor.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEVICE_COUNTS = (1, 2, 4)
RESULT_TAG = "SHARDED_RESULT "


def _child_main(args) -> None:
    """Runs inside a subprocess with the forced device count already set."""
    import time

    import jax
    import numpy as np

    from benchmarks.common import RATE_SCALE
    from repro.compat import make_mesh
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.flexai import (FlexAIAgent, FlexAIConfig,
                                   make_schedule_fn,
                                   make_sharded_schedule_fn)
    from repro.core.hmai import HMAIPlatform
    from repro.core.platform_jax import spec_from_platform, summarize
    from repro.core.tasks import (TaskArrays, pad_route_batch,
                                  pad_task_arrays, stack_task_arrays,
                                  tasks_to_arrays)

    n_dev = len(jax.devices())
    assert n_dev == args.devices, (n_dev, args.devices)

    # a few unique routes, tiled out to the lane count (same math, cheap
    # host-side queue generation)
    uniq = []
    for s in range(args.unique_routes):
        q = build_task_queue(EnvironmentParams(
            route_km=0.05, rate_scale=RATE_SCALE, seed=300 + s,
            max_times_turn=2, max_times_reverse=1,
            max_duration_turn=4.0, max_duration_reverse=6.0))
        ta = pad_task_arrays(tasks_to_arrays(q), max(len(q), args.tasks))
        uniq.append(TaskArrays(*[np.asarray(f)[: args.tasks] for f in ta]))
    routes = [uniq[i % len(uniq)] for i in range(args.lanes)]
    batch = pad_route_batch(stack_task_arrays(routes), n_dev)

    plat = HMAIPlatform(capacity_scale=RATE_SCALE)
    spec = spec_from_platform(plat)
    params = FlexAIAgent(plat, FlexAIConfig(seed=13)).learner.eval_p

    def best_of(fn, iters):
        """Min over iters: the shared CI host is noisy and best-of is the
        standard way to read the machine's actual capability."""
        result = fn()  # warmup / compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            result = fn()
            best = min(best, time.perf_counter() - t0)
        return result, best

    mesh = make_mesh((n_dev,), ("routes",))
    sharded = make_sharded_schedule_fn(spec, mesh)
    out, t_sharded = best_of(
        lambda: jax.block_until_ready(sharded(params, batch)), args.iters)
    n_tasks = int(np.asarray(batch.valid).sum())
    tps = n_tasks / t_sharded

    # fp32 parity vs the single-device scan path (plain vmapped jit runs
    # on device 0 regardless of the forced device count)
    plain = make_schedule_fn(spec, batched=True)
    ref, t_plain = best_of(
        lambda: jax.block_until_ready(plain(params, batch)), args.iters)
    f_sh, r_sh = jax.device_get(out)
    f_pl, r_pl = jax.device_get(ref)
    placements_equal = bool(
        np.array_equal(np.asarray(r_sh.action), np.asarray(r_pl.action)))
    metric_diff = 0.0
    for lane in range(args.lanes):
        s_sh = summarize(spec, *jax.tree_util.tree_map(
            lambda a, l=lane: a[l], (f_sh, r_sh)))
        s_pl = summarize(spec, *jax.tree_util.tree_map(
            lambda a, l=lane: a[l], (f_pl, r_pl)))
        for k in ("stm_rate", "gvalue", "makespan_s", "total_energy_j"):
            denom = max(abs(s_pl[k]), 1e-9)
            metric_diff = max(metric_diff,
                              abs(s_sh[k] - s_pl[k]) / denom)
    assert metric_diff < 1e-4, f"sharded/plain divergence {metric_diff}"
    assert placements_equal, "sharded placements diverge from the " \
        "single-device scan path"

    print(RESULT_TAG + json.dumps({
        "devices": n_dev,
        "lanes": int(batch.arrival.shape[0]),
        "tasks_per_lane": args.tasks,
        "scheduled_tasks_per_s": round(tps, 1),
        "plain_single_device_tasks_per_s": round(n_tasks / t_plain, 1),
        "placements_equal": placements_equal,
        "metric_rel_diff_max": metric_diff,
    }))


def _spawn(devices: int, lanes: int, tasks: int, iters: int,
           unique_routes: int) -> dict:
    from benchmarks.common import spawn_forced_device_child
    return spawn_forced_device_child(
        "sharded_engine", devices,
        ["--lanes", lanes, "--tasks", tasks, "--iters", iters,
         "--unique-routes", unique_routes],
        RESULT_TAG)


def run(quick: bool = True) -> list:
    from benchmarks.common import host_tuning, row, save

    # wide lanes: per-step compute must dominate the scan-step overhead for
    # route sharding to pay (at width <=32 the engine is overhead-bound and
    # extra devices only add contention — measured on the 2-core CI host)
    lanes = 256 if quick else 512
    tasks = 256 if quick else 512
    iters = 5
    results = {d: _spawn(d, lanes, tasks, iters, unique_routes=8)
               for d in DEVICE_COUNTS}
    tps = {d: r["scheduled_tasks_per_s"] for d, r in results.items()}
    scaling = round(tps[4] / tps[1], 2)

    summary = {
        "lanes": lanes,
        "tasks_per_lane": tasks,
        "by_device_count": results,
        "scaling_4dev_over_1dev": scaling,
        "parity_fp32_ok": all(r["metric_rel_diff_max"] < 1e-4
                              for r in results.values()),
        "placements_equal": all(r["placements_equal"]
                                for r in results.values()),
    }
    summary["host_tuning"] = host_tuning(devices=4)
    with open(os.path.join(os.getcwd(), "BENCH_sharded_engine.json"),
              "w") as f:
        json.dump(summary, f, indent=1)

    rows = [
        row(f"sharded_engine/{d}dev", 1e6 / tps[d],
            f"{tps[d]:.0f} tasks/s") for d in DEVICE_COUNTS
    ]
    rows.append(row("sharded_engine/scaling_4dev_over_1dev", 0.0, scaling))
    rows.append(row("sharded_engine/parity_fp32_ok", 0.0,
                    summary["parity_fp32_ok"]))
    save("sharded_engine", rows)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--lanes", type=int, default=256)
    ap.add_argument("--tasks", type=int, default=256)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--unique-routes", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        _child_main(args)
        return 0
    for r in run(quick=not args.full):
        print(r)
    return 0


if __name__ == "__main__":
    sys.exit(main())
