"""Figure 2: homogeneous vs heterogeneous platforms — energy consumption and
resource-utilization rate per urban scenario.

For each scenario the platform must sustain the Table-5 FPS mix; we compute
(a) the accelerator counts each homogeneous platform needs, (b) energy to
process one second of the workload, (c) utilization = busy-time / capacity,
reproducing the paper's conclusion: the (4,4,3) heterogeneous HMAI has the
lowest energy and highest utilization across all scenarios.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, save

REQ = {  # urban FPS requirements per scenario (Table 5)
    "GS": {"yolo": 435.0, "ssd": 435.0, "goturn": 840.0},
    "TL": {"yolo": 475.0, "ssd": 475.0, "goturn": 920.0},
    "RE": {"yolo": 370.0, "ssd": 370.0, "goturn": 740.0},
}


def _greedy_allocation(specs, req):
    """Assign per-model FPS load across accelerators maximizing utilization:
    waterfill each model class onto accelerators proportionally to their
    rate, honoring 1.0-utilization capacity."""
    n = len(specs)
    util = np.zeros(n)
    energy = 0.0
    feasible = True
    for kind, need in sorted(req.items(), key=lambda kv: -kv[1]):
        remaining = need
        # fastest accelerators first
        order = sorted(range(n), key=lambda i: -specs[i].fps[kind])
        for i in order:
            if remaining <= 0:
                break
            headroom = max(0.0, 1.0 - util[i])
            take = min(remaining, headroom * specs[i].fps[kind])
            util[i] += take / specs[i].fps[kind]
            energy += specs[i].power_w * (take / specs[i].fps[kind])
            remaining -= take
        if remaining > 1e-9:
            feasible = False
    return util, energy, feasible


def run(quick: bool = True) -> list:
    from repro.core.hmai import (ACCELERATOR_SPECS, HMAI_CONFIG,
                                 HOMOGENEOUS_CONFIGS)
    rows = []
    platforms = dict(HOMOGENEOUS_CONFIGS)
    platforms["HMAI(4,4,3)"] = HMAI_CONFIG
    summary = {}
    for pname, config in platforms.items():
        specs = []
        for name, count in config:
            specs.extend([ACCELERATOR_SPECS[name]] * count)
        utils, energies = [], []
        for sc, req in REQ.items():
            util, energy, feasible = _greedy_allocation(specs, req)
            mean_util = float(np.mean(util))
            utils.append(mean_util)
            energies.append(energy)
            rows.append(row(f"fig2/{pname}/{sc}/utilization", 0.0,
                            round(mean_util, 4), feasible=feasible))
            rows.append(row(f"fig2/{pname}/{sc}/energy_w", 0.0,
                            round(energy, 2)))
        summary[pname] = (float(np.exp(np.mean(np.log(np.maximum(
            utils, 1e-9))))), float(np.mean(energies)))
    best_util = max(summary, key=lambda p: summary[p][0])
    best_energy = min(summary, key=lambda p: summary[p][1])
    rows.append(row("fig2/best_utilization_platform", 0.0, best_util,
                    paper="HMAI(4,4,3)"))
    rows.append(row("fig2/best_energy_platform", 0.0, best_energy,
                    paper="HMAI(4,4,3)"))
    save("fig2_platform_comparison", rows)
    return rows
