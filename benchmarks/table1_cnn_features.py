"""Table 1: features of the perception CNNs (MACs, weights+neurons, layers)
— derived from the model definitions vs the paper's published values."""
from __future__ import annotations

from benchmarks.common import row, save, timer

PAPER = {
    "yolo": {"macs": 16e9, "weights_and_neurons": 150e6, "layers": 101},
    "ssd": {"macs": 26e9, "weights_and_neurons": 697.76e6, "layers": 53},
    "goturn": {"macs": 11e9, "weights_and_neurons": 13.95e6, "layers": 11},
}


def run(quick: bool = True) -> list:
    from repro.models.perception.nets import perception_stats
    stats, dt = timer(perception_stats, iters=1)
    rows = []
    for name, st in stats.items():
        p = PAPER[name]
        rows.append(row(
            f"table1/{name}/gmacs", dt * 1e6,
            round(st["macs"] / 1e9, 2),
            paper=p["macs"] / 1e9,
            ratio=round(st["macs"] / p["macs"], 2)))
        rows.append(row(
            f"table1/{name}/layers", dt * 1e6, st["layers"],
            paper=p["layers"]))
    save("table1_cnn_features", rows)
    return rows
