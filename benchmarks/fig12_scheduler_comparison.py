"""Figure 12: FlexAI vs baselines — time, R_Balance, MS, energy across
areas (UB/UHW/HW) and task queues.

Every scheduler family runs through the device-resident substrate at
multi-route scale: the area's queues are stacked into one [R, T] batch and
each family (FlexAI scan, Min-Min/ATA/worst scan, device GA/SA) schedules
the whole batch in one vmapped dispatch.  The NumPy loop schedulers remain
available as oracles (``tests/test_scan_engine.py`` /
``tests/test_metaheuristics.py``) but no longer sit on the benchmark path.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import platform, queues_for, row, save, trained_flexai

HEURISTICS = ("minmin", "ata", "worst")
METAHEURISTICS = ("ga", "sa")
BASELINES = HEURISTICS + METAHEURISTICS


def _lane_summaries(spec, out, n_lanes: int, dt: float,
                    lane_tasks: list) -> list:
    """Per-route summaries from one batched dispatch; the dispatch wall
    time is attributed per task across the batch."""
    import jax

    from repro.core.platform_jax import summarize
    finals, recs = out
    total = max(sum(lane_tasks), 1)
    summs = []
    for i in range(n_lanes):
        s = summarize(spec,
                      jax.tree_util.tree_map(lambda a, i=i: a[i], finals),
                      jax.tree_util.tree_map(lambda a, i=i: a[i], recs))
        s["schedule_time_s"] = dt * lane_tasks[i] / total
        s["schedule_time_per_task_s"] = dt / total
        summs.append(s)
    return summs


def _timed(fn):
    """Warm (compile) then measure one dispatch."""
    import jax
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return out, time.perf_counter() - t0


def run(quick: bool = True) -> list:
    import jax

    from repro.core.flexai.engine import make_schedule_fn
    from repro.core.platform_jax import spec_from_platform
    from repro.core.schedulers import (get_scan_scheduler,
                                       make_metaheuristic_fn)
    from repro.core.tasks import stack_task_arrays, tasks_to_arrays

    areas = ["UB"] if quick else ["UB", "UHW", "HW"]
    n_queues = 2 if quick else 5
    rows = []
    for area in areas:
        agent = trained_flexai(area, quick=quick)
        queues = queues_for(area, n_queues, km=0.1, seed0=50)
        arrays = [tasks_to_arrays(q) for q in queues]
        lane_tasks = [ta.num_tasks for ta in arrays]
        batch = stack_task_arrays(arrays)
        spec = spec_from_platform(platform())

        results = {}
        for name in HEURISTICS:
            fn = get_scan_scheduler(name, batched=True)
            out, dt = _timed(lambda fn=fn: fn(spec, batch))
            results[name] = _lane_summaries(spec, out, n_queues, dt,
                                            lane_tasks)
        keys = jax.random.split(jax.random.PRNGKey(0), n_queues)
        for name in METAHEURISTICS:
            fn = make_metaheuristic_fn(spec, name, batched=True)
            out, dt = _timed(lambda fn=fn: fn(keys, batch))
            results[name] = _lane_summaries(spec, out, n_queues, dt,
                                            lane_tasks)
        fn = make_schedule_fn(spec, agent.cfg.backlog_scale, batched=True)
        params = agent.learner.eval_p
        out, dt = _timed(lambda: fn(params, batch))
        results["flexai"] = _lane_summaries(spec, out, n_queues, dt,
                                            lane_tasks)

        for name, rs in results.items():
            gm = lambda k: float(np.exp(np.mean(np.log(np.maximum(
                [r[k] for r in rs], 1e-12)))))
            total_time = gm("makespan_s")
            rows.append(row(f"fig12a/{area}/{name}/time_s",
                            np.mean([r["schedule_time_per_task_s"]
                                     for r in rs]) * 1e6,
                            round(total_time, 2)))
            rows.append(row(f"fig12b/{area}/{name}/r_balance", 0.0,
                            round(float(np.mean([r["r_balance"]
                                                 for r in rs])), 4)))
            rows.append(row(f"fig12c/{area}/{name}/total_ms", 0.0,
                            round(float(np.mean([r["total_ms"]
                                                 for r in rs])), 1)))
            rows.append(row(f"fig12d/{area}/{name}/energy_j", 0.0,
                            round(gm("total_energy_j"), 1)))
        # headline orderings
        rb = {n: np.mean([r["r_balance"] for r in rs])
              for n, rs in results.items()}
        rows.append(row(f"fig12/{area}/flexai_best_rbalance", 0.0,
                        bool(max(rb, key=rb.get) == "flexai"), values={
                            k: round(v, 3) for k, v in rb.items()}))
    save("fig12_scheduler_comparison", rows)
    return rows
