"""Figure 12: FlexAI vs baselines — time, R_Balance, MS, energy across
areas (UB/UHW/HW) and task queues."""
from __future__ import annotations

import numpy as np

from benchmarks.common import platform, queues_for, row, save, trained_flexai

BASELINES = ("minmin", "ata", "ga", "sa", "worst")


def run(quick: bool = True) -> list:
    from repro.core.schedulers import get_scheduler
    areas = ["UB"] if quick else ["UB", "UHW", "HW"]
    n_queues = 2 if quick else 5
    rows = []
    for area in areas:
        agent = trained_flexai(area, quick=quick)
        queues = queues_for(area, n_queues, km=0.1, seed0=50)
        results = {}
        for name in BASELINES:
            per_q = []
            for q in queues:
                p = platform()
                per_q.append(get_scheduler(name).schedule(p, q))
            results[name] = per_q
        per_q = []
        for q in queues:
            p = platform()
            per_q.append(agent.schedule(p, q))
        results["flexai"] = per_q

        for name, rs in results.items():
            gm = lambda k: float(np.exp(np.mean(np.log(np.maximum(
                [r[k] for r in rs], 1e-12)))))
            total_time = gm("makespan_s")
            rows.append(row(f"fig12a/{area}/{name}/time_s",
                            np.mean([r["schedule_time_per_task_s"]
                                     for r in rs]) * 1e6,
                            round(total_time, 2)))
            rows.append(row(f"fig12b/{area}/{name}/r_balance", 0.0,
                            round(float(np.mean([r["r_balance"]
                                                 for r in rs])), 4)))
            rows.append(row(f"fig12c/{area}/{name}/total_ms", 0.0,
                            round(float(np.mean([r["total_ms"]
                                                 for r in rs])), 1)))
            rows.append(row(f"fig12d/{area}/{name}/energy_j", 0.0,
                            round(gm("total_energy_j"), 1)))
        # headline orderings
        rb = {n: np.mean([r["r_balance"] for r in rs])
              for n, rs in results.items()}
        rows.append(row(f"fig12/{area}/flexai_best_rbalance", 0.0,
                        bool(max(rb, key=rb.get) == "flexai"), values={
                            k: round(v, 3) for k, v in rb.items()}))
    save("fig12_scheduler_comparison", rows)
    return rows
