"""Scenario-fleet robustness benchmark (ISSUE 8): degradation training
under fire.

One base Table-5 route expands into the domain-randomized scenario fleet
(``core.scenarios``: clean / sensor_dropout / weather / burst / fault) and
two FlexAI arms face it:

* **clean-trained** — the benchmark's standard well-trained agent, blind
  to faults: it places with no health signal and its placements are
  *replayed* under each fault trace (``core.faults.replay_actions``), so
  a dead-core pick pays the ``HEALTH_FLOOR`` penalty.  This is exactly
  the deployment cost of ignoring degradation.
* **degradation-trained** — the same weights fleet-fine-tuned with the
  degradation trainer (``train_episode(tasks, health=...)`` over
  ``scenario_lane_batches``: masked greedy arm, fault traces in the
  scan) and *deployed health-aware* (the masked-argmax dispatch the
  in-scan fault model provides).

Candidate selection is conservative: the clean weights are always a
candidate, and the winner must stay within 2% STM of the clean baseline
on clean routes — so fine-tuning can only ever improve the faulted arm,
never trade away clean-route safety.  The honest caveat: the measured
gap bundles degradation *training* with the health-*signal* advantage at
dispatch time; both are part of the paper's variability story (a
platform that knows its own health routes around it), and the ``note``
field in the JSON says so.

Emits the standard benchmark rows *and* ``BENCH_scenarios.json`` with the
``gate`` block ``scripts/ci.sh`` fails on:

* degradation-trained deadline-miss strictly below clean-trained on the
  faulted routes;
* degradation-trained STM within 2% of clean-trained on clean routes;

plus a per-family STM / deadline-miss breakdown of the chosen agent.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks.common import (host_tuning, platform, queues_for, row,
                               save, timer, trained_flexai)

SEED = 47
LANES = 4


def _lane_summaries(spec, finals, recs):
    import jax
    from repro.core.platform_jax import summarize
    k = int(np.asarray(recs.valid).shape[0])
    return [summarize(spec,
                      jax.tree_util.tree_map(lambda a, i=i: a[i], finals),
                      jax.tree_util.tree_map(lambda a, i=i: a[i], recs))
            for i in range(k)]


def _miss(summ: dict) -> float:
    return 1.0 - float(summ["stm_rate"])


def run(quick: bool = True) -> list:
    import jax
    import jax.numpy as jnp
    from repro.core.faults import replay_actions
    from repro.core.flexai import ScanFlexAI
    from repro.core.flexai.engine import make_schedule_fn
    from repro.core.platform_jax import spec_from_platform, summarize
    from repro.core.scenarios import (FAMILIES, scenario_batch,
                                      scenario_lane_batches)
    from repro.core.tasks import tasks_to_arrays

    plat = platform()
    spec = spec_from_platform(plat)
    base = tasks_to_arrays(queues_for(
        "UB", 1, km=0.06 if quick else 0.1, seed0=90)[0])
    n_per = 4 if quick else 8
    batch = scenario_batch(base, plat.n, seed=SEED, n_per_family=n_per)

    agent = trained_flexai("UB", quick=quick)
    clean_params = agent.learner.eval_p
    sched = make_schedule_fn(spec, agent.cfg.backlog_scale, batched=True)

    take = jax.tree_util.tree_map
    rf = batch.family_rows("fault")
    rc = batch.family_rows("clean")
    tasks_f = take(lambda a: a[rf], batch.tasks)
    health_f = jnp.asarray(np.asarray(batch.health)[rf])
    tasks_c = take(lambda a: a[rc], batch.tasks)

    # ---- clean-trained, fault-blind: place without the trace, replay
    # the placements under it --------------------------------------------
    _, recs_blind = sched(clean_params, tasks_f)
    acts = np.asarray(recs_blind.action)
    blind = []
    for i in range(len(rf)):
        fin, rec = replay_actions(spec, take(lambda a: a[i], tasks_f),
                                  acts[i], np.asarray(health_f)[i])
        blind.append(summarize(spec, fin, rec))
    miss_clean_faulted = float(np.mean([_miss(s) for s in blind]))
    stm_clean_clean = float(np.mean(
        [s["stm_rate"] for s in _lane_summaries(
            spec, *sched(clean_params, tasks_c))]))

    # ---- degradation fine-tuning over the scenario fleet ---------------
    ft_cfg = dataclasses.replace(
        agent.cfg, eps_start=0.25, eps_end=0.02, eps_decay_steps=2000,
        min_replay=128, seed=SEED)
    trainer = ScanFlexAI.from_agent(agent, plat, lanes=LANES, cfg=ft_cfg)
    epochs = 3 if quick else 6
    for _ in range(epochs):
        for tasks_l, health_l in scenario_lane_batches(batch, LANES):
            trainer.train_episode(tasks_l, health=health_l)

    # ---- candidate selection: clean weights always compete -------------
    def evaluate(params):
        fm = float(np.mean([_miss(s) for s in _lane_summaries(
            spec, *sched(params, tasks_f, health=health_f))]))
        cs = float(np.mean([s["stm_rate"] for s in _lane_summaries(
            spec, *sched(params, tasks_c))]))
        return fm, cs

    candidates = [("clean_weights", clean_params)]
    candidates += [(f"finetuned_lane{i}", trainer.eval_params(i))
                   for i in range(LANES)]
    scored = [(name, p, *evaluate(p)) for name, p in candidates]
    feasible = [s for s in scored if s[3] >= 0.98 * stm_clean_clean]
    name, best_params, miss_deg_faulted, stm_deg_clean = min(
        feasible, key=lambda s: s[2])
    candidate_table = [
        {"name": n, "faulted_miss": round(fm, 4), "clean_stm": round(cs, 4),
         "feasible": bool(cs >= 0.98 * stm_clean_clean)}
        for n, _, fm, cs in scored]

    # ---- per-family breakdown of the chosen agent ----------------------
    (finals, recs), dt = timer(
        lambda: jax.block_until_ready(sched(
            best_params, batch.tasks, health=batch.health)), iters=2)
    per_row = _lane_summaries(spec, finals, recs)
    families = {}
    for fam in FAMILIES:
        rows_f = batch.family_rows(fam)
        stm = float(np.mean([per_row[i]["stm_rate"] for i in rows_f]))
        families[fam] = {"stm_rate": round(stm, 4),
                         "deadline_miss_rate": round(1.0 - stm, 4)}

    gate = {
        "faulted_strictly_better": bool(
            miss_deg_faulted < miss_clean_faulted),
        "clean_within_2pct": bool(
            stm_deg_clean >= 0.98 * stm_clean_clean),
    }
    result = {
        "quick": quick, "seed": SEED, "n_per_family": n_per,
        "host": host_tuning(),
        "clean_trained": {
            "faulted_miss": round(miss_clean_faulted, 4),
            "clean_stm": round(stm_clean_clean, 4)},
        "degradation_trained": {
            "candidate": name,
            "faulted_miss": round(miss_deg_faulted, 4),
            "clean_stm": round(stm_deg_clean, 4),
            "clean_stm_ratio": round(
                stm_deg_clean / max(stm_clean_clean, 1e-12), 4)},
        "families": families,
        "candidates": candidate_table,
        "gate": gate,
        "note": ("the degradation-trained arm bundles fleet fine-tuning "
                 "under seeded fault traces WITH health-aware dispatch "
                 "(masked argmax); the clean-trained arm is fault-blind "
                 "(placements replayed under the same traces) — the gap "
                 "measures the full variability story, not fine-tuning "
                 "alone; candidate selection always includes the clean "
                 "weights, so the faulted arm can never regress below "
                 "health-aware dispatch of the baseline"),
    }
    with open(os.path.join(os.getcwd(), "BENCH_scenarios.json"), "w") as f:
        json.dump(result, f, indent=1)

    rows = [
        row("scenarios/clean_trained/faulted_miss", 0.0,
            result["clean_trained"]["faulted_miss"],
            paper="fault-blind placements replayed under the trace"),
        row("scenarios/degradation_trained/faulted_miss", 0.0,
            result["degradation_trained"]["faulted_miss"],
            candidate=name),
        row("scenarios/degradation_trained/clean_stm_ratio", 0.0,
            result["degradation_trained"]["clean_stm_ratio"],
            paper="must stay >= 0.98 (the 2% clean-route tolerance)"),
        row("scenarios/fleet_dispatch", dt * 1e6,
            f"{batch.num_scenarios}_scenarios_one_dispatch"),
        row("scenarios/gate", 0.0,
            gate["faulted_strictly_better"] and gate["clean_within_2pct"]),
    ]
    rows += [row(f"scenarios/family/{fam}/stm_rate", 0.0,
                 families[fam]["stm_rate"]) for fam in FAMILIES]
    save("scenarios", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=os.environ.get("BENCH_FULL", "") != "1"):
        print(r["name"], r["derived"])
