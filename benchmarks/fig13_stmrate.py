"""Figure 13: safety-time meet rate (STMRate) per task queue per scheduler.

The ``flexai_served`` variant re-measures FlexAI's STM rate *through the
serving boundary* (``repro.serve.qos``, EDF admission): the paper's "100%
within period" claim is only meaningful if the rate survives wave
admission, queueing and preemption — not just the bare scheduler loop.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import platform, queues_for, row, save, trained_flexai


def _served_stm(agent, queues, deadline_scale: float) -> dict:
    """Serve the fig-13 queues through the deadline-aware engine and read
    the STM rate off the completed placements (serving-boundary STM)."""
    from repro.serve.qos import QoSConfig, QoSPlacementEngine
    eng = QoSPlacementEngine(
        platform(), agent.learner.eval_p,
        QoSConfig(policy="edf", slots=2, deadline_scale=deadline_scale),
        backlog_scale=agent.cfg.backlog_scale)
    t = 0.0
    for q in queues:
        eng.submit(q, arrival=t)
        t += 0.05
    eng.run_until_done()
    return eng.stats()


def run(quick: bool = True) -> list:
    from repro.core.schedulers import get_scheduler
    n_queues = 2 if quick else 5
    queues = queues_for("UB", n_queues, km=0.1, seed0=70)
    agent = trained_flexai("UB", quick=quick)
    rows = []
    stm = {}
    for name in ("minmin", "ata", "ga", "sa", "worst"):
        vals = []
        for q in queues:
            p = platform()
            vals.append(get_scheduler(name).schedule(p, q)["stm_rate"])
        stm[name] = float(np.mean(vals))
    vals = []
    for q in queues:
        p = platform()
        vals.append(agent.schedule(p, q)["stm_rate"])
    stm["flexai"] = float(np.mean(vals))
    served = _served_stm(agent, queues, deadline_scale=1.0)
    # task-weighted over the whole workload: shed routes count as unmet,
    # so this rate is comparable to the schedulers that process every queue
    stm["flexai_served"] = served["stm_rate_incl_shed"]
    for name, v in stm.items():
        rows.append(row(f"fig13/{name}/stm_rate", 0.0, round(v, 4)))
    rows.append(row("fig13/flexai_served/deadline_miss_rate_1x", 0.0,
                    round(served["miss_rate"], 4),
                    paper="'basically 100% within required period' at the "
                          "serving boundary, unrelaxed Table-5 budgets"))
    relaxed = _served_stm(agent, queues, deadline_scale=2.0)
    rows.append(row("fig13/flexai_served/deadline_miss_rate_2x", 0.0,
                    round(relaxed["miss_rate"], 4),
                    paper="same, with 2x-relaxed budgets (headroom check)"))
    order = sorted(stm, key=stm.get, reverse=True)
    rows.append(row("fig13/ranking", 0.0, ">".join(order),
                    paper="flexai ~100%, ata high, others lower"))
    save("fig13_stmrate", rows)
    return rows
