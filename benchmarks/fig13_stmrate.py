"""Figure 13: safety-time meet rate (STMRate) per task queue per scheduler.

The ``flexai_served`` variant re-measures FlexAI's STM rate *through the
serving boundary* (``repro.serve.qos``, EDF admission): the paper's "100%
within period" claim is only meaningful if the rate survives wave
admission, queueing and preemption — not just the bare scheduler loop.

The ``fig13/scenario/<family>`` rows break the rate down over the
domain-randomized scenario fleet (``core.scenarios``): one vmapped
dispatch schedules every scenario — fault traces included, health-aware —
and each family reports its own STM / deadline-miss rate, so the figure
shows *where* the rate is lost (weather rate-scaling vs bursts vs
accelerator faults) instead of one averaged number.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import platform, queues_for, row, save, trained_flexai


def _served_stm(agent, queues, deadline_scale: float) -> dict:
    """Serve the fig-13 queues through the deadline-aware engine and read
    the STM rate off the completed placements (serving-boundary STM)."""
    from repro.serve.qos import QoSConfig, QoSPlacementEngine
    eng = QoSPlacementEngine(
        platform(), agent.learner.eval_p,
        QoSConfig(policy="edf", slots=2, deadline_scale=deadline_scale),
        backlog_scale=agent.cfg.backlog_scale)
    t = 0.0
    for q in queues:
        eng.submit(q, arrival=t)
        t += 0.05
    eng.run_until_done()
    return eng.stats()


def run(quick: bool = True) -> list:
    from repro.core.schedulers import get_scheduler
    n_queues = 2 if quick else 5
    queues = queues_for("UB", n_queues, km=0.1, seed0=70)
    agent = trained_flexai("UB", quick=quick)
    rows = []
    stm = {}
    for name in ("minmin", "ata", "ga", "sa", "worst"):
        vals = []
        for q in queues:
            p = platform()
            vals.append(get_scheduler(name).schedule(p, q)["stm_rate"])
        stm[name] = float(np.mean(vals))
    vals = []
    for q in queues:
        p = platform()
        vals.append(agent.schedule(p, q)["stm_rate"])
    stm["flexai"] = float(np.mean(vals))
    served = _served_stm(agent, queues, deadline_scale=1.0)
    # task-weighted over the whole workload: shed routes count as unmet,
    # so this rate is comparable to the schedulers that process every queue
    stm["flexai_served"] = served["stm_rate_incl_shed"]
    for name, v in stm.items():
        rows.append(row(f"fig13/{name}/stm_rate", 0.0, round(v, 4)))
    rows.append(row("fig13/flexai_served/deadline_miss_rate_1x", 0.0,
                    round(served["miss_rate"], 4),
                    paper="'basically 100% within required period' at the "
                          "serving boundary, unrelaxed Table-5 budgets"))
    relaxed = _served_stm(agent, queues, deadline_scale=2.0)
    rows.append(row("fig13/flexai_served/deadline_miss_rate_2x", 0.0,
                    round(relaxed["miss_rate"], 4),
                    paper="same, with 2x-relaxed budgets (headroom check)"))
    order = sorted(stm, key=stm.get, reverse=True)
    rows.append(row("fig13/ranking", 0.0, ">".join(order),
                    paper="flexai ~100%, ata high, others lower"))
    rows += _scenario_breakdown(agent, queues[0], quick)
    save("fig13_stmrate", rows)
    return rows


def _scenario_breakdown(agent, base_queue, quick: bool) -> list:
    """Per-scenario-family STM / deadline-miss rates for FlexAI: the whole
    fleet schedules in one batched health-aware dispatch."""
    import jax

    from repro.core.flexai.engine import make_schedule_fn
    from repro.core.platform_jax import (spec_from_platform, summarize)
    from repro.core.scenarios import FAMILIES, scenario_batch
    from repro.core.tasks import tasks_to_arrays

    spec = spec_from_platform(platform())
    base = tasks_to_arrays(base_queue)
    batch = scenario_batch(base, spec.n, seed=13,
                           n_per_family=3 if quick else 8)
    sched = make_schedule_fn(spec, agent.cfg.backlog_scale, batched=True)
    finals, recs = sched(agent.learner.eval_p, batch.tasks,
                         health=batch.health)
    take = jax.tree_util.tree_map
    per_row = [summarize(spec, take(lambda a, i=i: a[i], finals),
                         take(lambda a, i=i: a[i], recs))
               for i in range(batch.num_scenarios)]
    rows = []
    for fam in FAMILIES:
        stm = float(np.mean([per_row[i]["stm_rate"]
                             for i in batch.family_rows(fam)]))
        rows.append(row(f"fig13/scenario/{fam}/stm_rate", 0.0,
                        round(stm, 4)))
        rows.append(row(f"fig13/scenario/{fam}/deadline_miss_rate", 0.0,
                        round(1.0 - stm, 4)))
    return rows
