"""Figure 13: safety-time meet rate (STMRate) per task queue per scheduler."""
from __future__ import annotations

import numpy as np

from benchmarks.common import platform, queues_for, row, save, trained_flexai


def run(quick: bool = True) -> list:
    from repro.core.schedulers import get_scheduler
    n_queues = 2 if quick else 5
    queues = queues_for("UB", n_queues, km=0.1, seed0=70)
    agent = trained_flexai("UB", quick=quick)
    rows = []
    stm = {}
    for name in ("minmin", "ata", "ga", "sa", "worst"):
        vals = []
        for q in queues:
            p = platform()
            vals.append(get_scheduler(name).schedule(p, q)["stm_rate"])
        stm[name] = float(np.mean(vals))
    vals = []
    for q in queues:
        p = platform()
        vals.append(agent.schedule(p, q)["stm_rate"])
    stm["flexai"] = float(np.mean(vals))
    for name, v in stm.items():
        rows.append(row(f"fig13/{name}/stm_rate", 0.0, round(v, 4)))
    order = sorted(stm, key=stm.get, reverse=True)
    rows.append(row("fig13/ranking", 0.0, ">".join(order),
                    paper="flexai ~100%, ata high, others lower"))
    save("fig13_stmrate", rows)
    return rows
