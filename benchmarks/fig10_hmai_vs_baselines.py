"""Figure 10: HMAI vs Tesla T4 and homogeneous platforms — speedup,
normalized power, TOPS/W on urban task queues."""
from __future__ import annotations

import numpy as np

from benchmarks.common import RATE_SCALE, queues_for, row, save


def _run_platform(specs, queue):
    from repro.core.hmai import HMAIPlatform
    from repro.core.schedulers import get_scheduler
    plat = HMAIPlatform(specs=specs, capacity_scale=RATE_SCALE)
    get_scheduler("ata").schedule(plat, queue)
    s = plat.summary()
    macs = sum(r.task.amount for r in plat.records)
    return {
        "makespan": s["makespan_s"],
        "energy": s["total_energy_j"],
        "power": sum(sp.power_w for sp in plat.specs),
        "tops_per_w": macs * 2 / 1e12 / max(s["total_energy_j"], 1e-9)
        / RATE_SCALE,  # undo the capacity subsampling for absolute TOPS/W
    }


def run(quick: bool = True) -> list:
    from repro.core.hmai import (ACCELERATOR_SPECS, HMAI_CONFIG,
                                 HOMOGENEOUS_CONFIGS, T4_SPEC)
    n_queues = 2 if quick else 5
    queues = queues_for("UB", n_queues, km=0.1 if quick else 0.25)
    platforms = {"TeslaT4": [T4_SPEC]}
    for pname, config in {**HOMOGENEOUS_CONFIGS, "HMAI": HMAI_CONFIG}.items():
        specs = []
        for name, count in config:
            specs.extend([ACCELERATOR_SPECS[name]] * count)
        platforms[pname] = specs

    rows = []
    agg = {p: [] for p in platforms}
    for qi, q in enumerate(queues):
        for pname, specs in platforms.items():
            agg[pname].append(_run_platform(specs, q))
    t4 = agg["TeslaT4"]
    for pname in platforms:
        speedup = float(np.mean([t4[i]["makespan"] / agg[pname][i]["makespan"]
                                 for i in range(len(queues))]))
        power_ratio = agg[pname][0]["power"] / t4[0]["power"]
        topsw = float(np.mean([r["tops_per_w"] for r in agg[pname]]))
        topsw_t4 = float(np.mean([r["tops_per_w"] for r in t4]))
        rows.append(row(f"fig10/{pname}/speedup_vs_t4", 0.0,
                        round(speedup, 2)))
        rows.append(row(f"fig10/{pname}/power_vs_t4", 0.0,
                        round(power_ratio, 2)))
        rows.append(row(f"fig10/{pname}/tops_per_w_vs_t4", 0.0,
                        round(topsw / max(topsw_t4, 1e-9), 2)))
    # headline claims: ~5x speedup, ~2x power, ~2.5x TOPS/W vs T4
    hm = [r for r in rows if r["name"].startswith("fig10/HMAI/")]
    rows.append(row("fig10/paper_claims", 0.0,
                    "speedup ~5x, power ~2x, TOPS/W ~2.5x",
                    measured={r["name"].split("/")[-1]: r["derived"]
                              for r in hm}))
    save("fig10_hmai_vs_baselines", rows)
    return rows
