"""Durability benchmark: snapshot overhead, crash recovery, fault response.

Three arms over the durable QoS serving engine
(``repro.serve.durability.DurableQoSEngine``), all on the deterministic
virtual serving clock:

* **overhead** — steady-state wall time of an identical workload with
  snapshots off vs. on (async ``AsyncCheckpointer`` writes on a segment
  cadence).  CI gates on < 10% overhead.
* **recovery** — a run is cut off mid-serving (its latest on-disk
  snapshot is generally *mid-wave*), restored, and driven to completion;
  the restored outcome digest must equal the uninterrupted reference
  bit-for-bit.  MTTR is reported as the redundant waves re-served
  because the crash landed between snapshots.
* **degradation** — one accelerator (the busiest core of the healthy
  run) degrades mid-run; the graceful-degradation arm (heartbeat
  detection -> alive-mask reroute -> capacity-scaled shedding) must show
  a strictly lower deadline-miss rate than the same fault unhandled.

Emits the standard benchmark rows *and* ``BENCH_recovery.json`` with a
``gate`` block CI fails on.
"""
from __future__ import annotations

import collections
import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import RATE_SCALE, host_tuning, row, save

SNAPSHOT_EVERY = 64


def _routes(n: int, seed0: int = 300) -> list:
    """Synthetic mixed-size routes (two buckets, no environment build)."""
    from repro.core.tasks import TaskArrays
    out = []
    for i in range(n):
        rng = np.random.default_rng(seed0 + i)
        nt = int(rng.integers(60, 120)) if i % 2 else int(
            rng.integers(150, 250))
        out.append(TaskArrays(
            kind=rng.integers(0, 3, nt).astype(np.int32),
            arrival=np.sort(rng.uniform(0, 0.005 * nt, nt)).astype(
                np.float32),
            safety=np.full(nt, 0.05, np.float32),
            group=np.zeros(nt, np.int32),
            valid=np.ones(nt, bool)))
    return out


def _engine(plat, agent, *, faults=None, **kw):
    from repro.serve.durability import DurableQoSEngine
    from repro.serve.qos import QoSConfig
    cfg = QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16)
    return DurableQoSEngine(plat, agent.learner.eval_p, cfg,
                            backlog_scale=agent.cfg.backlog_scale,
                            faults=faults, **kw)


def _submit(eng, queues, seed: int = 0, load: float = 1.2) -> None:
    mean_service = float(np.mean(
        [eng._bucket(q.num_tasks) for q in queues])) * eng.base_svc
    gap = mean_service / load
    rng = np.random.default_rng(seed)
    t = 0.0
    for q in queues:
        eng.submit(q, arrival=t)
        t += float(gap * rng.uniform(0.5, 1.5))


def _serve_wall(plat, agent, queues, reps: int, **kw) -> tuple:
    """Best-of-``reps`` wall time for one full serving run (fresh engine
    each rep: serving is stateful).  Returns (seconds, last engine)."""
    best, eng = np.inf, None
    for _ in range(reps):
        eng = _engine(plat, agent, **kw)
        _submit(eng, queues)
        t0 = time.perf_counter()
        eng.run_until_done()
        if eng.saver is not None:
            eng.saver.wait()
        best = min(best, time.perf_counter() - t0)
    return best, eng


def _busiest_core(eng) -> int:
    counts = collections.Counter()
    for r in eng.completed:
        if r.summary is not None:
            counts.update(np.asarray(r.summary["placements"]).tolist())
    return int(counts.most_common(1)[0][0]) if counts else 0


def run(quick: bool = True) -> list:
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.hmai import HMAIPlatform
    from repro.serve.durability import (DurableQoSEngine, FaultInjection,
                                        serving_digest, digests_equal)

    n_req = 16 if quick else 24
    reps = 2 if quick else 3
    plat = HMAIPlatform(capacity_scale=RATE_SCALE)
    agent = FlexAIAgent(plat, FlexAIConfig(seed=0))
    queues = _routes(n_req)
    rows, result = [], {"n_requests": n_req, "rate_scale": RATE_SCALE,
                        "snapshot_every": SNAPSHOT_EVERY}

    # -- arm 1: steady-state snapshot overhead ---------------------------
    _serve_wall(plat, agent, queues, 1)  # warm the jit caches
    t_base, ref = _serve_wall(plat, agent, queues, reps)
    ref_digest = serving_digest(ref)
    tmp = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        t_snap, snap_eng = _serve_wall(
            plat, agent, queues, reps, snapshot_dir=os.path.join(tmp, "ovh"),
            snapshot_every=SNAPSHOT_EVERY)
        # overhead = synchronous time the serving thread loses to
        # pack/encode/enqueue, over the serving wall time.  The disk
        # write itself is asynchronous (AsyncCheckpointer background
        # thread), and wall-clock ratios of two separate ~100ms runs are
        # dominated by machine noise — the sync fraction is the stable,
        # attributable cost of the snapshot cadence.
        overhead = snap_eng.snapshot_time_s / t_snap
        result["overhead"] = {
            "wall_s_no_snapshots": t_base, "wall_s_snapshots": t_snap,
            "wall_ratio": t_snap / t_base - 1.0,
            "snapshot_sync_s": snap_eng.snapshot_time_s,
            "overhead_frac": overhead,
            "snapshots_written": snap_eng.snapshots_written,
            "segments": snap_eng.segments_done}
        # snapshots must not perturb serving either
        snap_parity = digests_equal(ref_digest, serving_digest(snap_eng))

        # -- arm 2: crash mid-serving, restore, bit-exact completion -----
        crash_dir = os.path.join(tmp, "crash")
        crashed = _engine(plat, agent, snapshot_dir=crash_dir,
                          snapshot_every=SNAPSHOT_EVERY)
        _submit(crashed, queues)
        n_waves_ref = len(ref.wave_log)
        crashed.serve_waves(max(n_waves_ref // 2, 1))  # then "crash": no
        crashed.saver.wait()                           # boundary snapshot
        restored = DurableQoSEngine.restore(
            crash_dir, plat, backlog_scale=agent.cfg.backlog_scale)
        waves_at_restore = len(restored.wave_log)
        restored.run_until_done()
        restored.saver.wait()
        parity = digests_equal(ref_digest, serving_digest(restored))
        redundant = len(crashed.wave_log) - waves_at_restore
        result["recovery"] = {
            "parity_exact": bool(parity),
            "snapshot_parity": bool(snap_parity),
            "waves_total": n_waves_ref,
            "waves_before_crash": len(crashed.wave_log),
            "mttr_redundant_waves": int(redundant)}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # -- arm 3: single-accelerator failure, handled vs unhandled ---------
    core = _busiest_core(ref)
    fire_at = 0.25 * float(ref.now)
    arms = {}
    for name, handled in (("handled", True), ("unhandled", False)):
        eng = _engine(plat, agent, faults=[FaultInjection(
            at_time=fire_at, core=core, factor=50.0, handled=handled)])
        _submit(eng, queues)
        eng.run_until_done()
        s = eng.stats()
        arms[name] = {k: s[k] for k in (
            "miss_rate", "completed", "shed", "missed_deadline",
            "mean_stm_rate", "cores_masked", "svc_scale")}
    result["degradation"] = {
        "fault_core": core, "fault_at": fire_at, "factor": 50.0,
        "no_fault_miss_rate": ref.stats()["miss_rate"], **arms}

    result["gate"] = {
        "parity_exact": bool(result["recovery"]["parity_exact"]
                             and result["recovery"]["snapshot_parity"]),
        "overhead_below_0.10": bool(overhead < 0.10),
        "degradation_strictly_better": bool(
            arms["handled"]["miss_rate"] < arms["unhandled"]["miss_rate"]),
    }

    rows.append(row("recovery/snapshot_overhead_frac", t_snap * 1e6,
                    round(overhead, 4),
                    paper="async snapshots must cost < 10% steady-state"))
    rows.append(row("recovery/parity_exact", 0.0,
                    result["gate"]["parity_exact"],
                    paper="crash recovery must be bit-exact"))
    rows.append(row("recovery/mttr_redundant_waves", 0.0,
                    result["recovery"]["mttr_redundant_waves"]))
    rows.append(row("recovery/miss_rate_no_fault", 0.0,
                    round(result["degradation"]["no_fault_miss_rate"], 4)))
    rows.append(row("recovery/miss_rate_fault_handled", 0.0,
                    round(arms["handled"]["miss_rate"], 4)))
    rows.append(row("recovery/miss_rate_fault_unhandled", 0.0,
                    round(arms["unhandled"]["miss_rate"], 4)))
    rows.append(row("recovery/degradation_strictly_better", 0.0,
                    result["gate"]["degradation_strictly_better"],
                    paper="graceful degradation must beat no mitigation"))
    save("recovery", rows)
    result["host_tuning"] = host_tuning()
    with open(os.path.join(os.getcwd(), "BENCH_recovery.json"), "w") as f:
        json.dump(result, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run(quick=os.environ.get("BENCH_FULL", "") != "1"):
        print(r["name"], r["derived"])
