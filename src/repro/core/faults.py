"""Deterministic in-scan fault model: schedules, traces, and replays.

PR 6 injected accelerator faults only at the *serving* boundary
(``serve/durability.py``): the scheduler inside the fused scan never saw
them.  This module pushes the fault model into the device-resident
engines (ISSUE 8):

* a **fault schedule** is a list of :class:`FaultEvent` — (step, core,
  factor) triples where ``factor`` 0.0 fails the core, 1.0 recovers it,
  and anything in (0, 1) throttles it to that capacity;
* :func:`build_health_trace` compiles a schedule into the dense
  ``[T, n]`` **health trace** the scan engines consume: row ``t`` is the
  capacity vector in force when the ``t``-th task commits (carry-forward
  between events, everything healthy before the first);
* every engine applies a trace row via ``platform_jax.with_health`` before
  its policy runs, so dead cores drop out of the action support and
  throttled cores advertise inflated effective exec times.

Granularity contract (see DESIGN.md "Fault model & scenario families"):
per-task engines (FlexAI, worst, ATA, the pipeline wavefront) sample the
trace at every task index; windowed engines (Min-Min, GA, SA) sample it
once at each window's first task index and hold it for the window — a
planner that commits a 30-task window atomically reacts to faults at
window boundaries.  :func:`window_health` encodes that convention so the
fused paths and their reference replays agree bit-for-bit.

``random_fault_events`` draws a seeded schedule (NumPy ``default_rng`` —
the same seed always yields the same trace, on any backend), which is what
the scenario generator's accelerator-fault family and the benchmarks use.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.platform_jax import (PlatformSpec, platform_init,
                                     platform_step, with_health)
from repro.core.tasks import TaskArrays


class FaultEvent(NamedTuple):
    """One scheduled health transition: at scan step ``step`` (a task
    index), core ``core`` moves to capacity ``factor`` (0.0 = fail,
    1.0 = recover, else degrade) and stays there until its next event."""
    step: int
    core: int
    factor: float


def build_health_trace(n_steps: int, n_cores: int,
                       events: list) -> np.ndarray:
    """Compile a fault schedule into the dense [n_steps, n_cores] f32
    health trace (carry-forward semantics; all-healthy rows are 1.0)."""
    trace = np.ones((max(n_steps, 1), n_cores), np.float32)
    for ev in sorted(events, key=lambda e: e.step):
        if not 0 <= ev.core < n_cores:
            raise ValueError(
                f"fault event core {ev.core} out of range for "
                f"{n_cores} accelerators")
        if ev.step < n_steps:
            trace[max(ev.step, 0):, ev.core] = np.float32(ev.factor)
    return trace


def random_fault_events(seed: int, n_steps: int, n_cores: int,
                        n_faults: int = 2, recover: bool = True,
                        degrade_range: tuple = (0.25, 0.75),
                        p_fail: float = 0.5) -> list:
    """Seeded random fail/degrade/recover schedule.

    Draws ``n_faults`` distinct cores; each faults at a random step in the
    first two-thirds of the route (fail with probability ``p_fail``, else
    a degrade drawn from ``degrade_range``) and, with ``recover=True``,
    returns to full health at a later step.  Never faults every core at
    once: core draws are without replacement and ``n_faults`` is clamped
    to ``n_cores - 1`` so at least one survivor remains.
    """
    rng = np.random.default_rng(seed)
    n_faults = int(min(n_faults, max(n_cores - 1, 0)))
    cores = rng.choice(n_cores, size=n_faults, replace=False)
    events = []
    for core in cores:
        lo, hi = 1, max(2 * n_steps // 3, 2)
        at = int(rng.integers(lo, hi))
        if rng.uniform() < p_fail:
            factor = 0.0
        else:
            factor = float(rng.uniform(*degrade_range))
        events.append(FaultEvent(step=at, core=int(core), factor=factor))
        if recover:
            back = int(rng.integers(at + max(n_steps // 6, 1),
                                    max(n_steps, at + 2)))
            events.append(FaultEvent(step=back, core=int(core), factor=1.0))
    return events


def window_health(trace, window: int):
    """[T, n] trace -> [n_windows, n] per-window rows (the row at each
    window's FIRST task index — the windowed engines' sampling contract).
    Pads the tail window with the last row, mirroring
    ``tasks.window_task_arrays``'s right-padding.  jnp-based so it can sit
    inside a traced function."""
    trace = jnp.asarray(trace)
    t = trace.shape[0]
    pad = -t % window
    if pad:
        trace = jnp.concatenate(
            [trace, jnp.broadcast_to(trace[-1:], (pad, trace.shape[1]))])
    return trace[::window]


def healthy_trace(n_steps: int, n_cores: int) -> np.ndarray:
    """The trivial all-alive trace (capacity 1.0 everywhere)."""
    return np.ones((max(n_steps, 1), n_cores), np.float32)


# ---------------------------------------------------------------------------
# task-major action replay (the reference semantics of a fault trace)
# ---------------------------------------------------------------------------

def _replay_run(spec: PlatformSpec):
    """Un-jitted task-major replay of FIXED placements under a fault
    trace: one ``platform_step`` per task in stream order, health row
    ``t`` installed before step ``t``.  This is the reference execution
    semantics every fused fault-trace engine must reproduce — and the
    evaluation path for a fault-BLIND scheduler (compute placements with
    no trace, replay them under one: dead-core picks pay the
    ``HEALTH_FLOOR`` penalty, which is exactly the deployment cost of
    ignoring degradation)."""

    def body(state, x):
        task, action, hrow = x
        return platform_step(spec, with_health(state, hrow), task,
                             action.astype(jnp.int32))

    def run(tasks: TaskArrays, actions, health=None, state0=None):
        t = tasks.arrival.shape[0]
        if health is None:
            health = jnp.ones((t, spec.n), jnp.float32)
        init = platform_init(spec.n) if state0 is None else state0
        return jax.lax.scan(body, init,
                            (tasks, jnp.asarray(actions), health))

    return run


_REPLAY_CACHE: dict = {}


def replay_actions(spec: PlatformSpec, tasks: TaskArrays, actions,
                   health=None):
    """Jitted convenience wrapper over :func:`_replay_run` (cached per
    platform table)."""
    key = (np.asarray(spec.exec_time).tobytes(),
           np.asarray(spec.energy).tobytes())
    if key not in _REPLAY_CACHE:
        _REPLAY_CACHE[key] = jax.jit(_replay_run(spec))
    return _REPLAY_CACHE[key](tasks, actions, health)
