"""System design criteria (paper §6): RSS safety time, Matching Score,
Global State Value.

Equation (1) (RSS minimal safe distance for opposite-direction traffic,
Shalev-Shwartz et al.):

    d_min = (v1 + v1_rho)/2 * rho + v1_rho^2 / (2 b_correct)
          + (|v2| + v2_rho)/2 * rho + v2_rho^2 / (2 b)

with v1_rho = v1 + rho*a_accel, v2_rho = |v2| + rho*a_accel.  The paper sets
d_min to each camera's max distance and solves for rho — the camera's
*safety time* (the worst-case response budget).  Expanding gives a quadratic
in rho solved in closed form below.

Constants (paper §6.1): a_max_accel = 8.382 m/s^2 (Tesla max), braking
6.2 m/s^2 (skilled driver), area speed limits 60/80/120 km/h (UB/UHW/HW),
turning capped at 50 km/h.
"""
from __future__ import annotations

import math

A_MAX_ACCEL = 8.382   # m/s^2
A_BRAKE = 6.2         # m/s^2 (both a_min_brake and a_min_brake_correct)

KMH = 1.0 / 3.6

AREA_SPEED_LIMIT_KMH = {"UB": 60.0, "UHW": 80.0, "HW": 120.0}
TURN_SPEED_KMH = 50.0

# camera max distances (m) per function group (paper §6.1 / Fig 7)
CAMERA_MAX_DISTANCE = {
    "FC": 250.0,    # forward
    "RC": 100.0,    # rear
    "FLSC": 80.0,   # side groups
    "RLSC": 80.0,
    "FRSC": 80.0,
    "RRSC": 80.0,
}


def rss_safe_distance(v1: float, v2: float, rho: float,
                      a_accel: float = A_MAX_ACCEL,
                      b_correct: float = A_BRAKE,
                      b: float = A_BRAKE) -> float:
    """Equation (1) evaluated forward: d_min given processing time rho."""
    v1r = v1 + rho * a_accel
    v2r = abs(v2) + rho * a_accel
    return ((v1 + v1r) / 2 * rho + v1r ** 2 / (2 * b_correct)
            + (abs(v2) + v2r) / 2 * rho + v2r ** 2 / (2 * b))


def rss_safety_time(d_min: float, v1: float, v2: float,
                    a_accel: float = A_MAX_ACCEL,
                    b_correct: float = A_BRAKE,
                    b: float = A_BRAKE) -> float:
    """Invert Eq. (1) for rho (the safety time).

    d(rho) = A rho^2 + B rho + C0, quadratic coefficients:
        A  = a + a^2/(2 b1) + a^2/(2 b2)
        B  = v1 + |v2| + a v1/b1 + a |v2|/b2
        C0 = v1^2/(2 b1) + |v2|^2/(2 b2)
    Solve A rho^2 + B rho + (C0 - d_min) = 0, positive root.
    Returns 0.0 when even rho=0 is unsafe (d(0) >= d_min).
    """
    v2 = abs(v2)
    a = a_accel
    A = a + a * a / (2 * b_correct) + a * a / (2 * b)
    B = v1 + v2 + a * v1 / b_correct + a * v2 / b
    C0 = v1 * v1 / (2 * b_correct) + v2 * v2 / (2 * b)
    C = C0 - d_min
    if C >= 0:
        return 0.0
    disc = B * B - 4 * A * C
    return (-B + math.sqrt(disc)) / (2 * A)


def scenario_velocity(area: str, scenario: str) -> float:
    """Vehicle speed (m/s) for an (area, scenario) pair."""
    v_kmh = AREA_SPEED_LIMIT_KMH[area]
    if scenario in ("TL", "TR", "turn"):
        v_kmh = min(v_kmh, TURN_SPEED_KMH)
    if scenario in ("RE", "reverse"):
        v_kmh = min(v_kmh, 10.0)  # reversing is slow; RE not allowed on HW
    return v_kmh * KMH


def camera_safety_time(camera_group: str, area: str, scenario: str) -> float:
    """Safety time (s) for a camera group in a driving context."""
    d = CAMERA_MAX_DISTANCE[camera_group]
    v = scenario_velocity(area, scenario)
    # worst case: obstacle closing at the same speed in the opposite
    # direction (paper's forward-camera model, applied per §6.1 to all
    # camera groups with their own max distance)
    return rss_safety_time(d, v, v)


def matching_score_det(response_time: float, safety_time: float) -> float:
    """MS for object detection (Fig 7a).

    In the accepted region MS grows linearly with response time (slower
    execution within the deadline = lower energy), reaching 1 at the safety
    time; past it MS plummets to -1.
    """
    if response_time <= safety_time and safety_time > 0:
        return response_time / safety_time
    return -1.0


def matching_score_tra(response_time: float, safety_time: float) -> float:
    """MS for object tracking (Fig 7b): step function at ST_OT ( = ST_OD).

    (The paper's prose inverts the labels — "in ACTime, MS is always -1" —
    which contradicts Fig 7 and §8's 'higher MS = better safety'; we use the
    self-consistent reading: inside the accepted window +1, outside -1.)
    """
    return 1.0 if response_time <= safety_time else -1.0


def matching_score(kind: str, response_time: float, safety_time: float) -> float:
    if kind in ("TRA", "tra", "tracking"):
        return matching_score_tra(response_time, safety_time)
    return matching_score_det(response_time, safety_time)


def gvalue(energy: float, runtime: float, r_balance: float,
           e_scale: float = 1.0, t_scale: float = 1.0) -> float:
    """Global State Value = (-E - T + R_Balance)/3 (after normalization).

    ``e_scale``/``t_scale`` are the normalization constants (running maxima
    in the scheduler; explicit here for testability).
    """
    e = energy / max(e_scale, 1e-12)
    t = runtime / max(t_scale, 1e-12)
    return (-e - t + r_balance) / 3.0
