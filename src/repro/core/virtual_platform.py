"""TPU adaptation of HMAI: heterogeneous *virtual accelerators* as
sub-mesh pools (DESIGN.md §3, platform level).

HMAI's accelerator-level parallelism maps onto a TPU pod by partitioning
the device mesh into pools, each compiled for one perception-workload class
with the dataflow archetype that suits it (the paper's SconvOD / SconvIC /
MconvMC affinities).  The FlexAI scheduler drives the pools through the
same queue interface as the simulated HMAI: each pool advertises a
*measured* FPS per model class (calibrated at startup by timing a warm
batch), and ``execute`` really runs the batch.

On this CPU container the pools are host-device groups and the models are
the reduced-width perception CNNs — the structure (mesh partitioning,
per-pool compilation, measured-rate scheduling) is exactly what deploys on
a real pod.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hmai as H
from repro.core.tasks import TaskKind


@dataclasses.dataclass
class PoolSpec:
    name: str
    archetype: str          # taxonomy archetype this pool emulates
    n_devices: int
    batch_size: int = 4
    width_mult: float = 0.1  # reduced CNNs for CPU-scale runs


class _ModelBank:
    """Shared, compiled-once perception nets (params passed as args so one
    jit compilation serves every pool)."""

    _instance = None

    def __init__(self, key, width_mult: float, batch_size: int):
        from repro.models.perception.cnn import convnet_apply
        from repro.models.perception.nets import (
            GOTURN_TOWER, SSD_SPEC, YOLO_SPEC, goturn_apply, init_convnet,
            init_goturn)
        from repro.sharding import unbox
        k1, k2, k3 = jax.random.split(key, 3)
        goturn_p = unbox(init_goturn(k3, max(0.2, width_mult)))
        head_spec = goturn_p.pop("head_spec")  # static: closed over, not traced
        self.params = {
            "yolo": unbox(init_convnet(k1, YOLO_SPEC, width_mult)),
            "ssd": unbox(init_convnet(k2, SSD_SPEC, width_mult)),
            "goturn": goturn_p,
        }
        self.fns = {
            "yolo": jax.jit(lambda p, x: convnet_apply(p, YOLO_SPEC, x)),
            "ssd": jax.jit(lambda p, x: convnet_apply(p, SSD_SPEC, x)),
            "goturn": jax.jit(lambda p, x: goturn_apply(
                {**p, "head_spec": head_spec}, x, x)),
        }
        self.inputs = {
            "yolo": jnp.zeros((batch_size, 64, 64, 3)),
            "ssd": jnp.zeros((batch_size, 64, 64, 3)),
            "goturn": jnp.zeros((batch_size, 32, 32, 3)),
        }

    @classmethod
    def get(cls, key, width_mult, batch_size):
        if cls._instance is None:
            cls._instance = cls(key, width_mult, batch_size)
        return cls._instance


class VirtualAcceleratorPool:
    """A device group serving the shared model bank (per-pool params would
    differ in deployment; the pool's identity here is its device count and
    dataflow archetype)."""

    def __init__(self, spec: PoolSpec, devices, key):
        self.spec = spec
        self.devices = devices
        self.bank = _ModelBank.get(key, spec.width_mult, spec.batch_size)
        self.inputs = self.bank.inputs
        self.measured_fps: dict = {}

    def calibrate(self) -> dict:
        """Measure frames/s per model class (warm, batched)."""
        for kind, fn in self.bank.fns.items():
            x = self.inputs[kind]
            p = self.bank.params[kind]
            jax.block_until_ready(fn(p, x))  # compile + warm
            t0 = time.perf_counter()
            iters = 3
            for _ in range(iters):
                jax.block_until_ready(fn(p, x))
            dt = (time.perf_counter() - t0) / iters
            # a pool of n devices serves n batches concurrently
            self.measured_fps[kind] = (x.shape[0] * self.spec.n_devices) / dt
        return self.measured_fps

    def run(self, kind: str, frames: jax.Array):
        return self.bank.fns[kind](self.bank.params[kind], frames)

    def as_accelerator_spec(self) -> H.AcceleratorSpec:
        from repro.core.taxonomy import TAXONOMY
        return H.AcceleratorSpec(
            name=f"pool:{self.spec.name}",
            arch=TAXONOMY[self.spec.archetype],
            fps=dict(self.measured_fps),
            power_w=H.ACCELERATOR_SPECS[self.spec.archetype].power_w
            * self.spec.n_devices)


DEFAULT_POOLS = (
    PoolSpec("det-large", "MconvMC", n_devices=1),
    PoolSpec("det-small", "SconvOD", n_devices=1),
    PoolSpec("tracking", "SconvIC", n_devices=1),
)


class VirtualPlatform(H.HMAIPlatform):
    """HMAIPlatform whose specs come from measured pool rates and whose
    ``execute`` really runs the batch on the pool."""

    def __init__(self, pool_specs=DEFAULT_POOLS, seed: int = 0,
                 run_real: bool = True):
        devices = jax.devices()
        self.pools: list[VirtualAcceleratorPool] = []
        key = jax.random.PRNGKey(seed)
        di = 0
        for i, ps in enumerate(pool_specs):
            devs = devices[di: di + ps.n_devices] or devices[:1]
            di += ps.n_devices
            pool = VirtualAcceleratorPool(ps, devs, jax.random.fold_in(key, i))
            pool.calibrate()
            self.pools.append(pool)
        specs = [p.as_accelerator_spec() for p in self.pools]
        super().__init__(specs=specs)
        self.run_real = run_real

    def execute(self, task, accel_index: int):
        if self.run_real:
            pool = self.pools[accel_index]
            frames = pool.inputs[task.kind.value]
            jax.block_until_ready(pool.run(task.kind.value, frames))
        return super().execute(task, accel_index)
