"""Scheduler interface + registry.

A scheduler consumes a task queue (arrival-ordered) and commits every task
to an accelerator on the platform.  ``schedule`` returns the platform
summary augmented with scheduling-runtime stats (T_schedule in the Fig-14
breakdown).
"""
from __future__ import annotations

import time

from repro.core.hmai import HMAIPlatform


class Scheduler:
    name = "base"

    def assign(self, platform: HMAIPlatform, task) -> int:
        raise NotImplementedError

    def schedule(self, platform: HMAIPlatform, tasks: list) -> dict:
        t0 = time.perf_counter()
        for task in tasks:
            idx = self.assign(platform, task)
            platform.execute(task, idx)
        dt = time.perf_counter() - t0
        summ = platform.summary()
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(len(tasks), 1)
        return summ


SCHEDULERS: dict = {}


def register(cls):
    SCHEDULERS[cls.name] = cls
    return cls


def get_scheduler(name: str, **kwargs) -> Scheduler:
    return SCHEDULERS[name](**kwargs)
