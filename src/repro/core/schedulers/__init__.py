from repro.core.schedulers.base import Scheduler, SCHEDULERS, get_scheduler
from repro.core.schedulers.minmin import MinMinScheduler
from repro.core.schedulers.ata import ATAScheduler
from repro.core.schedulers.ga import GAScheduler
from repro.core.schedulers.sa import SAScheduler
from repro.core.schedulers.worst import WorstCaseScheduler, RandomScheduler
from repro.core.schedulers.scan import (SCAN_SCHEDULERS, get_scan_scheduler,
                                        scan_schedule)
from repro.core.schedulers.metaheuristic_jax import (
    DeviceGAScheduler, DeviceSAScheduler, GAConfig, SAConfig,
    make_metaheuristic_fn, make_sharded_metaheuristic_fn,
    metaheuristic_schedule, window_fitness)
