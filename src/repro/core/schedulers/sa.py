"""Simulated annealing scheduler (Kirkpatrick lineage, paper baseline).

Windowed like GA; neighbour move = reassign one task.  Cost = makespan +
energy (Table 11: no R_Balance / MS terms).
"""
from __future__ import annotations

import numpy as np

from repro.core.schedulers.base import register
from repro.core.schedulers.ga import _WindowedSearch, _evaluate


@register
class SAScheduler(_WindowedSearch):
    name = "sa"

    def __init__(self, window: int = 30, iters: int = 120,
                 t_start: float = 1.0, t_end: float = 0.01):
        self.window = window
        self.iters = iters
        self.t_start = t_start
        self.t_end = t_end

    def optimize_window(self, platform, tasks, rng) -> np.ndarray:
        n, m = len(tasks), platform.n
        cur = rng.integers(0, m, size=n)
        cur_fit = _evaluate(platform, tasks, cur)
        best, best_fit = cur.copy(), cur_fit
        for it in range(self.iters):
            temp = self.t_start * (self.t_end / self.t_start) ** (
                it / max(self.iters - 1, 1))
            cand = cur.copy()
            cand[rng.integers(0, n)] = rng.integers(0, m)
            fit = _evaluate(platform, tasks, cand)
            if fit > cur_fit or rng.random() < np.exp(
                    (fit - cur_fit) / max(temp, 1e-9)):
                cur, cur_fit = cand, fit
                if fit > best_fit:
                    best, best_fit = cand.copy(), fit
        return best
