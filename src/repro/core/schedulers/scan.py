"""Heuristic schedulers on the device-resident array path.

The per-task Python heuristics (``minmin.py``/``ata.py``/``worst.py``)
stay as oracles; these are their pure-array twins sharing
``platform_jax.platform_step``, so benchmark comparisons against FlexAI's
scan engine run through the same substrate (one device dispatch per route,
vmap-able across routes).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core.faults import window_health
from repro.core.platform_jax import (PlatformSpec, health_capacity,
                                     platform_init, platform_step,
                                     spec_from_platform, summarize,
                                     with_health)
from repro.core.tasks import (TaskArrays, tasks_to_arrays,
                              window_task_arrays)


def _trace_or_ones(health, t: int, n: int):
    """Default the optional [T, n] fault trace to all-healthy rows (which
    the lookups divide by exactly 1.0 — a value-identical no-op)."""
    return jnp.ones((t, n), jnp.float32) if health is None \
        else jnp.asarray(health, jnp.float32)


def worst_scan(spec: PlatformSpec, tasks: TaskArrays, state0=None,
               alive=None, health=None):
    """Everything onto one accelerator (the unscheduled worst case):
    accelerator 0, or the first alive one under a fault mask / at each
    step of a ``health`` trace ([T, n], core.faults)."""
    mask = jnp.ones((spec.n,), bool) if alive is None else alive

    def body(state, x):
        task, hrow = x
        state = with_health(state, hrow)
        target = jnp.argmax(mask & state.alive).astype(jnp.int32)
        return platform_step(spec, state, task, target)

    init = platform_init(spec.n) if state0 is None else state0
    trace = _trace_or_ones(health, tasks.arrival.shape[0], spec.n)
    return jax.lax.scan(body, init, (tasks, trace))


def ata_scan(spec: PlatformSpec, tasks: TaskArrays, state0=None,
             alive=None, health=None):
    """ATA: lowest-energy accelerator meeting the safety time; fastest
    response as the deadline-salvage fallback (mirrors ``ATAScheduler``).
    ``alive`` ([n] bool) drops dead accelerators from both argmins —
    the graceful-degradation reroute of serve/durability.py — and a
    ``health`` trace ([T, n]) additionally drops per-step failures and
    inflates throttled cores' response/energy by 1/capacity."""
    mask = jnp.ones((spec.n,), bool) if alive is None else alive

    def body(state, x):
        task, hrow = x
        state = with_health(state, hrow)
        eff = health_capacity(state)
        ok = mask & state.alive
        resp = (jnp.maximum(task.arrival, state.avail)
                + spec.exec_time[:, task.kind] / eff - task.arrival)
        feasible = (resp <= task.safety) & ok
        energy = spec.energy[:, task.kind] / eff
        a_feas = jnp.argmin(jnp.where(feasible, energy, jnp.inf))
        action = jnp.where(feasible.any(), a_feas,
                           jnp.argmin(jnp.where(ok, resp, jnp.inf))
                           ).astype(jnp.int32)
        return platform_step(spec, state, task, action)

    init = platform_init(spec.n) if state0 is None else state0
    trace = _trace_or_ones(health, tasks.arrival.shape[0], spec.n)
    return jax.lax.scan(body, init, (tasks, trace))


def minmin_scan(spec: PlatformSpec, tasks: TaskArrays, state0=None,
                window: int = 30, alive=None, incremental: bool = True,
                health=None):
    """Windowed Min-Min as a nested scan.

    Outer scan walks windows of ``window`` tasks; the inner scan commits
    one (task, accelerator) pair per step — the pair with the smallest
    completion time among unscheduled window rows, row-major tie-break like
    the NumPy loop.  Padding rows start pre-scheduled, and an all-scheduled
    window step degenerates to a masked no-op ``platform_step``.

    A ``health`` trace ([T, n], core.faults) is sampled once per window —
    the row at the window's first task index — and held constant while the
    window commits (the windowed granularity contract: health constant
    within a window keeps the incremental completion-time carry valid).
    Dead cores' columns go to inf; throttled cores' completion times and
    charged exec/energy inflate by 1/capacity.

    ``incremental=True`` (default) carries the ``[W, n]`` completion-time
    matrix through the inner scan instead of rebuilding it every step:
    committing ``(ti, a)`` only moves ``state.avail[a]``, so the update is
    row ``ti`` -> inf plus a recompute of column ``a`` — O(W + n) touched
    entries per step instead of O(W*n).  Each surviving entry is produced
    by the same elementwise ``max(arrival, avail) + exec`` expression, so
    the flat argmin (and its row-major tie-break) is bit-identical to the
    rebuild path; ``incremental=False`` keeps the rebuild as the parity
    oracle.
    """
    n = spec.n
    win = window_task_arrays(tasks, window)
    mask = jnp.ones((n,), bool) if alive is None else alive
    whealth = window_health(
        _trace_or_ones(health, tasks.arrival.shape[0], n), window)

    def ct_full(wtasks, state, scheduled):
        eff = health_capacity(state)
        ok = mask & state.alive
        ct = (jnp.maximum(wtasks.arrival[:, None], state.avail[None, :])
              + spec.exec_time.T[wtasks.kind] / eff[None, :])  # [W, n]
        ct = jnp.where(ok[None, :], ct, jnp.inf)
        return jnp.where(scheduled[:, None], jnp.inf, ct)

    def commit(wtasks, state, scheduled, ct):
        flat = jnp.argmin(ct)
        ti, a = flat // n, flat % n
        ok = ~scheduled[ti]                               # False if all done
        task_i = jax.tree_util.tree_map(lambda x: x[ti], wtasks)
        state2, rec = platform_step(spec, state, task_i,
                                    a.astype(jnp.int32), valid=ok)
        return state2, scheduled.at[ti].set(True), ti, a, rec

    def inner(wtasks, carry, _):
        state, scheduled = carry
        ct = ct_full(wtasks, state, scheduled)
        state2, scheduled2, _, _, rec = commit(wtasks, state, scheduled, ct)
        return (state2, scheduled2), rec

    def inner_inc(wtasks, carry, _):
        state, scheduled, ct = carry
        state2, scheduled2, ti, a, rec = commit(wtasks, state, scheduled, ct)
        eff = health_capacity(state2)
        col = (jnp.maximum(wtasks.arrival, state2.avail[a])
               + spec.exec_time[a, wtasks.kind] / eff[a])  # [W]
        col = jnp.where(mask[a] & state2.alive[a] & ~scheduled2,
                        col, jnp.inf)
        ct2 = ct.at[ti, :].set(jnp.inf).at[:, a].set(col)
        return (state2, scheduled2, ct2), rec

    def outer(state, x):
        wtasks, hrow = x
        state = with_health(state, hrow)
        sched0 = ~wtasks.valid
        if incremental:
            (state, _, _), recs = jax.lax.scan(
                functools.partial(inner_inc, wtasks),
                (state, sched0, ct_full(wtasks, state, sched0)),
                None, length=window)
        else:
            (state, _), recs = jax.lax.scan(
                functools.partial(inner, wtasks), (state, sched0),
                None, length=window)
        return state, recs

    init = platform_init(n) if state0 is None else state0
    final, recs = jax.lax.scan(outer, init, (win, whealth))
    recs = jax.tree_util.tree_map(lambda a: a.reshape(-1, *a.shape[2:]),
                                  recs)
    return final, recs


SCAN_SCHEDULERS = {
    "worst": worst_scan,
    "ata": ata_scan,
    "minmin": minmin_scan,
}

_JIT_CACHE: dict = {}


def get_scan_scheduler(name: str, batched: bool = False):
    """Jitted (and optionally vmapped-over-routes) scan heuristic."""
    key = (name, batched)
    if key not in _JIT_CACHE:
        fn = SCAN_SCHEDULERS[name]
        if batched:
            fn = jax.vmap(fn, in_axes=(None, 0))
        _JIT_CACHE[key] = jax.jit(fn)
    return _JIT_CACHE[key]


def package_device_summary(spec, final, recs, dt: float,
                           n_tasks: int) -> dict:
    """``Scheduler.schedule``-shaped summary from one device dispatch:
    metrics via ``summarize``, wall time per task, and the committed
    placements trimmed to valid (non-padding) rows."""
    import numpy as np
    summ = summarize(spec, final, recs)
    summ["schedule_time_s"] = dt
    summ["schedule_time_per_task_s"] = dt / max(n_tasks, 1)
    summ["placements"] = np.asarray(recs.action)[
        np.asarray(recs.valid, bool)]
    return summ


def scan_schedule(name: str, platform, tasks) -> dict:
    """Convenience mirror of ``Scheduler.schedule``: same summary keys,
    computed from one device dispatch."""
    spec = spec_from_platform(platform)
    ta = tasks if isinstance(tasks, TaskArrays) else tasks_to_arrays(tasks)
    fn = get_scan_scheduler(name)
    t0 = time.perf_counter()
    final, recs = fn(spec, ta)
    jax.block_until_ready(final)
    dt = time.perf_counter() - t0
    return package_device_summary(spec, final, recs, dt, ta.num_tasks)
