"""Unscheduled baselines (paper §8.3's "worse case")."""
from __future__ import annotations

import numpy as np

from repro.core.hmai import HMAIPlatform
from repro.core.schedulers.base import Scheduler, register


@register
class WorstCaseScheduler(Scheduler):
    """Everything piles onto one accelerator — the unscheduled worst case
    (maximal queueing, minimal resource balance)."""
    name = "worst"

    def assign(self, platform: HMAIPlatform, task) -> int:
        return 0


@register
class RandomScheduler(Scheduler):
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def assign(self, platform: HMAIPlatform, task) -> int:
        return int(self.rng.integers(0, platform.n))
