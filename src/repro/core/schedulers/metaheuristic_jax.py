"""Device-resident GA/SA metaheuristics on the pure platform substrate.

The NumPy baselines (``ga.py`` / ``sa.py``) re-simulate the platform one
task per Python iteration, per individual, per generation — O(pop x
generations x window) ``_evaluate`` platform simulations for every window
of every route.  Here the whole windowed search runs inside one
``lax.scan`` over windows:

* ``window_fitness``     — the Table-11 guided-random-search fitness
  (-(makespan + 0.1 * energy)) scanned over a window's ``TaskArrays``
  slice from a *snapshot* ``PlatformState`` (``state_from_platform``),
  mutating nothing.
* ``ga`` window search   — a ``lax.fori_loop`` over generations with the
  fitness ``vmap``-ed over the population axis: elite selection by sorted
  fitness, uniform parent draws among elites, one-point crossover and
  masked mutation, all driven by ``jax.random``.
* ``sa`` window search   — ``chains`` independent annealing chains
  (vmapped): single-task reassignment proposals on a geometric
  temperature ladder with Metropolis acceptance; best state over all
  chains wins.
* route driver           — an outer ``lax.scan`` walks the route window
  by window, committing the winning assignment through ``platform_step``
  (the same transition the FlexAI scan engine uses), so a route
  schedules in one device dispatch and the search is ``vmap``-able over
  a leading route axis and shard_map-able over the ``("routes",)`` mesh
  seam (``make_sharded_metaheuristic_fn`` + ``tasks.pad_route_batch``).

The NumPy ``GAScheduler``/``SAScheduler`` stay registered as the parity
oracles; ``tests/test_metaheuristics.py`` pins the fitness arithmetic and
the committed-placement semantics to them.  See DESIGN.md ("Vectorized
metaheuristic substrate").
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.faults import window_health
from repro.core.platform_jax import (PlatformSpec, PlatformState,
                                     health_capacity, platform_init,
                                     platform_step, spec_from_platform,
                                     with_health)
from repro.core.schedulers.base import Scheduler, register
from repro.core.tasks import TaskArrays, tasks_to_arrays, window_task_arrays


class GAConfig(NamedTuple):
    """Mirrors ``GAScheduler``'s hyperparameters (paper Table 11)."""
    window: int = 30
    population: int = 16
    generations: int = 10
    mutation: float = 0.1


class SAConfig(NamedTuple):
    """Mirrors ``SAScheduler``; ``chains`` parallel annealing chains are
    the population axis the device path adds (chains=1 == the oracle's
    single trajectory, modulo the RNG stream).

    ``tempering=True`` switches the chains from independent Kirkpatrick
    annealing (every chain walks the same decaying temperature schedule)
    to **parallel tempering**: each chain holds a FIXED temperature on a
    geometric ladder from ``t_start`` (hot, chain 0) to ``t_end`` (cold),
    and every ``exchange_every`` iterations adjacent chains attempt a
    replica-exchange Metropolis swap.  Fidelity note: this is no longer
    Kirkpatrick SA — there is no cooling schedule, so per-chain behaviour
    does not converge on the oracle's trajectory; what it buys is mixing
    (hot chains tunnel out of local minima and hand good states down the
    ladder), which at equal iteration budgets gives equal-or-better best
    fitness with the chains the device path already vmaps for free.
    """
    window: int = 30
    iters: int = 120
    t_start: float = 1.0
    t_end: float = 0.01
    chains: int = 8
    tempering: bool = False
    exchange_every: int = 10


# ---------------------------------------------------------------------------
# window fitness (the pure mirror of ga._evaluate)
# ---------------------------------------------------------------------------

def _maxplus_reduce(c: jax.Array, d: jax.Array):
    """Order-preserving reduction of the affine max-plus maps
    ``g_k(x) = max(x + c_k, d_k)`` along axis 0.

    The maps are closed under composition — ``(g2 . g1)`` has
    ``c = c1 + c2`` and ``d = max(d1 + c2, d2)`` — with identity
    ``(0, -inf)``, so the window folds in ``log2(W)`` pairwise combines of
    fully-vectorized arrays instead of a W-step sequential scan.
    """
    w = c.shape[0]
    pad = (1 << max(w - 1, 1).bit_length()) - w
    c = jnp.concatenate([c, jnp.zeros((pad,) + c.shape[1:], c.dtype)])
    d = jnp.concatenate([d, jnp.full((pad,) + d.shape[1:], -jnp.inf,
                                     d.dtype)])
    while c.shape[0] > 1:
        c0, c1 = c[0::2], c[1::2]
        d0, d1 = d[0::2], d[1::2]
        c = c0 + c1
        d = jnp.maximum(d0 + c1, d1)
    return c[0], d[0]


def window_fitness(spec: PlatformSpec, state: PlatformState,
                   wtasks: TaskArrays, assignment: jax.Array) -> jax.Array:
    """Fitness = -(makespan + 0.1 * energy) of ``assignment`` simulated on
    a scratch copy of ``state`` — arithmetic-identical to ``ga._evaluate``
    on the NumPy platform (time + energy only, no R_Balance/MS terms).

    Each accelerator's FIFO queueing recurrence
    ``f_k = max(arrival_k, f_{k-1}) + et_k`` (tasks not assigned to it
    pass ``f`` through) is an affine max-plus map, so the window evaluates
    in ``log2(W)`` vectorized combines (``_maxplus_reduce``) rather than a
    sequential scan — this is what lets one generation score the whole
    population as a single [P, W, n] tensor op.  Invalid (padding) rows
    are identity maps and contribute no energy.
    """
    a = assignment.astype(jnp.int32)
    # health scale from the snapshot state: throttled cores inflate
    # et/energy by 1/capacity, dead cores by 1/HEALTH_FLOOR — fitness
    # pressure alone drives genes off dead cores, no explicit masking
    # (all-healthy divides by exactly 1.0: the oracle parity is intact)
    eff = health_capacity(state)
    et = spec.exec_time[a, wtasks.kind] / eff[a]              # [W]
    onehot = ((a[:, None] == jnp.arange(spec.n)[None, :])
              & wtasks.valid[:, None])                        # [W, n]
    energy = jnp.sum(jnp.where(wtasks.valid,
                               spec.energy[a, wtasks.kind] / eff[a], 0.0))
    c = jnp.where(onehot, et[:, None], 0.0)
    d = jnp.where(onehot, (wtasks.arrival + et)[:, None], -jnp.inf)
    c_all, d_all = _maxplus_reduce(c, d)
    finish = jnp.maximum(state.avail + c_all, d_all)          # [n]
    # idle accelerators fold in as avail_i, which never exceeds T.max()
    makespan = jnp.maximum(jnp.max(state.T), jnp.max(finish))
    return -(makespan + 0.1 * energy)


# ---------------------------------------------------------------------------
# window searches
# ---------------------------------------------------------------------------

def _ga_window(spec: PlatformSpec, cfg: GAConfig, state: PlatformState,
               wtasks: TaskArrays, key: jax.Array) -> jax.Array:
    """One GA window search; returns the best assignment vector [W]."""
    w = wtasks.arrival.shape[0]
    pop, n_elite = cfg.population, cfg.population // 2
    n_child = pop - n_elite
    fitness = jax.vmap(lambda a: window_fitness(spec, state, wtasks, a))
    k_init, k_loop = jax.random.split(key)
    population = jax.random.randint(k_init, (pop, w), 0, spec.n, jnp.int32)

    def gen(_, carry):
        population, key = carry
        key, k_par, k_cx, k_mut, k_val = jax.random.split(key, 5)
        order = jnp.argsort(-fitness(population))
        elite = population[order[:n_elite]]
        parents = elite[jax.random.randint(k_par, (n_child, 2), 0, n_elite)]
        cx = jax.random.randint(k_cx, (n_child, 1), 1, max(w, 2))
        child = jnp.where(jnp.arange(w)[None, :] < cx,
                          parents[:, 0], parents[:, 1])
        mut = jax.random.uniform(k_mut, (n_child, w)) < cfg.mutation
        child = jnp.where(
            mut, jax.random.randint(k_val, (n_child, w), 0, spec.n,
                                    jnp.int32), child)
        return jnp.concatenate([elite, child]), key

    population, _ = jax.lax.fori_loop(0, cfg.generations, gen,
                                      (population, k_loop), unroll=2)
    return population[jnp.argmax(fitness(population))]


def _sa_window(spec: PlatformSpec, cfg: SAConfig, state: PlatformState,
               wtasks: TaskArrays, key: jax.Array) -> jax.Array:
    """SA over ``cfg.chains`` vmapped annealing chains; best chain wins.

    With ``cfg.tempering`` the chains become parallel-tempering replicas:
    fixed per-chain temperatures on the geometric ladder plus periodic
    adjacent-chain exchange moves (see :class:`SAConfig`).  The default
    keeps the decaying-schedule Kirkpatrick chains bit-exactly (the
    tempering branch is compiled out and the PRNG stream is untouched)."""
    w = wtasks.arrival.shape[0]
    c = cfg.chains
    fitness = jax.vmap(lambda a: window_fitness(spec, state, wtasks, a))
    k_init, k_loop = jax.random.split(key)
    cur = jax.random.randint(k_init, (c, w), 0, spec.n, jnp.int32)
    cur_fit = fitness(cur)
    if cfg.tempering:
        # chain 0 hottest -> chain c-1 coldest, fixed for the whole window
        ladder = cfg.t_start * (cfg.t_end / cfg.t_start) ** (
            jnp.arange(c, dtype=jnp.float32) / max(c - 1, 1))

    def it(i, carry):
        cur, cur_fit, best, best_fit, key = carry
        if cfg.tempering:
            temp = ladder                                     # [c]
        else:
            frac = i.astype(jnp.float32) / max(cfg.iters - 1, 1)
            temp = cfg.t_start * (cfg.t_end / cfg.t_start) ** frac
        key, k_pos, k_val, k_acc = jax.random.split(key, 4)
        pos = jax.random.randint(k_pos, (c,), 0, w)
        val = jax.random.randint(k_val, (c,), 0, spec.n, jnp.int32)
        cand = cur.at[jnp.arange(c), pos].set(val)
        fit = fitness(cand)
        # exponent clipped at 0: uphill moves are accepted unconditionally
        # by the first clause, and exp() must not overflow for them
        p_acc = jnp.exp(jnp.minimum(
            (fit - cur_fit) / jnp.maximum(temp, 1e-9), 0.0))
        accept = (fit > cur_fit) | (jax.random.uniform(k_acc, (c,)) < p_acc)
        cur = jnp.where(accept[:, None], cand, cur)
        cur_fit = jnp.where(accept, fit, cur_fit)
        if cfg.tempering:
            # replica exchange: alternating even/odd adjacent pairs, the
            # standard exp((beta_j - beta_k)(E_j - E_k)) swap acceptance
            # with E = -fitness; one shared coin per pair (the left
            # member's draw) so both sides take the same decision
            key, k_ex = jax.random.split(key)
            idx = jnp.arange(c)
            parity = ((i + 1) // max(cfg.exchange_every, 1)) % 2
            left = (idx % 2 == parity) & (idx < c - 1)
            partner = jnp.where(left, idx + 1,
                                jnp.where(jnp.roll(left, 1), idx - 1, idx))
            beta = 1.0 / jnp.maximum(ladder, 1e-9)
            delta = (beta - beta[partner]) * (cur_fit[partner] - cur_fit)
            u = jax.random.uniform(k_ex, (c,))
            u_pair = jnp.where(left, u, u[partner])
            due = (i + 1) % max(cfg.exchange_every, 1) == 0
            swap = ((u_pair < jnp.exp(jnp.minimum(delta, 0.0)))
                    & (partner != idx) & due)
            cur = jnp.where(swap[:, None], cur[partner], cur)
            cur_fit = jnp.where(swap, cur_fit[partner], cur_fit)
        improved = cur_fit > best_fit
        best = jnp.where(improved[:, None], cur, best)
        best_fit = jnp.maximum(best_fit, cur_fit)
        return cur, cur_fit, best, best_fit, key

    # the ladder is 120 tiny dependent steps; partial unroll keeps the
    # loop-iteration overhead from dominating the vectorized proposals
    _, _, best, best_fit, _ = jax.lax.fori_loop(
        0, cfg.iters, it, (cur, cur_fit, cur, cur_fit, k_loop),
        unroll=8)
    return best[jnp.argmax(best_fit)]


_WINDOW_SEARCHES = {"ga": (_ga_window, GAConfig),
                    "sa": (_sa_window, SAConfig)}


# ---------------------------------------------------------------------------
# route driver: scan over windows, commit through platform_step
# ---------------------------------------------------------------------------

def _route_run(spec: PlatformSpec, cfg, search):
    """Un-jitted single-route runner: ``run(key, tasks, state0=None) ->
    (final_state, records)`` — the shared core the jitted, vmapped and
    shard_mapped entry points wrap (same layering as the FlexAI engine)."""
    window = cfg.window

    def commit(state, x):
        task, a = x
        return platform_step(spec, state, task, a)

    def win_body(carry, x):
        wtasks, hrow = x
        state, key = carry
        # windowed granularity contract (core.faults): the health row at
        # the window's first task index holds for the whole window, so
        # the search's fitness and the committed platform_steps agree
        state = with_health(state, hrow)
        key, k_w = jax.random.split(key)
        best = search(spec, cfg, state, wtasks, k_w)
        # partial unroll only: the commit body is scatter-heavy and a
        # full unroll sends XLA compile time past 10 minutes
        state2, recs = jax.lax.scan(commit, state, (wtasks, best),
                                    unroll=6)
        return (state2, key), recs

    def run(key, tasks: TaskArrays, state0: PlatformState | None = None,
            health=None):
        win = window_task_arrays(tasks, window)
        trace = (jnp.ones((tasks.arrival.shape[0], spec.n), jnp.float32)
                 if health is None else jnp.asarray(health, jnp.float32))
        init = platform_init(spec.n) if state0 is None else state0
        (state, _), recs = jax.lax.scan(win_body, (init, key),
                                        (win, window_health(trace, window)))
        recs = jax.tree_util.tree_map(
            lambda a: a.reshape(-1, *a.shape[2:]), recs)
        return state, recs

    return run


def make_metaheuristic_fn(spec: PlatformSpec, name: str, cfg=None,
                          batched: bool = False):
    """Compile the windowed device search ``name`` ("ga" / "sa").

    Returns ``fn(key, tasks[, state0]) -> (final_state, records)``; with
    ``batched=True`` both ``key`` [R, ...] and ``tasks`` [R, T] carry a
    leading route axis (no ``state0`` on the batched path).
    """
    search, cfg_cls = _WINDOW_SEARCHES[name]
    cfg = cfg_cls() if cfg is None else cfg
    run = _route_run(spec, cfg, search)
    if batched:
        single = run

        def run(key, tasks, health=None):
            if health is None:
                return jax.vmap(single, in_axes=(0, 0))(key, tasks)
            return jax.vmap(lambda k, t, h: single(k, t, health=h),
                            in_axes=(0, 0, 0))(key, tasks, health)
    return jax.jit(run)


def make_sharded_metaheuristic_fn(spec: PlatformSpec, name: str, mesh,
                                  cfg=None, axis: str = "routes"):
    """Multi-device variant: the vmapped route batch splits over
    ``mesh``'s ``axis`` with shard_map (keys and tasks both shard on the
    route axis; R must be a mesh-size multiple — ``pad_route_batch``).
    Window searches are route-local, so no collectives are involved."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    search, cfg_cls = _WINDOW_SEARCHES[name]
    cfg = cfg_cls() if cfg is None else cfg
    run = jax.vmap(_route_run(spec, cfg, search), in_axes=(0, 0))
    sharded = shard_map(run, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# host-side scheduler wrappers (registry names "ga_scan" / "sa_scan")
# ---------------------------------------------------------------------------

class _DeviceMetaheuristic(Scheduler):
    """``Scheduler.schedule`` surface over the device search: same summary
    keys, one device dispatch per route.  The NumPy platform argument
    supplies the hardware tables only and is left untouched (the committed
    state lives in the returned summary, like ``scan_schedule``)."""
    search_name = ""

    def __init__(self, cfg=None, seed: int = 0):
        self.cfg = cfg
        self.seed = seed
        self._cache: dict = {}

    def _fn(self, platform, spec):
        key = (platform.exec_time_table.tobytes(),
               platform.energy_table.tobytes())
        if key not in self._cache:
            self._cache[key] = make_metaheuristic_fn(
                spec, self.search_name, self.cfg)
        return self._cache[key]

    def schedule(self, platform, tasks) -> dict:
        from repro.core.schedulers.scan import package_device_summary
        spec = spec_from_platform(platform)
        ta = tasks if isinstance(tasks, TaskArrays) else \
            tasks_to_arrays(tasks)
        fn = self._fn(platform, spec)
        t0 = time.perf_counter()
        final, recs = fn(jax.random.PRNGKey(self.seed), ta)
        jax.block_until_ready(final)
        dt = time.perf_counter() - t0
        return package_device_summary(spec, final, recs, dt, ta.num_tasks)


@register
class DeviceGAScheduler(_DeviceMetaheuristic):
    name = "ga_scan"
    search_name = "ga"


@register
class DeviceSAScheduler(_DeviceMetaheuristic):
    name = "sa_scan"
    search_name = "sa"


def metaheuristic_schedule(name: str, platform, tasks, cfg=None,
                           seed: int = 0) -> dict:
    """Convenience mirror of ``scan_schedule`` for the GA/SA families."""
    cls = {"ga": DeviceGAScheduler, "sa": DeviceSAScheduler}[name]
    return cls(cfg=cfg, seed=seed).schedule(platform, tasks)
