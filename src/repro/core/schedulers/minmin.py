"""Min-Min heuristic (Braun et al. 2001, paper baseline).

Classic Min-Min operates on a batch of ready tasks: repeatedly find, for
each unscheduled task, its minimum-completion-time machine; then commit the
task whose minimum completion time is smallest.  Streaming arrival is
handled by windowing the queue (tasks within a window are treated as
simultaneously ready), matching how the paper applies batch heuristics to
camera bursts (30 frames arrive at once).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.hmai import HMAIPlatform
from repro.core.schedulers.base import Scheduler, register


@register
class MinMinScheduler(Scheduler):
    name = "minmin"

    def __init__(self, window: int = 30):
        self.window = window

    def schedule(self, platform: HMAIPlatform, tasks: list) -> dict:
        t0 = time.perf_counter()
        for w0 in range(0, len(tasks), self.window):
            batch = list(tasks[w0: w0 + self.window])
            while batch:
                # completion time of each (task, accel) pair
                best_pair = None
                best_ct = np.inf
                for ti, task in enumerate(batch):
                    for i in range(platform.n):
                        start = max(task.arrival_time, platform.avail[i])
                        ct = start + platform.exec_time(task, i)
                        if ct < best_ct:
                            best_ct = ct
                            best_pair = (ti, i)
                ti, i = best_pair
                platform.execute(batch.pop(ti), i)
        dt = time.perf_counter() - t0
        summ = platform.summary()
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(len(tasks), 1)
        return summ
