"""Genetic algorithm scheduler (Hou et al. lineage, paper baseline).

Windowed: each window of tasks is assigned by evolving a population of
assignment vectors.  The fitness follows the paper's Table-11
characterization of guided random search — time + energy only (no resource
balance, no MS), which is exactly why GA trails FlexAI on those metrics.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.hmai import HMAIPlatform
from repro.core.schedulers.base import Scheduler, register


def _evaluate(platform: HMAIPlatform, tasks, assignment) -> float:
    """Fitness = -(makespan + energy) simulated on a scratch copy."""
    avail = platform.avail.copy()
    energy = 0.0
    makespan = platform.T.max() if platform.n else 0.0
    for task, i in zip(tasks, assignment):
        et = platform.exec_time(task, i)
        start = max(task.arrival_time, avail[i])
        avail[i] = start + et
        energy += platform.specs[i].energy(task.kind)
        makespan = max(makespan, avail[i])
    return -(makespan + 0.1 * energy)


class _WindowedSearch(Scheduler):
    window = 30

    def optimize_window(self, platform, tasks, rng) -> np.ndarray:
        raise NotImplementedError

    def schedule(self, platform: HMAIPlatform, tasks: list) -> dict:
        t0 = time.perf_counter()
        rng = np.random.default_rng(0)
        for w0 in range(0, len(tasks), self.window):
            batch = tasks[w0: w0 + self.window]
            assignment = self.optimize_window(platform, batch, rng)
            for task, i in zip(batch, assignment):
                platform.execute(task, int(i))
        dt = time.perf_counter() - t0
        summ = platform.summary()
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(len(tasks), 1)
        return summ


@register
class GAScheduler(_WindowedSearch):
    name = "ga"

    def __init__(self, window: int = 30, population: int = 16,
                 generations: int = 10, mutation: float = 0.1):
        self.window = window
        self.population = population
        self.generations = generations
        self.mutation = mutation

    def optimize_window(self, platform, tasks, rng) -> np.ndarray:
        n, m = len(tasks), platform.n
        pop = rng.integers(0, m, size=(self.population, n))
        for _ in range(self.generations):
            fit = np.array([_evaluate(platform, tasks, ind) for ind in pop])
            order = np.argsort(-fit)
            elite = pop[order[: self.population // 2]]
            children = []
            while len(children) < self.population - len(elite):
                a, b = elite[rng.integers(0, len(elite), 2)]
                cx = rng.integers(1, n) if n > 1 else 0
                child = np.concatenate([a[:cx], b[cx:]])
                mut = rng.random(n) < self.mutation
                child = np.where(mut, rng.integers(0, m, n), child)
                children.append(child)
            pop = np.vstack([elite] + children)
        fit = np.array([_evaluate(platform, tasks, ind) for ind in pop])
        return pop[int(np.argmax(fit))]
