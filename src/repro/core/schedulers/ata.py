"""ATA — Adaptive Task-partitioning Algorithm (Oh et al., ICTC'18 per the
paper's citation [47]): minimize energy while guaranteeing latency.

Per task: among accelerators whose predicted response time meets the
safety time, pick the lowest-energy one; if none is feasible, fall back to
the fastest response (deadline salvage).  This makes ATA MS-optimized
(Fig 12c/13) at some energy/time cost elsewhere — matching the paper.
"""
from __future__ import annotations

from repro.core.hmai import HMAIPlatform
from repro.core.schedulers.base import Scheduler, register


@register
class ATAScheduler(Scheduler):
    name = "ata"

    def assign(self, platform: HMAIPlatform, task) -> int:
        feasible = []
        for i in range(platform.n):
            resp = platform.predicted_response(task, i)
            if resp <= task.safety_time:
                feasible.append((platform.specs[i].energy(task.kind), i))
        if feasible:
            return min(feasible)[1]
        # no feasible accelerator: minimize response time
        return min(range(platform.n),
                   key=lambda i: platform.predicted_response(task, i))
