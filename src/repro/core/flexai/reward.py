"""Reward computation (paper §7.2).

After executing the M-th task:

    reward = Gvalue_new - Gvalue + MS_new - MS

where Gvalue = (-E - T + R_Balance)/3 over the whole platform and MS is the
summed Matching Score across accelerators.  The platform tracks the running
normalization scales for E and T.
"""
from __future__ import annotations

from repro.core.hmai import HMAIPlatform


def snapshot(platform: HMAIPlatform) -> dict:
    return {"gvalue": platform.gvalue(), "ms": platform.total_ms}


def compute_reward(before: dict, platform: HMAIPlatform) -> float:
    after = snapshot(platform)
    return (after["gvalue"] - before["gvalue"]) + (after["ms"] - before["ms"])


def reward_from_states(spec, before, after):
    """Pure dGvalue + dMS on ``platform_jax.PlatformState`` pairs — the
    in-scan counterpart of ``compute_reward``."""
    from repro.core.platform_jax import gvalue_state
    return ((gvalue_state(spec, after) - gvalue_state(spec, before))
            + (after.MS.sum() - before.MS.sum()))
