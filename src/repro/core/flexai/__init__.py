from repro.core.flexai.dqn import DQNParams, init_qnet, qnet_apply, DQNLearner
from repro.core.flexai.replay import ReplayBuffer, DeviceReplay
from repro.core.flexai.agent import FlexAIAgent, FlexAIConfig
from repro.core.flexai.reward import compute_reward
from repro.core.flexai.engine import (ScanFlexAI, TrainState, dp_train_init,
                                      make_dp_train_fn, make_schedule_fn,
                                      make_sharded_schedule_fn,
                                      make_sharded_train_fn, make_train_fn,
                                      train_init)
