from repro.core.flexai.dqn import DQNParams, init_qnet, qnet_apply, DQNLearner
from repro.core.flexai.replay import ReplayBuffer
from repro.core.flexai.agent import FlexAIAgent, FlexAIConfig
from repro.core.flexai.reward import compute_reward
