"""FlexAI: the RL task-scheduling engine (paper §7).

The agent's input state is Task-Info (Amount, LayerNum, safety_time) +
HW-Info (E_i, T_i, R_Balance_i, MS_i for every accelerator); its action is
the accelerator index; the reward is dGvalue + dMS (``reward.py``).

Training follows Fig 8: schedule -> execute on HMAI -> record
(S_i, H_j, r_i, S_{i+1}) -> replay-sample -> TD update; TargNet syncs on a
fixed cadence.  Inference is a single EvalNet forward per task (predictive:
no lookahead over later tasks; global: HW-Info carries platform state).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.flexai.dqn import DQNLearner
from repro.core.flexai.replay import ReplayBuffer
from repro.core.flexai.reward import compute_reward, snapshot
from repro.core.hmai import HMAIPlatform
from repro.core.tasks import KIND_INDEX, Task, task_features


@dataclasses.dataclass(frozen=True)
class FlexAIConfig:
    gamma: float = 0.95
    lr: float = 1e-3           # paper §8.3 uses 0.01; 1e-3 is stable with Adam (see DESIGN.md)
    batch_size: int = 64
    replay_capacity: int = 50_000
    min_replay: int = 256
    target_sync_every: int = 200
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 20_000
    update_every: int = 1
    backlog_scale: float = 1.0  # seconds; HW-Info backlog -> log1p(b/scale)
    seed: int = 0


class FlexAIAgent:
    def __init__(self, platform: HMAIPlatform, cfg: FlexAIConfig = FlexAIConfig()):
        self.cfg = cfg
        self.n_actions = platform.n
        # Task-Info (3) + per-accelerator HW-Info (E, T, R_Balance, MS) +
        # the accelerator's service time for the current task class (the
        # platform knows its own Table-8 rates; exposing them in HW-Info
        # substitutes for the paper's 30M-step training budget — DESIGN.md)
        self.state_dim = 3 + 5 * platform.n
        self.learner = DQNLearner(
            jax.random.PRNGKey(cfg.seed), self.state_dim, self.n_actions,
            gamma=cfg.gamma, lr=cfg.lr,
            target_sync_every=cfg.target_sync_every)
        self.replay = ReplayBuffer(cfg.replay_capacity, self.state_dim,
                                   seed=cfg.seed)
        self.rng = np.random.default_rng(cfg.seed)
        self.env_steps = 0
        self.losses: list[float] = []

    # ------------------------------------------------------------------
    def state_vector(self, task: Task, platform: HMAIPlatform) -> np.ndarray:
        tf = np.asarray(task_features(task), np.float32)
        hw = platform.hw_info(now=task.arrival_time).astype(np.float32)
        hw[:, 1] = np.log1p(hw[:, 1] / self.cfg.backlog_scale)
        exec_row = platform.exec_time_table[:, KIND_INDEX[task.kind]] \
            .astype(np.float32)[:, None]
        hw = np.concatenate([hw, exec_row], axis=1)
        return np.concatenate([tf, hw.reshape(-1)])

    def epsilon(self) -> float:
        c = self.cfg
        frac = min(1.0, self.env_steps / max(c.eps_decay_steps, 1))
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    def act(self, state: np.ndarray, explore: bool) -> int:
        if explore and self.rng.random() < self.epsilon():
            return int(self.rng.integers(0, self.n_actions))
        q = np.asarray(self.learner.q_values(state[None]))[0]
        return int(np.argmax(q))

    # ------------------------------------------------------------------
    def train_episode(self, platform: HMAIPlatform, tasks: list) -> dict:
        """One episode = one task queue (paper §8.3)."""
        platform.reset()
        c = self.cfg
        ep_losses = []
        state = None
        for i, task in enumerate(tasks):
            state = self.state_vector(task, platform)
            action = self.act(state, explore=True)
            before = snapshot(platform)
            platform.execute(task, action)
            reward = compute_reward(before, platform)
            nxt_task = tasks[i + 1] if i + 1 < len(tasks) else task
            next_state = self.state_vector(nxt_task, platform)
            self.replay.add(state, action, reward, next_state,
                            done=(i + 1 == len(tasks)))
            self.env_steps += 1
            if (self.replay.size >= c.min_replay
                    and self.env_steps % c.update_every == 0):
                loss = self.learner.update(self.replay.sample(c.batch_size))
                ep_losses.append(loss)
                self.losses.append(loss)
        summ = platform.summary()
        summ["mean_loss"] = float(np.mean(ep_losses)) if ep_losses else None
        return summ

    def train(self, platform: HMAIPlatform, queues: list, episodes: int,
              eval_queue: list | None = None, eval_every: int = 5) -> list:
        """Cycle through task queues for the given number of episodes.

        With ``eval_queue``, periodically evaluates the greedy policy and
        keeps the best EvalNet weights (model selection on a validation
        queue — the counterpart of the paper's train-to-convergence budget).
        """
        history = []
        best_stm = -1.0
        best_params = None
        for ep in range(episodes):
            tasks = queues[ep % len(queues)]
            history.append(self.train_episode(platform, tasks))
            if eval_queue is not None and (ep + 1) % eval_every == 0:
                p_eval = HMAIPlatform(
                    specs=list(platform.specs), capacity_scale=1.0)
                stm = self.schedule(p_eval, eval_queue)["stm_rate"]
                history[-1]["eval_stm"] = stm
                if stm > best_stm:
                    best_stm = stm
                    best_params = self.learner.eval_p
        if best_params is not None:
            self.learner.eval_p = best_params
            self.learner.targ_p = best_params
        return history

    # ------------------------------------------------------------------
    def save_weights(self, path: str) -> None:
        from repro.core.flexai.dqn import save_dqn_npz
        save_dqn_npz(path, self.learner.eval_p)

    def load_weights(self, path: str) -> None:
        from repro.core.flexai.dqn import load_dqn_npz
        params = load_dqn_npz(path)
        self.learner.eval_p = params
        self.learner.targ_p = params

    # ------------------------------------------------------------------
    def schedule(self, platform: HMAIPlatform, tasks: list) -> dict:
        """Inference (well-trained agent): greedy Q per task (§7.1)."""
        t0 = time.perf_counter()
        for task in tasks:
            state = self.state_vector(task, platform)
            action = self.act(state, explore=False)
            platform.execute(task, action)
        sched_time = time.perf_counter() - t0
        summ = platform.summary()
        summ["schedule_time_s"] = sched_time
        summ["schedule_time_per_task_s"] = sched_time / max(len(tasks), 1)
        return summ

    def schedule_scan(self, platform: HMAIPlatform, tasks) -> dict:
        """Greedy inference through the device-resident engine: identical
        policy/weights as ``schedule``, one device dispatch per route
        instead of one per task.  ``tasks`` may be a Task list or a
        precompiled ``TaskArrays``; the jitted scan is cached per
        (platform shape, route length)."""
        from repro.core.flexai.engine import make_schedule_fn
        from repro.core.platform_jax import spec_from_platform, summarize
        from repro.core.tasks import TaskArrays, tasks_to_arrays
        spec = spec_from_platform(platform)
        # key on the table contents, not just the accelerator count — two
        # platforms with equal n but different hardware must not share a
        # compiled closure
        key = (platform.exec_time_table.tobytes(),
               platform.energy_table.tobytes(),
               float(self.cfg.backlog_scale))
        cache = getattr(self, "_scan_cache", None)
        if cache is None:
            cache = self._scan_cache = {}
        if key not in cache:
            cache[key] = make_schedule_fn(spec, self.cfg.backlog_scale)
        ta = tasks if isinstance(tasks, TaskArrays) else \
            tasks_to_arrays(tasks)
        t0 = time.perf_counter()
        final, recs = cache[key](self.learner.eval_p, ta)
        jax.block_until_ready(final)
        dt = time.perf_counter() - t0
        summ = summarize(spec, final, recs)
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(ta.num_tasks, 1)
        summ["placements"] = np.asarray(recs.action)
        return summ
