"""DQN networks for FlexAI (paper §7.1).

EvalNet / TargNet: identical MLPs of two fully-connected layers (256, 64
neurons, ReLU) followed by a linear head producing one Q value per
accelerator.  TargNet's parameters are copied from EvalNet every
``target_sync_every`` updates; the TD loss is

    L = ( r + gamma * max_a' TargNet(s')  -  EvalNet(s)[a] )^2

exactly the §7.1 formulation.  The update step is a single jitted function.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class DQNParams(NamedTuple):
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array
    w3: jax.Array
    b3: jax.Array


HIDDEN = (256, 64)


def init_qnet(key, state_dim: int, n_actions: int) -> DQNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2 = HIDDEN

    def glorot(k, fan_in, fan_out):
        lim = jnp.sqrt(6.0 / (fan_in + fan_out))
        return jax.random.uniform(k, (fan_in, fan_out), jnp.float32,
                                  -lim, lim)

    return DQNParams(
        w1=glorot(k1, state_dim, s1), b1=jnp.zeros((s1,)),
        w2=glorot(k2, s1, s2), b2=jnp.zeros((s2,)),
        w3=glorot(k3, s2, n_actions), b3=jnp.zeros((n_actions,)),
    )


def qnet_apply(p: DQNParams, state: jax.Array) -> jax.Array:
    """state [..., state_dim] -> Q values [..., n_actions]."""
    h = jax.nn.relu(state @ p.w1 + p.b1)
    h = jax.nn.relu(h @ p.w2 + p.b2)
    return h @ p.w3 + p.b3


class AdamState(NamedTuple):
    step: jax.Array
    mu: DQNParams
    nu: DQNParams


def _adam_init(params: DQNParams) -> AdamState:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), z, z)


def dqn_td_grads(eval_p: DQNParams, targ_p: DQNParams, batch: dict,
                 gamma: float = 0.95):
    """TD loss + norm-clipped gradients on a replay batch — the gradient
    half of :func:`dqn_td_update`, split out so the data-parallel trainer
    can all-reduce (``lax.pmean``) the clipped gradients across route
    shards before the shared Adam application.

    batch: s [B,D], a [B], r [B], s_next [B,D], done [B].
    Returns (loss, grads) with the 10.0 global-norm clip already applied
    (clip-then-average: each shard clips its local batch's gradient, so a
    single diverging shard cannot blow up the synchronized step).
    """

    def loss_fn(p):
        q = qnet_apply(p, batch["s"])                        # [B, A]
        q_sel = jnp.take_along_axis(q, batch["a"][:, None], axis=1)[:, 0]
        # double DQN (van Hasselt et al. — the paper's [12]): EvalNet picks
        # the argmax action, TargNet values it
        a_star = jnp.argmax(qnet_apply(p, batch["s_next"]), axis=-1)
        q_next = qnet_apply(targ_p, batch["s_next"])         # [B, A]
        q_tn = jnp.take_along_axis(q_next, a_star[:, None], axis=1)[:, 0]
        y = batch["r"] + gamma * (1.0 - batch["done"]) * q_tn
        y = jax.lax.stop_gradient(y)
        # Huber (smooth-L1) — standard DQN stabilizer vs outlier TD errors
        err = y - q_sel
        delta = 1.0
        return jnp.mean(jnp.where(
            jnp.abs(err) <= delta, 0.5 * err * err,
            delta * (jnp.abs(err) - 0.5 * delta)))

    loss, grads = jax.value_and_grad(loss_fn)(eval_p)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                         for g in jax.tree_util.tree_leaves(grads)))
    clip = jnp.minimum(1.0, 10.0 / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * clip, grads)
    return loss, grads


def adam_apply(eval_p: DQNParams, opt: AdamState, grads: DQNParams,
               lr: float = 0.01):
    """The Adam half of :func:`dqn_td_update`: one optimizer step on
    already-clipped (and, in the DP trainer, already all-reduced)
    gradients.  Returns (new_eval_p, new_opt)."""
    step = opt.step + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        return p - lr * (m / c1) / (jnp.sqrt(v / c2) + eps), m, v

    results = [upd(p, g, m, v) for p, g, m, v
               in zip(eval_p, grads, opt.mu, opt.nu)]
    new_p = DQNParams(*[r[0] for r in results])
    new_m = DQNParams(*[r[1] for r in results])
    new_v = DQNParams(*[r[2] for r in results])
    return new_p, AdamState(step, new_m, new_v)


def dqn_td_update(eval_p: DQNParams, targ_p: DQNParams, opt: AdamState,
                  batch: dict, gamma: float = 0.95, lr: float = 0.01):
    """One TD update on a replay batch — pure (unjitted), so the scan
    engine can inline it in a ``lax.scan`` body.

    batch: s [B,D], a [B], r [B], s_next [B,D], done [B].
    Returns (new_eval_p, new_opt, loss).
    """
    loss, grads = dqn_td_grads(eval_p, targ_p, batch, gamma=gamma)
    new_p, new_opt = adam_apply(eval_p, opt, grads, lr=lr)
    return new_p, new_opt, loss


@functools.partial(jax.jit, static_argnames=("gamma", "lr"))
def dqn_update(eval_p: DQNParams, targ_p: DQNParams, opt: AdamState,
               batch: dict, *, gamma: float = 0.95, lr: float = 0.01):
    """Jitted host-loop entry point around ``dqn_td_update``."""
    return dqn_td_update(eval_p, targ_p, opt, batch, gamma=gamma, lr=lr)


def save_dqn_npz(path: str, params: DQNParams) -> None:
    """THE checkpoint format (p0..p5 EvalNet arrays) — shared by
    ``FlexAIAgent`` and ``ScanFlexAI`` so the loop and fused trainers
    stay freely interchangeable."""
    import numpy as np
    np.savez(path, **{f"p{i}": np.asarray(w)
                      for i, w in enumerate(params)})


def load_dqn_npz(path: str) -> DQNParams:
    import numpy as np
    data = np.load(path)
    return DQNParams(*[jnp.asarray(data[f"p{i}"])
                       for i in range(len(data.files))])


class DQNLearner:
    """EvalNet + TargNet + Adam + target syncing (host-side wrapper)."""

    def __init__(self, key, state_dim: int, n_actions: int,
                 gamma: float = 0.95, lr: float = 0.01,
                 target_sync_every: int = 100):
        self.eval_p = init_qnet(key, state_dim, n_actions)
        self.targ_p = self.eval_p
        self.opt = _adam_init(self.eval_p)
        self.gamma = gamma
        self.lr = lr
        self.target_sync_every = target_sync_every
        self.updates = 0
        self._q_jit = jax.jit(qnet_apply)

    def q_values(self, state) -> jax.Array:
        return self._q_jit(self.eval_p, state)

    def update(self, batch: dict) -> float:
        self.eval_p, self.opt, loss = dqn_update(
            self.eval_p, self.targ_p, self.opt, batch,
            gamma=self.gamma, lr=self.lr)
        self.updates += 1
        if self.updates % self.target_sync_every == 0:
            self.targ_p = self.eval_p
        return float(loss)
