"""Experience replay memory (paper §7.1 step (2)).

Two implementations: the host-side ``ReplayBuffer`` used by the Python
training loop, and ``DeviceReplay`` — the same circular buffer as a pytree
of device arrays with pure add/sample ops, so the scan engine can write a
transition and sample a TD batch without leaving the device.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, seed: int = 0):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s_next = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)

    def add(self, s, a, r, s_next, done) -> None:
        i = self.ptr
        self.s[i] = s
        self.a[i] = a
        self.r[i] = r
        self.s_next[i] = s_next
        self.done[i] = float(done)
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, batch_size: int) -> dict:
        idx = self.rng.integers(0, self.size, size=batch_size)
        return {
            "s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
            "s_next": self.s_next[idx], "done": self.done[idx],
        }


# ---------------------------------------------------------------------------
# device-resident replay (scan engine)
# ---------------------------------------------------------------------------

class DeviceReplay(NamedTuple):
    s: "object"       # [C, D] f32
    a: "object"       # [C] i32
    r: "object"       # [C] f32
    s_next: "object"  # [C, D] f32
    done: "object"    # [C] f32
    ptr: "object"     # scalar i32
    size: "object"    # scalar i32


def device_replay_init(capacity: int, state_dim: int) -> DeviceReplay:
    """Rows [0, capacity) are the ring; row ``capacity`` is a trash slot
    that absorbs masked-out writes, keeping every ``add`` an in-place O(D)
    dynamic update (a predicated write would select over the whole ring
    each scan step — catastrophic under vmap)."""
    import jax.numpy as jnp
    return DeviceReplay(
        s=jnp.zeros((capacity + 1, state_dim), jnp.float32),
        a=jnp.zeros((capacity + 1,), jnp.int32),
        r=jnp.zeros((capacity + 1,), jnp.float32),
        s_next=jnp.zeros((capacity + 1, state_dim), jnp.float32),
        done=jnp.zeros((capacity + 1,), jnp.float32),
        ptr=jnp.int32(0), size=jnp.int32(0),
    )


def device_replay_add(buf: DeviceReplay, s, a, r, s_next, done,
                      write=True) -> DeviceReplay:
    """Pure circular write at ``ptr``; when ``write`` is False (padding
    row in a vmapped lane) the values land in the trash slot instead."""
    import jax.numpy as jnp
    cap = buf.s.shape[0] - 1
    i = jnp.where(write, buf.ptr, cap)
    return DeviceReplay(
        s=buf.s.at[i].set(s),
        a=buf.a.at[i].set(jnp.asarray(a, jnp.int32)),
        r=buf.r.at[i].set(r),
        s_next=buf.s_next.at[i].set(s_next),
        done=buf.done.at[i].set(jnp.asarray(done, jnp.float32)),
        ptr=jnp.where(write, (buf.ptr + 1) % cap, buf.ptr),
        size=jnp.where(write, jnp.minimum(buf.size + 1, cap), buf.size),
    )


def device_replay_sample(buf: DeviceReplay, key, batch_size: int) -> dict:
    """Uniform sample over the filled prefix (callers gate the TD update on
    ``size >= min_replay``, so an underfilled read is never consumed)."""
    import jax
    import jax.numpy as jnp
    idx = jax.random.randint(key, (batch_size,), 0,
                             jnp.maximum(buf.size, 1))
    return {"s": buf.s[idx], "a": buf.a[idx], "r": buf.r[idx],
            "s_next": buf.s_next[idx], "done": buf.done[idx]}
