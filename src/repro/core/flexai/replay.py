"""Experience replay memory (paper §7.1 step (2))."""
from __future__ import annotations

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, seed: int = 0):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s_next = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)

    def add(self, s, a, r, s_next, done) -> None:
        i = self.ptr
        self.s[i] = s
        self.a[i] = a
        self.r[i] = r
        self.s_next[i] = s_next
        self.done[i] = float(done)
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, batch_size: int) -> dict:
        idx = self.rng.integers(0, self.size, size=batch_size)
        return {
            "s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
            "s_next": self.s_next[idx], "done": self.done[idx],
        }
