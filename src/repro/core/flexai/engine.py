"""Device-resident FlexAI episode engine.

The Python training/inference loop (``agent.py``) pays a host->device
roundtrip per task: one jitted Q forward for ``act`` and one ``dqn_update``
dispatch per TD step.  Here the whole route runs inside a single
``lax.scan``:

* ``make_schedule_fn``  — greedy inference: state-vector build + Q argmax +
  ``platform_step`` fused per scan step; one device dispatch per route.
* ``make_train_fn``     — epsilon-greedy act + platform step + dGvalue+dMS
  reward + device-replay write + (on the ``update_every`` cadence) an
  inlined ``dqn_td_update`` with TargNet sync, all in the scan body.
* both come with a ``jax.vmap``-ed batch variant: routes padded to a common
  length (``TaskArrays.valid`` masks the tail) so one device call schedules
  or trains N routes/seeds.

``ScanFlexAI`` is the host-side convenience wrapper mirroring
``FlexAIAgent``'s train/schedule surface on top of these functions.
See DESIGN.md ("Scan-body layout").
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple

import jax
import jax.flatten_util  # noqa: F401  (jax.flatten_util.ravel_pytree)
import jax.numpy as jnp
import numpy as np

from repro.core.flexai.dqn import (AdamState, DQNParams, _adam_init,
                                   adam_apply, dqn_td_grads, dqn_td_update,
                                   init_qnet, qnet_apply)
from repro.core.flexai.replay import (DeviceReplay, device_replay_add,
                                      device_replay_init,
                                      device_replay_sample)
from repro.core.flexai.reward import reward_from_states
from repro.core.platform_jax import (PlatformSpec, kind_feature_table,
                                     platform_init, platform_step,
                                     spec_from_platform, state_vector,
                                     summarize, with_health)
from repro.core.tasks import (TaskArrays, pad_task_arrays,
                              stack_task_arrays, tasks_to_arrays)


# ---------------------------------------------------------------------------
# greedy inference
# ---------------------------------------------------------------------------

def _schedule_run(spec: PlatformSpec, backlog_scale: float):
    """Un-jitted single-route greedy episode: the shared core that the
    jitted, vmapped and shard_mapped entry points all wrap.

    An optional ``health`` trace ([T, n], core.faults) is installed row
    by row before each policy step: the state vector's exec column
    inflates by 1/capacity and the Q argmax is masked to alive cores.
    With no trace every row is 1.0, which divides and masks as the
    identity — placements match the pre-fault engine bit-exactly."""
    feat = jnp.asarray(kind_feature_table())

    def body(params, state, x):
        task, hrow = x
        state = with_health(state, hrow)
        sv = state_vector(spec, feat, backlog_scale, state, task)
        q = jnp.where(state.alive, qnet_apply(params, sv), -jnp.inf)
        action = jnp.argmax(q).astype(jnp.int32)
        return platform_step(spec, state, task, action)

    def run(params, tasks: TaskArrays, state0=None, health=None):
        init = platform_init(spec.n) if state0 is None else state0
        t = tasks.arrival.shape[0]
        trace = (jnp.ones((t, spec.n), jnp.float32) if health is None
                 else jnp.asarray(health, jnp.float32))
        final, recs = jax.lax.scan(functools.partial(body, params),
                                   init, (tasks, trace))
        return final, recs

    return run


def _schedule_run_masked(spec: PlatformSpec, backlog_scale: float):
    """Greedy episode with an ``alive`` accelerator mask: dead cores are
    excluded from the Q argmax, so every placement lands on a survivor.

    This is the graceful-degradation variant of :func:`_schedule_run`
    (serve/durability.py): ``alive`` is a runtime [n] bool argument, so
    one compiled closure serves any fault pattern, and with all cores
    alive the select is the identity — placements match the unmasked
    engine bit-exactly.
    """
    feat = jnp.asarray(kind_feature_table())

    def body(params, alive, state, task):
        sv = state_vector(spec, feat, backlog_scale, state, task)
        q = jnp.where(alive, qnet_apply(params, sv), -jnp.inf)
        action = jnp.argmax(q).astype(jnp.int32)
        return platform_step(spec, state, task, action)

    def run(params, tasks: TaskArrays, state0=None, alive=None):
        init = platform_init(spec.n) if state0 is None else state0
        mask = jnp.ones((spec.n,), bool) if alive is None else alive
        final, recs = jax.lax.scan(
            functools.partial(body, params, mask), init, tasks)
        return final, recs

    return run


def make_schedule_fn(spec: PlatformSpec, backlog_scale: float = 1.0,
                     batched: bool = False):
    """Compile the greedy scheduler.

    Returns ``fn(params, tasks) -> (final_state, records)``; with
    ``batched=True`` the tasks carry a leading route axis [R, T] and the
    params are shared across routes.  The single-route variant also
    accepts an optional third ``state0`` argument to resume scheduling
    from a mid-route ``PlatformState`` (the fig-14 braking continuation).
    """
    run = _schedule_run(spec, backlog_scale)
    if batched:
        single = run

        def run(params, tasks, health=None):
            # per-route fault traces vmap alongside the routes; the
            # healthy default keeps the two-arg call signature intact
            if health is None:
                return jax.vmap(single, in_axes=(None, 0))(params, tasks)
            return jax.vmap(lambda p, t, h: single(p, t, health=h),
                            in_axes=(None, 0, 0))(params, tasks, health)
    return jax.jit(run)


def make_sharded_schedule_fn(spec: PlatformSpec, mesh,
                             backlog_scale: float = 1.0,
                             axis: str = "routes"):
    """Compile the multi-device greedy scheduler: the vmapped route batch
    is split over ``mesh``'s ``axis`` with ``shard_map``, one independent
    scan per device over its local routes.

    Params replicate; the [R, T] task batch shards on the route axis, so R
    must be a multiple of the mesh size (``tasks.pad_route_batch``).  No
    collectives are involved — routes are independent — which is why the
    engine scales linearly until the per-device lane width stops covering
    the scan-step overhead.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    run = jax.vmap(_schedule_run(spec, backlog_scale), in_axes=(None, 0))
    sharded = shard_map(run, mesh=mesh, in_specs=(P(), P(axis)),
                        out_specs=P(axis))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# fused training episode
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    """Everything the fused episode mutates, as one pytree (per lane when
    vmapped): EvalNet/TargNet/Adam, the device replay ring, the epsilon /
    target-sync counters, and the PRNG key."""
    eval_p: DQNParams
    targ_p: DQNParams
    opt: AdamState
    replay: DeviceReplay
    env_steps: jax.Array   # i32: epsilon schedule position
    updates: jax.Array     # i32: TD updates done (TargNet cadence)
    key: jax.Array


def train_init(key, state_dim: int, n_actions: int,
               replay_capacity: int) -> TrainState:
    params = init_qnet(key, state_dim, n_actions)
    return TrainState(
        eval_p=params, targ_p=params, opt=_adam_init(params),
        replay=device_replay_init(replay_capacity, state_dim),
        env_steps=jnp.int32(0), updates=jnp.int32(0),
        key=jax.random.fold_in(key, 1),
    )


def _train_run(spec: PlatformSpec, cfg, td_kernel: bool = False):
    """Un-jitted single-lane fused training episode (see
    :func:`make_train_fn` for the contract).

    ``td_kernel=True`` swaps the scan body's ``dqn_td_update`` for the
    Pallas fused kernel (``repro.kernels.dqn_update``): forward, double-
    DQN target, Huber loss, hand-derived backward, global-norm clip and
    Adam in one VMEM-resident pass.  The switch is a Python-level branch,
    so the default trace is *identical* to the pre-kernel engine — the
    kernel compiles out entirely when off.

    The optional ``health`` trace makes this the *degradation trainer*:
    the greedy arm is masked to alive cores and ``platform_step`` charges
    health-scaled exec/energy, so the reward stream penalizes placements
    on throttled cores.  Random exploration stays uniform over all cores —
    the agent must *learn* to avoid degraded ones, and the PRNG stream is
    untouched, so a healthy trace reproduces the clean trainer bit-exactly
    (the DP-parity contract; the DP trainer itself stays clean-only)."""
    feat = jnp.asarray(kind_feature_table())
    n_actions = spec.n
    if td_kernel:
        from repro.kernels.dqn_update import dqn_td_update_fused
        td_update = dqn_td_update_fused
    else:
        td_update = dqn_td_update

    def body(carry, x):
        # sv rides the carry: nsv computed at step i-1 IS step i's
        # observation (same platform state, same task row), so each step
        # builds exactly one state vector instead of two.  The health row
        # lands on the *platform* before the step commits; the observation
        # sees it one step later (nsv is built from the stepped state) —
        # the action mask, not the exec column, is the fresh fault signal.
        ts, plat, sv = carry
        task, nxt_task, done, hrow = x
        plat = with_health(plat, hrow)
        key, k_eps, k_act, k_smp = jax.random.split(ts.key, 4)

        frac = jnp.minimum(
            1.0, ts.env_steps.astype(jnp.float32)
            / max(cfg.eps_decay_steps, 1))
        eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
        explore = jax.random.uniform(k_eps) < eps
        greedy = jnp.argmax(jnp.where(plat.alive,
                                      qnet_apply(ts.eval_p, sv), -jnp.inf))
        action = jnp.where(
            explore, jax.random.randint(k_act, (), 0, n_actions),
            greedy).astype(jnp.int32)

        plat2, rec = platform_step(spec, plat, task, action)
        reward = reward_from_states(spec, plat, plat2)
        nsv = state_vector(spec, feat, cfg.backlog_scale, plat2, nxt_task)

        valid = task.valid
        replay = device_replay_add(ts.replay, sv, action, reward, nsv,
                                   done.astype(jnp.float32), write=valid)
        env_steps = ts.env_steps + valid.astype(jnp.int32)
        do_update = (valid & (replay.size >= cfg.min_replay)
                     & (env_steps % cfg.update_every == 0))

        def upd(_):
            batch = device_replay_sample(replay, k_smp, cfg.batch_size)
            new_p, new_opt, loss = td_update(
                ts.eval_p, ts.targ_p, ts.opt, batch,
                gamma=cfg.gamma, lr=cfg.lr)
            updates = ts.updates + 1
            sync = (updates % cfg.target_sync_every) == 0
            targ = jax.tree_util.tree_map(
                lambda t, e: jnp.where(sync, e, t), ts.targ_p, new_p)
            return new_p, targ, new_opt, updates, loss

        def skip(_):
            return (ts.eval_p, ts.targ_p, ts.opt, ts.updates,
                    jnp.float32(0.0))

        eval_p, targ_p, opt, updates, loss = jax.lax.cond(
            do_update, upd, skip, None)
        ts2 = TrainState(eval_p=eval_p, targ_p=targ_p, opt=opt,
                         replay=replay, env_steps=env_steps,
                         updates=updates, key=key)
        return (ts2, plat2, nsv), (rec, loss, do_update)

    def run(ts: TrainState, tasks: TaskArrays, health=None):
        # S_{i+1} pairs with the *next valid* task; the last valid task
        # pairs with itself and carries done=True, matching the Python
        # loop — on padded routes the terminal transition must not
        # bootstrap from a padding row
        next_valid = jnp.concatenate(
            [tasks.valid[1:], jnp.zeros((1,), bool)])
        nxt = jax.tree_util.tree_map(
            lambda a: jnp.where(next_valid,
                                jnp.concatenate([a[1:], a[-1:]]), a),
            tasks)
        t = tasks.arrival.shape[0]
        done = jnp.arange(t) == tasks.valid.sum() - 1
        trace = (jnp.ones((t, spec.n), jnp.float32) if health is None
                 else jnp.asarray(health, jnp.float32))
        plat0 = platform_init(spec.n)
        sv0 = state_vector(spec, feat, cfg.backlog_scale, plat0,
                           jax.tree_util.tree_map(lambda a: a[0], tasks))
        (ts_f, plat_f, _), (recs, losses, upd_mask) = jax.lax.scan(
            body, (ts, plat0, sv0), (tasks, nxt, done, trace))
        return ts_f, plat_f, recs, losses, upd_mask

    return run


def make_train_fn(spec: PlatformSpec, cfg, batched: bool = False,
                  td_kernel: bool = False):
    """Compile the fused training episode for a ``FlexAIConfig``-shaped
    ``cfg`` (gamma, lr, batch_size, min_replay, target_sync_every,
    eps_start/end/decay_steps, update_every, backlog_scale).

    Returns ``fn(train_state, tasks) -> (train_state, platform_state,
    records, losses, update_mask)``.  ``batched=True`` vmaps over lanes:
    stacked TrainState (independent seeds) x stacked routes.
    ``td_kernel=True`` runs the TD update through the Pallas fused kernel
    (interpret-mode off-accelerator; see ``repro.kernels.protocol``).
    """
    # note: no buffer donation — at init eval_p and targ_p alias the same
    # arrays, and donating an aliased pytree is an XLA error
    run = _train_run(spec, cfg, td_kernel=td_kernel)
    if batched:
        single = run

        def run(ts, tasks, health=None):
            if health is None:
                return jax.vmap(single, in_axes=(0, 0))(ts, tasks)
            return jax.vmap(lambda s, t, h: single(s, t, health=h),
                            in_axes=(0, 0, 0))(ts, tasks, health)
    return jax.jit(run)


def make_sharded_train_fn(spec: PlatformSpec, cfg, mesh,
                          axis: str = "routes", td_kernel: bool = False):
    """Compile the multi-device fused training episode: stacked lanes
    (TrainState x routes) shard over ``mesh``'s ``axis``, each device
    training its local lanes' independent agents in one scan.

    The lane count must be a multiple of the mesh size.  Lanes never
    communicate (independent seeds, per-lane replay rings), so this is the
    population-training analogue of :func:`make_sharded_schedule_fn`.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    run = jax.vmap(_train_run(spec, cfg, td_kernel=td_kernel),
                   in_axes=(0, 0))
    sharded = shard_map(run, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# data-parallel fused training (one synchronized agent over route shards)
# ---------------------------------------------------------------------------

def dp_train_init(key, state_dim: int, n_actions: int, replay_capacity: int,
                  lanes: int) -> TrainState:
    """TrainState for the data-parallel trainer: ONE shared agent
    (EvalNet/TargNet/Adam/counters/key exactly as :func:`train_init`) plus
    a stacked [lanes, ...] replay ring — one ring per route lane, so each
    lane's TD batch samples its own trajectory and the gradients are
    averaged (the data-parallel global batch)."""
    params = init_qnet(key, state_dim, n_actions)
    return TrainState(
        eval_p=params, targ_p=params, opt=_adam_init(params),
        replay=jax.vmap(
            lambda _: device_replay_init(replay_capacity, state_dim)
        )(jnp.arange(lanes)),
        env_steps=jnp.int32(0), updates=jnp.int32(0),
        key=jax.random.fold_in(key, 1),
    )


def _dp_train_run(spec: PlatformSpec, cfg, lanes: int, axis=None,
                  n_shards: int = 1, chunk_collectives: bool = True,
                  td_kernel: bool = False):
    """Un-jitted data-parallel fused episode over ``lanes`` local routes.

    ``td_kernel=True`` computes each lane's clipped TD gradient with the
    Pallas fused kernel's *grads* variant — the ``(loss, grads)`` /
    ``adam_apply`` seam below is untouched, so the per-lane gradients
    still average locally and ``lax.pmean`` across the mesh axis before
    the single shared Adam step.

    Unlike :func:`_train_run` (N *independent* population agents), every
    lane — and, when ``axis`` names a mesh axis under ``shard_map``, every
    device — advances ONE synchronized agent:

    * acting / platform stepping / replay writes are per-lane (vmapped);
    * each lane samples a TD batch from its own ring, computes the clipped
      gradient, and the gradients are averaged over local lanes and
      ``lax.pmean``-ed over the mesh axis before a single shared Adam step;
    * the epsilon schedule, update cadence and TargNet sync run on *global*
      counters (``lax.psum`` of per-shard valid-task counts), so every
      shard takes the identical parameter trajectory.

    Collective layout (``chunk_collectives=True``, the default): only the
    2-float update-gate stats all-reduce every scan step; the TD batch
    sample, gradient computation, gradient all-reduce and Adam step run
    inside ``lax.cond`` on optimizer steps only (MaxText-style chunking —
    the big collective fires once per optimizer step, not once per scan
    step).  A conditioned ``pmean`` is safe here *because the predicate is
    shard-uniform by construction*: it derives solely from the psum'd
    global counters, so every shard takes the same branch and the mesh
    cannot deadlock.  ``chunk_collectives=False`` keeps the legacy layout
    (gradient computed and all-reduced every step, application masked with
    ``where``) — the two are bit-exact-trajectory equivalent at equal
    global batch (tests/test_dp_trainer.py) since the per-step PRNG splits
    are consumed identically and the kept values come from identical ops.

    With ``axis=None``, 1 lane, and the same route, the trajectory
    reproduces :func:`_train_run` (the DP parity contract in
    tests/test_dp_trainer.py): global lane 0 consumes the per-step PRNG
    keys raw, exactly like the single-lane body, while lane g > 0 folds g
    in for exploration/sampling diversity.
    """
    feat = jnp.asarray(kind_feature_table())
    n_actions = spec.n
    if td_kernel:
        from repro.kernels.dqn_update import dqn_td_grads_fused
        td_grads = dqn_td_grads_fused
    else:
        td_grads = dqn_td_grads

    if axis is None:
        psum = pmean = lambda x: x
        n_shards = 1
    else:
        psum = functools.partial(jax.lax.psum, axis_name=axis)
        pmean = functools.partial(jax.lax.pmean, axis_name=axis)

    def body(gidx, carry, x):
        ts, plats, svs = carry              # svs: step i's observations
        task, nxt_task, done = x            # leaves [lanes]
        key, k_eps, k_act, k_smp = jax.random.split(ts.key, 4)

        def lane_keys(k):
            ks = jax.vmap(lambda g: jax.random.fold_in(k, g))(gidx)
            return jnp.where((gidx == 0)[:, None], k[None, :], ks)

        frac = jnp.minimum(
            1.0, ts.env_steps.astype(jnp.float32)
            / max(cfg.eps_decay_steps, 1))
        eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac

        def act_step(plat, sv, trow, nrow, ke, ka):
            explore = jax.random.uniform(ke) < eps
            greedy = jnp.argmax(qnet_apply(ts.eval_p, sv))
            action = jnp.where(
                explore, jax.random.randint(ka, (), 0, n_actions),
                greedy).astype(jnp.int32)
            plat2, rec = platform_step(spec, plat, trow, action)
            reward = reward_from_states(spec, plat, plat2)
            nsv = state_vector(spec, feat, cfg.backlog_scale, plat2, nrow)
            return plat2, rec, action, reward, nsv

        plats2, recs, actions, rewards, nsvs = jax.vmap(act_step)(
            plats, svs, task, nxt_task, lane_keys(k_eps), lane_keys(k_act))
        replay = jax.vmap(device_replay_add)(
            ts.replay, svs, actions, rewards, nsvs,
            done.astype(jnp.float32), task.valid)

        def td_batch():
            batches = jax.vmap(
                lambda b, k: device_replay_sample(b, k, cfg.batch_size)
            )(replay, lane_keys(k_smp))
            return jax.vmap(
                lambda b: td_grads(ts.eval_p, ts.targ_p, b,
                                   gamma=cfg.gamma))(batches)

        # cadence = update_every-boundary CROSSING, not an exact-multiple
        # check: env_steps advances by the global valid-lane count per
        # scan step, so `env_steps % update_every == 0` would alias
        # (e.g. 4 lanes with update_every=3 lands on a multiple only
        # every third step — a 6x silent under-training).  For one lane
        # the crossing test reduces exactly to the single-lane modulo.
        if chunk_collectives:
            # only the 2-float gate stats all-reduce every step; the
            # gradient collective + Adam step wait for an optimizer step.
            # The cond predicate is shard-uniform (pure function of the
            # psum'd globals), so the conditional pmean cannot deadlock.
            stats = psum(jnp.stack([
                task.valid.astype(jnp.float32).sum(),
                (replay.size.min() >= cfg.min_replay).astype(jnp.float32),
            ]))
            env_steps = ts.env_steps + stats[0].astype(jnp.int32)
            crossed = (env_steps // cfg.update_every
                       > ts.env_steps // cfg.update_every)
            do_update = crossed & (stats[1] == float(n_shards))

            def upd(_):
                losses, grads = td_batch()
                flat, unravel = jax.flatten_util.ravel_pytree(
                    (losses.mean(),
                     jax.tree_util.tree_map(lambda g: g.mean(0), grads)))
                gloss, g = unravel(pmean(flat))
                new_p, new_opt = adam_apply(ts.eval_p, ts.opt, g, lr=cfg.lr)
                return new_p, new_opt, gloss

            def skip(_):
                return ts.eval_p, ts.opt, jnp.float32(0.0)

            eval_p, opt, loss = jax.lax.cond(do_update, upd, skip, None)
        else:
            # legacy layout: ONE collective per scan step — the update-gate
            # counters ride the gradient pmean as f32 (pre-scaled by
            # n_shards: pmean(x * n) == psum(x), exact in f32 for these
            # small integers) and the application is where-masked
            losses, grads = td_batch()
            stats = jnp.stack([
                task.valid.astype(jnp.float32).sum(),
                (replay.size.min() >= cfg.min_replay).astype(jnp.float32),
            ]) * float(n_shards)
            flat, unravel = jax.flatten_util.ravel_pytree(
                (stats, losses.mean(),
                 jax.tree_util.tree_map(lambda g: g.mean(0), grads)))
            stats, loss, grads = unravel(pmean(flat))
            env_steps = ts.env_steps + stats[0].astype(jnp.int32)
            crossed = (env_steps // cfg.update_every
                       > ts.env_steps // cfg.update_every)
            do_update = crossed & (stats[1] == float(n_shards))
            new_p, new_opt = adam_apply(ts.eval_p, ts.opt, grads, lr=cfg.lr)
            keep = lambda n, o: jnp.where(do_update, n, o)  # noqa: E731
            eval_p = jax.tree_util.tree_map(keep, new_p, ts.eval_p)
            opt = jax.tree_util.tree_map(keep, new_opt, ts.opt)
            loss = jnp.where(do_update, loss, 0.0)

        updates = ts.updates + do_update.astype(jnp.int32)
        sync = do_update & (updates % cfg.target_sync_every == 0)
        targ_p = jax.tree_util.tree_map(
            lambda e, t: jnp.where(sync, e, t), eval_p, ts.targ_p)
        ts2 = TrainState(eval_p=eval_p, targ_p=targ_p, opt=opt,
                         replay=replay, env_steps=env_steps,
                         updates=updates, key=key)
        return (ts2, plats2, nsvs), (recs, loss, do_update)

    def run(ts: TrainState, tasks: TaskArrays):
        # global lane ids: shard i owns contiguous lanes [i*lanes, ...)
        # (shard_map block partitioning); global lane 0 keeps the raw
        # per-step keys so the 1-shard trajectory matches _train_run
        base = 0 if axis is None else jax.lax.axis_index(axis) * lanes
        gidx = base + jnp.arange(lanes)
        next_valid = jnp.concatenate(
            [tasks.valid[:, 1:], jnp.zeros((lanes, 1), bool)], axis=1)
        nxt = jax.tree_util.tree_map(
            lambda a: jnp.where(
                next_valid,
                jnp.concatenate([a[:, 1:], a[:, -1:]], axis=1), a),
            tasks)
        t = tasks.arrival.shape[1]
        done = jnp.arange(t)[None, :] == \
            tasks.valid.sum(axis=1, keepdims=True) - 1
        plats0 = jax.vmap(lambda _: platform_init(spec.n))(jnp.arange(lanes))
        svs0 = jax.vmap(
            lambda p, trow: state_vector(spec, feat, cfg.backlog_scale,
                                         p, trow)
        )(plats0, jax.tree_util.tree_map(lambda a: a[:, 0], tasks))
        xs = jax.tree_util.tree_map(
            lambda a: jnp.swapaxes(a, 0, 1), (tasks, nxt, done))
        (ts_f, plat_f, _), (recs, losses, upd) = jax.lax.scan(
            functools.partial(body, gidx), (ts, plats0, svs0), xs)
        recs = jax.tree_util.tree_map(
            lambda a: jnp.swapaxes(a, 0, 1), recs)
        return ts_f, plat_f, recs, losses, upd

    return run


def make_dp_train_fn(spec: PlatformSpec, cfg, lanes: int, mesh=None,
                     axis: str = "routes", chunk_collectives: bool = True,
                     td_kernel: bool = False):
    """Compile the data-parallel fused trainer.

    Returns ``fn(train_state, tasks) -> (train_state, platform_states,
    records, losses, update_mask)`` where ``train_state`` comes from
    :func:`dp_train_init` (shared agent + [lanes, ...] replay) and
    ``tasks`` is a [lanes, T] route batch — the data-parallel global
    batch.  ``records`` / ``platform_states`` keep the [lanes, ...] route
    axis; ``losses`` / ``update_mask`` are [T], shared by construction.

    With ``mesh``, the lane axis shards over ``mesh``'s ``axis``
    (``lanes`` must be a multiple of the mesh size): each device runs its
    local routes and the per-step gradient all-reduce keeps every shard on
    one synchronized agent — the scale-out recipe of MaxText-style JAX
    trainers, on the platform substrate — and with the default
    ``chunk_collectives=True`` the gradient all-reduce fires once per
    optimizer step instead of every scan step (see ``_dp_train_run``).
    """
    if mesh is None:
        return jax.jit(_dp_train_run(spec, cfg, lanes,
                                     chunk_collectives=chunk_collectives,
                                     td_kernel=td_kernel))
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    if lanes < 1 or lanes % mesh.size:
        raise ValueError(f"lanes={lanes} must be a positive multiple of "
                         f"the mesh size {mesh.size}")
    run = _dp_train_run(spec, cfg, lanes // mesh.size, axis=axis,
                        n_shards=mesh.size,
                        chunk_collectives=chunk_collectives,
                        td_kernel=td_kernel)
    ts_specs = TrainState(eval_p=P(), targ_p=P(), opt=P(), replay=P(axis),
                          env_steps=P(), updates=P(), key=P())
    sharded = shard_map(run, mesh=mesh, in_specs=(ts_specs, P(axis)),
                        out_specs=(ts_specs, P(axis), P(axis), P(), P()))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# host-side wrapper
# ---------------------------------------------------------------------------

class ScanFlexAI:
    """FlexAI with the device-resident engine: ``FlexAIAgent``'s surface
    (train over queues, greedy schedule, weight import/export) at one
    device dispatch per route — or per route *batch* with ``lanes > 1``.

    Two multi-lane training modes:

    * ``dp=False`` (default): ``lanes`` *independent* population agents,
      one per lane (N seeds x N routes per device call).  With ``mesh``
      (a 1-D device mesh) the lane batch shards over the mesh.
    * ``dp=True``: ONE synchronized agent trained data-parallel over a
      ``lanes``-route global batch (per-lane TD gradients averaged, and —
      with ``mesh`` — ``lax.pmean``-ed across devices each step).

    ``td_kernel=True`` routes every TD update through the Pallas fused
    kernel (``repro.kernels.dqn_update``): single-lane/population paths
    use the Adam-folded variant, the DP path the grads variant ahead of
    its ``pmean`` + shared ``adam_apply``.  Default off — the flag is a
    trace-time Python branch, so the kernel compiles out entirely and
    the default trainer stays bit-identical to the pre-kernel engine.
    Off-accelerator the kernel runs in Pallas interpret mode (slower on
    CPU — honest numbers in BENCH_kernels.json); set
    ``REPRO_KERNEL_COMPILED=1`` on a TPU/GPU host to run it compiled.
    """

    def __init__(self, platform, cfg, lanes: int = 1, mesh=None,
                 dp: bool = False, td_kernel: bool = False):
        self.cfg = cfg
        self.spec = spec_from_platform(platform)
        self.n_actions = platform.n
        self.state_dim = 3 + 5 * platform.n
        self.lanes = lanes
        self.mesh = mesh
        self.dp = dp
        self.td_kernel = td_kernel
        key = jax.random.PRNGKey(cfg.seed)
        if dp:
            self.ts = dp_train_init(key, self.state_dim, self.n_actions,
                                    cfg.replay_capacity, lanes)
            self._train_fn = make_dp_train_fn(
                self.spec, cfg, lanes, mesh=mesh,
                axis=mesh.axis_names[0] if mesh is not None else "routes",
                td_kernel=td_kernel)
        elif lanes == 1:
            self.ts = train_init(key, self.state_dim, self.n_actions,
                                 cfg.replay_capacity)
        else:
            self.ts = jax.vmap(
                lambda k: train_init(k, self.state_dim, self.n_actions,
                                     cfg.replay_capacity)
            )(jax.random.split(key, lanes))
        if not dp:
            if mesh is not None:
                # lanes == 1 keeps an unstacked TrainState, which the
                # vmapped sharded runner cannot consume — and a sharded
                # single lane is pointless anyway
                if lanes < 2 or lanes % mesh.size:
                    raise ValueError(
                        f"lanes={lanes} must be >= 2 and a multiple of the "
                        f"mesh size {mesh.size} (omit mesh for single-lane)")
                self._train_fn = make_sharded_train_fn(
                    self.spec, cfg, mesh, axis=mesh.axis_names[0],
                    td_kernel=td_kernel)
            else:
                self._train_fn = make_train_fn(self.spec, cfg,
                                               batched=lanes > 1,
                                               td_kernel=td_kernel)
        self._sched_fn = make_schedule_fn(self.spec, cfg.backlog_scale)
        self._eval_fn = None
        self.losses: list[float] = []
        self.best_eval_stm: float | None = None
        # model-selection state lives on the instance (not train() locals)
        # so a snapshot/resume cycle keeps the best-so-far candidate
        self._best_stm: float = -1.0
        self._best_params: DQNParams | None = None

    def _as_arrays(self, tasks) -> TaskArrays:
        return tasks if isinstance(tasks, TaskArrays) else \
            tasks_to_arrays(tasks)

    def train_episode(self, tasks, health=None) -> dict:
        """One fused episode (single-lane) or one episode per lane
        (``tasks`` as a list of routes / stacked TaskArrays).

        ``health`` is an optional fault trace — [T, n] single-lane,
        [lanes, T, n] for population lanes — consumed by the degradation
        trainer (core.faults); the DP and sharded trainers are clean-only.
        """
        if health is not None and (self.dp or self.mesh is not None):
            raise ValueError(
                "fault-trace training is supported on the single-host "
                "population trainer only (not dp/mesh)")
        if self.lanes > 1:
            ta = tasks if isinstance(tasks, TaskArrays) else \
                stack_task_arrays([self._as_arrays(q) for q in tasks])
        else:
            ta = self._as_arrays(tasks)
            if self.dp:  # the DP runner always carries a [lanes, T] axis
                ta = TaskArrays(*[np.asarray(f)[None] for f in ta])
        if health is None:
            self.ts, plat, recs, losses, upd = self._train_fn(self.ts, ta)
        else:
            self.ts, plat, recs, losses, upd = self._train_fn(
                self.ts, ta, health=jnp.asarray(health, jnp.float32))
        losses, upd = np.asarray(losses), np.asarray(upd, bool)
        if upd.any():
            self.losses.extend(losses[upd].tolist())
        if self.dp:
            mean_loss = float(losses[upd].mean()) if upd.any() else None
            summ = [summarize(
                self.spec,
                jax.tree_util.tree_map(lambda a, i=i: a[i], plat),
                jax.tree_util.tree_map(lambda a, i=i: a[i], recs))
                for i in range(self.lanes)]
            if self.lanes == 1:
                s = summ[0]
                s["mean_loss"] = mean_loss
                return s
            return {"lanes": summ, "mean_loss": mean_loss}
        if self.lanes > 1:
            summ = []
            for i in range(self.lanes):
                lane = summarize(
                    self.spec,
                    jax.tree_util.tree_map(lambda a, i=i: a[i], plat),
                    jax.tree_util.tree_map(lambda a, i=i: a[i], recs))
                m = upd[i]
                lane["mean_loss"] = (float(losses[i][m].mean())
                                     if m.any() else None)
                summ.append(lane)
            return {"lanes": summ}
        s = summarize(self.spec, plat, recs)
        s["mean_loss"] = float(losses[upd].mean()) if upd.any() else None
        return s

    def train(self, queues: list, episodes: int, eval_queue=None,
              eval_every: int = 5, on_episode=None,
              start_episode: int = 0) -> list:
        """Cycle the queue pool; with ``lanes > 1`` each episode consumes
        the next ``lanes`` routes round-robin, one per lane.

        With ``eval_queue``, periodically runs a vmapped greedy eval on
        the held-out queue between fused episode segments and keeps the
        best-eval EvalNet weights (the scan-path counterpart of
        ``FlexAIAgent.train``'s model selection); the winner is restored
        into EvalNet/TargNet once training ends.

        ``on_episode(ep, trainer)`` fires after each episode (snapshot
        cadence hook); ``start_episode`` resumes mid-run — route cycling
        and the eval cadence are indexed by the *global* episode number,
        so a restored run consumes exactly the episodes the uninterrupted
        run would have (the bit-exact resume contract; model-selection
        state rides on ``self._best_stm`` / ``self._best_params`` and is
        the restorer's to reinstall).
        """
        routes = [self._as_arrays(q) for q in queues]
        if self.lanes > 1 or self.dp:
            # shared static length -> one compiled episode per lane batch.
            # Single-lane pools stay unpadded: padding rows are training
            # no-ops but still consume per-step PRNG splits, which would
            # shift the exploration stream of every later episode.
            t_max = max(r.arrival.shape[-1] for r in routes)
            routes = [pad_task_arrays(r, t_max)
                      if r.arrival.shape[-1] < t_max else r
                      for r in routes]
        ta_eval = self._as_arrays(eval_queue) \
            if eval_queue is not None else None
        history = []
        if start_episode == 0:
            self._best_stm, self._best_params = -1.0, None
        per_lane = 1 if (self.lanes == 1 and not self.dp) else self.lanes
        for ep in range(start_episode, episodes):
            if per_lane == 1:
                history.append(self.train_episode(routes[ep % len(routes)]))
            else:
                lane_routes = [
                    routes[(ep * per_lane + i) % len(routes)]
                    for i in range(per_lane)]
                history.append(self.train_episode(lane_routes))
            if ta_eval is not None and (ep + 1) % eval_every == 0:
                stms = self._eval_stms(ta_eval)
                history[-1]["eval_stm"] = (
                    stms[0] if len(stms) == 1 else stms)
                lane = int(np.argmax(stms))
                if stms[lane] > self._best_stm:
                    self._best_stm = stms[lane]
                    self._best_params = self.eval_params(lane)
            if on_episode is not None:
                on_episode(ep, self)
        if self._best_params is not None:
            self.set_params(self._best_params)
            self.best_eval_stm = self._best_stm
        return history

    def _eval_stms(self, ta_eval: TaskArrays) -> list[float]:
        """Greedy STM rate on the held-out queue, per candidate parameter
        set: one entry for the shared agent (single-lane / DP), one per
        lane for population training (params vmapped over lanes, queue
        broadcast — a single device dispatch either way)."""
        if self.dp or self.lanes == 1:
            final, recs = self._sched_fn(self.eval_params(), ta_eval)
            return [summarize(self.spec, final, recs)["stm_rate"]]
        if self._eval_fn is None:
            self._eval_fn = jax.jit(jax.vmap(
                _schedule_run(self.spec, self.cfg.backlog_scale),
                in_axes=(0, None)))
        finals, recs = self._eval_fn(self.ts.eval_p, ta_eval)
        return [summarize(
            self.spec,
            jax.tree_util.tree_map(lambda a, i=i: a[i], finals),
            jax.tree_util.tree_map(lambda a, i=i: a[i], recs))["stm_rate"]
            for i in range(self.lanes)]

    def eval_params(self, lane: int = 0) -> DQNParams:
        if self.dp or self.lanes == 1:
            return self.ts.eval_p
        return jax.tree_util.tree_map(lambda a: a[lane], self.ts.eval_p)

    # ------------------------------------------------------------------
    # weight interop with FlexAIAgent (shared npz checkpoint format)
    # ------------------------------------------------------------------

    def set_params(self, params: DQNParams) -> None:
        """Install EvalNet weights (TargNet synced, Adam reset — importing
        mid-run optimizer moments across trainers is meaningless).  With
        population lanes the weights broadcast to every lane."""
        if self.dp or self.lanes == 1:
            eval_p = params
        else:
            eval_p = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a, (self.lanes,) + a.shape).copy(),
                params)
        self.ts = self.ts._replace(
            eval_p=eval_p, targ_p=eval_p,
            opt=jax.tree_util.tree_map(jnp.zeros_like, self.ts.opt))

    @classmethod
    def from_agent(cls, agent, platform, *, lanes: int = 1, mesh=None,
                   dp: bool = False, td_kernel: bool = False,
                   cfg=None) -> "ScanFlexAI":
        """Lossless import of a ``FlexAIAgent``: same config (unless
        overridden), same EvalNet/TargNet weights, ready to continue
        training on the fused path."""
        trainer = cls(platform, cfg if cfg is not None else agent.cfg,
                      lanes=lanes, mesh=mesh, dp=dp, td_kernel=td_kernel)
        trainer.set_params(agent.learner.eval_p)
        trainer.losses = list(agent.losses)
        return trainer

    def to_agent(self, platform, lane: int = 0):
        """Lossless export to a ``FlexAIAgent`` (the Python-loop wrapper):
        the greedy policy — and therefore every placement — is preserved
        bit-exactly."""
        from repro.core.flexai.agent import FlexAIAgent
        agent = FlexAIAgent(platform, self.cfg)
        params = self.eval_params(lane)
        agent.learner.eval_p = params
        agent.learner.targ_p = params
        agent.losses = list(self.losses)
        return agent

    def save_weights(self, path: str, lane: int = 0) -> None:
        """``FlexAIAgent.save_weights``-compatible npz (p0..p5 arrays,
        one shared serializer in ``dqn.py``)."""
        from repro.core.flexai.dqn import save_dqn_npz
        save_dqn_npz(path, self.eval_params(lane))

    def load_weights(self, path: str) -> None:
        from repro.core.flexai.dqn import load_dqn_npz
        self.set_params(load_dqn_npz(path))

    def schedule(self, tasks, lane: int = 0, health=None) -> dict:
        ta = self._as_arrays(tasks)
        t0 = time.perf_counter()
        if health is None:
            final, recs = self._sched_fn(self.eval_params(lane), ta)
        else:
            final, recs = self._sched_fn(
                self.eval_params(lane), ta,
                health=jnp.asarray(health, jnp.float32))
        jax.block_until_ready(final)
        dt = time.perf_counter() - t0
        summ = summarize(self.spec, final, recs)
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(ta.num_tasks, 1)
        summ["placements"] = np.asarray(recs.action)
        return summ
