"""Device-resident FlexAI episode engine.

The Python training/inference loop (``agent.py``) pays a host->device
roundtrip per task: one jitted Q forward for ``act`` and one ``dqn_update``
dispatch per TD step.  Here the whole route runs inside a single
``lax.scan``:

* ``make_schedule_fn``  — greedy inference: state-vector build + Q argmax +
  ``platform_step`` fused per scan step; one device dispatch per route.
* ``make_train_fn``     — epsilon-greedy act + platform step + dGvalue+dMS
  reward + device-replay write + (on the ``update_every`` cadence) an
  inlined ``dqn_td_update`` with TargNet sync, all in the scan body.
* both come with a ``jax.vmap``-ed batch variant: routes padded to a common
  length (``TaskArrays.valid`` masks the tail) so one device call schedules
  or trains N routes/seeds.

``ScanFlexAI`` is the host-side convenience wrapper mirroring
``FlexAIAgent``'s train/schedule surface on top of these functions.
See DESIGN.md ("Scan-body layout").
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flexai.dqn import (AdamState, DQNParams, _adam_init,
                                   dqn_td_update, init_qnet, qnet_apply)
from repro.core.flexai.replay import (DeviceReplay, device_replay_add,
                                      device_replay_init,
                                      device_replay_sample)
from repro.core.flexai.reward import reward_from_states
from repro.core.platform_jax import (PlatformSpec, kind_feature_table,
                                     platform_init, platform_step,
                                     spec_from_platform, state_vector,
                                     summarize)
from repro.core.tasks import TaskArrays, stack_task_arrays, tasks_to_arrays


# ---------------------------------------------------------------------------
# greedy inference
# ---------------------------------------------------------------------------

def _schedule_run(spec: PlatformSpec, backlog_scale: float):
    """Un-jitted single-route greedy episode: the shared core that the
    jitted, vmapped and shard_mapped entry points all wrap."""
    feat = jnp.asarray(kind_feature_table())

    def body(params, state, task):
        sv = state_vector(spec, feat, backlog_scale, state, task)
        action = jnp.argmax(qnet_apply(params, sv)).astype(jnp.int32)
        return platform_step(spec, state, task, action)

    def run(params, tasks: TaskArrays, state0=None):
        init = platform_init(spec.n) if state0 is None else state0
        final, recs = jax.lax.scan(functools.partial(body, params),
                                   init, tasks)
        return final, recs

    return run


def make_schedule_fn(spec: PlatformSpec, backlog_scale: float = 1.0,
                     batched: bool = False):
    """Compile the greedy scheduler.

    Returns ``fn(params, tasks) -> (final_state, records)``; with
    ``batched=True`` the tasks carry a leading route axis [R, T] and the
    params are shared across routes.  The single-route variant also
    accepts an optional third ``state0`` argument to resume scheduling
    from a mid-route ``PlatformState`` (the fig-14 braking continuation).
    """
    run = _schedule_run(spec, backlog_scale)
    if batched:
        run = jax.vmap(run, in_axes=(None, 0))
    return jax.jit(run)


def make_sharded_schedule_fn(spec: PlatformSpec, mesh,
                             backlog_scale: float = 1.0,
                             axis: str = "routes"):
    """Compile the multi-device greedy scheduler: the vmapped route batch
    is split over ``mesh``'s ``axis`` with ``shard_map``, one independent
    scan per device over its local routes.

    Params replicate; the [R, T] task batch shards on the route axis, so R
    must be a multiple of the mesh size (``tasks.pad_route_batch``).  No
    collectives are involved — routes are independent — which is why the
    engine scales linearly until the per-device lane width stops covering
    the scan-step overhead.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    run = jax.vmap(_schedule_run(spec, backlog_scale), in_axes=(None, 0))
    sharded = shard_map(run, mesh=mesh, in_specs=(P(), P(axis)),
                        out_specs=P(axis))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# fused training episode
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    """Everything the fused episode mutates, as one pytree (per lane when
    vmapped): EvalNet/TargNet/Adam, the device replay ring, the epsilon /
    target-sync counters, and the PRNG key."""
    eval_p: DQNParams
    targ_p: DQNParams
    opt: AdamState
    replay: DeviceReplay
    env_steps: jax.Array   # i32: epsilon schedule position
    updates: jax.Array     # i32: TD updates done (TargNet cadence)
    key: jax.Array


def train_init(key, state_dim: int, n_actions: int,
               replay_capacity: int) -> TrainState:
    params = init_qnet(key, state_dim, n_actions)
    return TrainState(
        eval_p=params, targ_p=params, opt=_adam_init(params),
        replay=device_replay_init(replay_capacity, state_dim),
        env_steps=jnp.int32(0), updates=jnp.int32(0),
        key=jax.random.fold_in(key, 1),
    )


def _train_run(spec: PlatformSpec, cfg):
    """Un-jitted single-lane fused training episode (see
    :func:`make_train_fn` for the contract)."""
    feat = jnp.asarray(kind_feature_table())
    n_actions = spec.n

    def body(carry, x):
        ts, plat = carry
        task, nxt_task, done = x
        key, k_eps, k_act, k_smp = jax.random.split(ts.key, 4)

        sv = state_vector(spec, feat, cfg.backlog_scale, plat, task)
        frac = jnp.minimum(
            1.0, ts.env_steps.astype(jnp.float32)
            / max(cfg.eps_decay_steps, 1))
        eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
        explore = jax.random.uniform(k_eps) < eps
        greedy = jnp.argmax(qnet_apply(ts.eval_p, sv))
        action = jnp.where(
            explore, jax.random.randint(k_act, (), 0, n_actions),
            greedy).astype(jnp.int32)

        plat2, rec = platform_step(spec, plat, task, action)
        reward = reward_from_states(spec, plat, plat2)
        nsv = state_vector(spec, feat, cfg.backlog_scale, plat2, nxt_task)

        valid = task.valid
        replay = device_replay_add(ts.replay, sv, action, reward, nsv,
                                   done.astype(jnp.float32), write=valid)
        env_steps = ts.env_steps + valid.astype(jnp.int32)
        do_update = (valid & (replay.size >= cfg.min_replay)
                     & (env_steps % cfg.update_every == 0))

        def upd(_):
            batch = device_replay_sample(replay, k_smp, cfg.batch_size)
            new_p, new_opt, loss = dqn_td_update(
                ts.eval_p, ts.targ_p, ts.opt, batch,
                gamma=cfg.gamma, lr=cfg.lr)
            updates = ts.updates + 1
            sync = (updates % cfg.target_sync_every) == 0
            targ = jax.tree_util.tree_map(
                lambda t, e: jnp.where(sync, e, t), ts.targ_p, new_p)
            return new_p, targ, new_opt, updates, loss

        def skip(_):
            return (ts.eval_p, ts.targ_p, ts.opt, ts.updates,
                    jnp.float32(0.0))

        eval_p, targ_p, opt, updates, loss = jax.lax.cond(
            do_update, upd, skip, None)
        ts2 = TrainState(eval_p=eval_p, targ_p=targ_p, opt=opt,
                         replay=replay, env_steps=env_steps,
                         updates=updates, key=key)
        return (ts2, plat2), (rec, loss, do_update)

    def run(ts: TrainState, tasks: TaskArrays):
        # S_{i+1} pairs with the *next valid* task; the last valid task
        # pairs with itself and carries done=True, matching the Python
        # loop — on padded routes the terminal transition must not
        # bootstrap from a padding row
        next_valid = jnp.concatenate(
            [tasks.valid[1:], jnp.zeros((1,), bool)])
        nxt = jax.tree_util.tree_map(
            lambda a: jnp.where(next_valid,
                                jnp.concatenate([a[1:], a[-1:]]), a),
            tasks)
        t = tasks.arrival.shape[0]
        done = jnp.arange(t) == tasks.valid.sum() - 1
        (ts_f, plat_f), (recs, losses, upd_mask) = jax.lax.scan(
            body, (ts, platform_init(spec.n)), (tasks, nxt, done))
        return ts_f, plat_f, recs, losses, upd_mask

    return run


def make_train_fn(spec: PlatformSpec, cfg, batched: bool = False):
    """Compile the fused training episode for a ``FlexAIConfig``-shaped
    ``cfg`` (gamma, lr, batch_size, min_replay, target_sync_every,
    eps_start/end/decay_steps, update_every, backlog_scale).

    Returns ``fn(train_state, tasks) -> (train_state, platform_state,
    records, losses, update_mask)``.  ``batched=True`` vmaps over lanes:
    stacked TrainState (independent seeds) x stacked routes.
    """
    # note: no buffer donation — at init eval_p and targ_p alias the same
    # arrays, and donating an aliased pytree is an XLA error
    run = _train_run(spec, cfg)
    if batched:
        run = jax.vmap(run, in_axes=(0, 0))
    return jax.jit(run)


def make_sharded_train_fn(spec: PlatformSpec, cfg, mesh,
                          axis: str = "routes"):
    """Compile the multi-device fused training episode: stacked lanes
    (TrainState x routes) shard over ``mesh``'s ``axis``, each device
    training its local lanes' independent agents in one scan.

    The lane count must be a multiple of the mesh size.  Lanes never
    communicate (independent seeds, per-lane replay rings), so this is the
    population-training analogue of :func:`make_sharded_schedule_fn`.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    run = jax.vmap(_train_run(spec, cfg), in_axes=(0, 0))
    sharded = shard_map(run, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# host-side wrapper
# ---------------------------------------------------------------------------

class ScanFlexAI:
    """FlexAI with the device-resident engine: ``FlexAIAgent``'s surface
    (train over queues, greedy schedule, weight export) at one device
    dispatch per route — or per route *batch* with ``lanes > 1``.

    With ``mesh`` (a 1-D device mesh), the lane batch is sharded over the
    mesh: each device trains ``lanes / mesh.size`` independent agents.
    """

    def __init__(self, platform, cfg, lanes: int = 1, mesh=None):
        self.cfg = cfg
        self.spec = spec_from_platform(platform)
        self.n_actions = platform.n
        self.state_dim = 3 + 5 * platform.n
        self.lanes = lanes
        self.mesh = mesh
        key = jax.random.PRNGKey(cfg.seed)
        if lanes == 1:
            self.ts = train_init(key, self.state_dim, self.n_actions,
                                 cfg.replay_capacity)
        else:
            self.ts = jax.vmap(
                lambda k: train_init(k, self.state_dim, self.n_actions,
                                     cfg.replay_capacity)
            )(jax.random.split(key, lanes))
        if mesh is not None:
            # lanes == 1 keeps an unstacked TrainState, which the vmapped
            # sharded runner cannot consume — and a sharded single lane is
            # pointless anyway
            if lanes < 2 or lanes % mesh.size:
                raise ValueError(
                    f"lanes={lanes} must be >= 2 and a multiple of the "
                    f"mesh size {mesh.size} (omit mesh for single-lane)")
            self._train_fn = make_sharded_train_fn(self.spec, cfg, mesh,
                                                   axis=mesh.axis_names[0])
        else:
            self._train_fn = make_train_fn(self.spec, cfg,
                                           batched=lanes > 1)
        self._sched_fn = make_schedule_fn(self.spec, cfg.backlog_scale)
        self.losses: list[float] = []

    def _as_arrays(self, tasks) -> TaskArrays:
        return tasks if isinstance(tasks, TaskArrays) else \
            tasks_to_arrays(tasks)

    def train_episode(self, tasks) -> dict:
        """One fused episode (single-lane) or one episode per lane
        (``tasks`` as a list of routes / stacked TaskArrays)."""
        if self.lanes > 1:
            ta = tasks if isinstance(tasks, TaskArrays) else \
                stack_task_arrays([self._as_arrays(q) for q in tasks])
        else:
            ta = self._as_arrays(tasks)
        self.ts, plat, recs, losses, upd = self._train_fn(self.ts, ta)
        losses, upd = np.asarray(losses), np.asarray(upd, bool)
        if upd.any():
            self.losses.extend(losses[upd].tolist())
        if self.lanes > 1:
            summ = []
            for i in range(self.lanes):
                lane = summarize(
                    self.spec,
                    jax.tree_util.tree_map(lambda a, i=i: a[i], plat),
                    jax.tree_util.tree_map(lambda a, i=i: a[i], recs))
                m = upd[i]
                lane["mean_loss"] = (float(losses[i][m].mean())
                                     if m.any() else None)
                summ.append(lane)
            return {"lanes": summ}
        s = summarize(self.spec, plat, recs)
        s["mean_loss"] = float(losses[upd].mean()) if upd.any() else None
        return s

    def train(self, queues: list, episodes: int) -> list:
        """Cycle the queue pool; with ``lanes > 1`` each episode consumes
        the next ``lanes`` routes round-robin, one per lane."""
        routes = [self._as_arrays(q) for q in queues]
        history = []
        for ep in range(episodes):
            if self.lanes == 1:
                history.append(self.train_episode(routes[ep % len(routes)]))
            else:
                lane_routes = [
                    routes[(ep * self.lanes + i) % len(routes)]
                    for i in range(self.lanes)]
                history.append(self.train_episode(lane_routes))
        return history

    def eval_params(self, lane: int = 0) -> DQNParams:
        if self.lanes == 1:
            return self.ts.eval_p
        return jax.tree_util.tree_map(lambda a: a[lane], self.ts.eval_p)

    def schedule(self, tasks, lane: int = 0) -> dict:
        ta = self._as_arrays(tasks)
        t0 = time.perf_counter()
        final, recs = self._sched_fn(self.eval_params(lane), ta)
        jax.block_until_ready(final)
        dt = time.perf_counter() - t0
        summ = summarize(self.spec, final, recs)
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(ta.num_tasks, 1)
        summ["placements"] = np.asarray(recs.action)
        return summ
