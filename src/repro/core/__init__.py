"""The paper's contribution: HMAI heterogeneous accelerator platform,
system design criteria (Matching Score / Gvalue), the dynamic driving
environment, and the FlexAI RL scheduler."""

from repro.core.taxonomy import (AcceleratorArch, DataProcessing,
                                 Propagation, RegisterAlloc, TAXONOMY)
from repro.core.criteria import (rss_safe_distance, rss_safety_time,
                                 matching_score_det, matching_score_tra,
                                 gvalue)
from repro.core.tasks import Task, TaskKind, task_features
from repro.core.hmai import (AcceleratorSpec, HMAIPlatform, HMAI_CONFIG,
                             ACCELERATOR_SPECS, accelerator_fps)
from repro.core.environment import (DrivingEnvironment, EnvironmentParams,
                                    Area, Scenario, CameraGroup,
                                    CAMERA_GROUPS, build_task_queue)
