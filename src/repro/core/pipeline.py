"""Pipeline parallelism over the heterogeneous mesh.

The scan engines up to PR 6 place every chunk task *whole* on one
accelerator — pure data parallelism over routes.  This module refactors
the substrate to "one DAG -> pipeline stages -> accelerator groups"
(alpa-style inter-op parallelism, on the platform simulator):

* ``build_stage_plan`` — the stage-construction pass: MAC-balanced layer
  windows per kind (``tasks.stage_layer_stats``) are turned into per-stage
  exec/energy tables via architecture-affinity *share profiles*, and the
  accelerators are partitioned into stage groups by an exact bottleneck
  search over arch-class count compositions.
* ``_pipeline_run`` — the flattened single-device wavefront: one
  ``lax.scan`` over ``(task, stage)`` steps in wavefront-column order,
  with a finish *ring* carrying the producer->consumer edge (stage s of
  task k starts no earlier than stage s-1's finish plus the boundary
  reshard latency).
* ``make_sharded_pipeline_fn`` — the same wavefront over a 2-D
  ``("stages", "routes")`` mesh: each stage group runs on its own device
  shard and the finish ring travels through ``lax.ppermute`` — the
  cross-mesh resharding collective.  Bit-exact against the flattened
  engine (group-masked policies, order-independent observations).
* ``_pipeline_reference_run`` — the unpipelined task-major reference
  (stages unrolled per task): the parity oracle for both engines.
* stage-level FlexAI: the action space places *stages*; the observation
  (``platform_jax.stage_state_vector``, ``4 + 6n``) gains stage-occupancy
  features and a group-membership mask.  Scan (single-lane / population)
  and data-parallel (chunked-collective) training paths mirror
  ``flexai/engine.py``; ``PipelineFlexAI`` is the host wrapper.

Why per-stage shares differ per architecture: Table 8 gives whole-model
exec times only, so stage times are modeled as ``share(arch, stage, kind)
* exec(arch, kind)`` where the share comes from per-layer MACs weighted by
an arch-affinity efficiency profile (SconvOD favors large-spatial early
conv, MconvMC favors many-channel late layers, SconvIC is neutral).  The
shares sum to 1 over stages, so no accelerator is ever made faster in
aggregate — pipeline wins only by steering each stage to the group whose
architecture is strong on those layers.  See DESIGN.md ("Pipeline
parallelism over the heterogeneous mesh").
"""
from __future__ import annotations

import functools
import itertools
import time
from typing import NamedTuple

import jax
import jax.flatten_util  # noqa: F401  (jax.flatten_util.ravel_pytree)
import jax.numpy as jnp
import numpy as np

from repro.core.flexai.dqn import (DQNParams, adam_apply, dqn_td_grads,
                                   dqn_td_update, qnet_apply)
from repro.core.flexai.engine import TrainState, dp_train_init, train_init
from repro.core.flexai.replay import device_replay_add, device_replay_sample
from repro.core.flexai.reward import reward_from_states
from repro.core.platform_jax import (PlatformSpec, PlatformState,
                                     health_capacity, kind_feature_table,
                                     platform_init, platform_step,
                                     spec_from_platform, stage_state_vector,
                                     state_vector, summarize, with_health)
from repro.core.tasks import (KIND_ORDER, TABLE5_FPS, TaskArrays,
                              _model_stats, pad_task_arrays,
                              stack_task_arrays, stage_layer_stats,
                              tasks_to_arrays)

# Cross-stage link bandwidth for the reshard latency model (bytes/s).
# Activation payloads are sub-MB (tasks.stage_layer_stats), so at 16 GB/s
# the boundary hop is tens of microseconds — real but small next to
# capacity-scaled exec times, exactly the regime that makes inter-op
# pipelining worthwhile.
DEFAULT_LINK_BYTES_PER_S = 16e9


class StagePlan(NamedTuple):
    """Static output of the stage-construction pass (not scanned over).

    * ``stage_exec`` / ``stage_energy`` [S, n, K]: per-stage views of the
      platform tables; summing over S recovers the whole-model tables
      bit-for-nearly (shares sum to 1 in f64 before the f32 product).
    * ``groups`` [n] i32: accelerator -> stage group id.
    * ``group_mask`` [S, n] bool: row s flags stage s's accelerators.
    * ``mac_frac`` [S, K] f32: MAC fraction of stage s for each kind.
    * ``reshard_s`` [S, K] f32: seconds to move kind k's activation over
      the stage boundary AFTER stage s (last row is 0 — the output stays).
    """
    stage_exec: jax.Array
    stage_energy: jax.Array
    groups: jax.Array
    group_mask: jax.Array
    mac_frac: jax.Array
    reshard_s: jax.Array

    @property
    def n_stages(self) -> int:
        return self.stage_exec.shape[0]

    @property
    def n(self) -> int:
        return self.stage_exec.shape[1]


def stage_state_dim(n: int) -> int:
    """Observation width of the stage-placement agent (see
    ``platform_jax.stage_state_vector``)."""
    return 4 + 6 * n


def _layer_eff(arch: str, layer: dict) -> float:
    """Relative efficiency of ``arch`` on one layer, in (0, 1].

    The §5 taxonomy: SconvOD is the object-detection systolic array —
    strongest on large-spatial-reuse early conv, weak once feature maps
    shrink; MconvMC is the many-channel design — strongest on
    channel-heavy late conv / fc; SconvIC sits in between (neutral).
    ``w = macs / eff`` inflates the layers an arch is weak on, which is
    what skews its per-stage share away from the plain MAC fraction.
    """
    hw_out = layer.get("hw", 1) // max(layer.get("stride", 1), 1)
    if arch == "SconvOD":
        return float(np.clip(hw_out / 48.0, 0.25, 1.0))
    if arch == "MconvMC":
        return float(np.clip(layer.get("c_in", 1) / 256.0, 0.30, 1.0))
    return 0.65


@functools.lru_cache(maxsize=32)
def stage_share_table(arch_names: tuple, n_stages: int) -> np.ndarray:
    """[n_accel, S, K] share of each kind's exec time spent in each stage,
    per accelerator.  Rows sum to 1 over S (computed in f64), so
    ``share * exec_table`` decomposes — never rescales — Table 8."""
    splits, _, _ = stage_layer_stats(n_stages)
    stats = _model_stats()
    share = np.zeros((len(arch_names), n_stages, len(KIND_ORDER)),
                     np.float32)
    for ai, arch in enumerate(arch_names):
        for ki, kind in enumerate(KIND_ORDER):
            per_layer = stats[kind.value]["per_layer"]
            w = np.asarray(
                [l["macs"] / _layer_eff(arch, l) for l in per_layer],
                np.float64)
            tot = w.sum()
            for s in range(n_stages):
                lo, hi = int(splits[ki, s]), int(splits[ki, s + 1])
                share[ai, s, ki] = w[lo:hi].sum() / tot
    return share


def assign_stage_groups(arch_names: tuple, stage_exec: np.ndarray,
                        kind_weights: np.ndarray) -> np.ndarray:
    """Exact bottleneck-optimal partition of accelerators into stage
    groups.

    Same-arch accelerators are interchangeable, so the search enumerates
    *count compositions* per arch class (how many of each class serve each
    stage) instead of the 11^S assignment space — ~10^2..10^4 candidates.
    Score = min over stages of the group's aggregate service rate
    ``sum 1/tbar`` where ``tbar`` is the kind-mix-weighted stage time; the
    argmax is the steady-state pipeline throughput bound.
    """
    S = stage_exec.shape[0]
    classes: dict = {}
    for i, nm in enumerate(arch_names):
        classes.setdefault(nm, []).append(i)
    cls_names = sorted(classes)
    w = np.asarray(kind_weights, np.float64)
    tbar = (stage_exec.astype(np.float64) * w[None, None, :]).sum(-1)

    def comps(m: int, k: int):
        if k == 1:
            yield (m,)
            return
        for first in range(m + 1):
            for rest in comps(m - first, k - 1):
                yield (first,) + rest

    best = None
    for combo in itertools.product(
            *[list(comps(len(classes[nm]), S)) for nm in cls_names]):
        counts = np.asarray(combo)                       # [n_cls, S]
        if (counts.sum(0) == 0).any():
            continue
        rate = np.zeros(S)
        for ci, nm in enumerate(cls_names):
            rate += counts[ci] / tbar[:, classes[nm][0]]
        score = rate.min()
        if best is None or score > best[0]:
            best = (score, counts)
    if best is None:
        raise ValueError(
            f"cannot form {S} non-empty stage groups from "
            f"{len(arch_names)} accelerators")
    counts = best[1]
    groups = np.zeros(len(arch_names), np.int64)
    for ci, nm in enumerate(cls_names):
        members, off = classes[nm], 0
        for s in range(S):
            for _ in range(int(counts[ci, s])):
                groups[members[off]] = s
                off += 1
    return groups.astype(np.int32)


def build_stage_plan(platform, n_stages: int, groups=None,
                     link_bytes_per_s: float = DEFAULT_LINK_BYTES_PER_S,
                     kind_weights=None) -> StagePlan:
    """Stage-construction pass: ``HMAIPlatform`` + stage count ->
    :class:`StagePlan`.  ``groups`` overrides the partition search with an
    explicit [n] stage-id assignment."""
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    arch_names = tuple(s.name for s in platform.specs)
    exec_table = np.asarray(platform.exec_time_table, np.float32)
    energy_table = np.asarray(platform.energy_table, np.float32)
    share = stage_share_table(arch_names, n_stages)      # [n, S, K]
    stage_exec = np.swapaxes(share, 0, 1) * exec_table[None]
    stage_energy = np.swapaxes(share, 0, 1) * energy_table[None]
    if kind_weights is None:
        kw = np.asarray([TABLE5_FPS[k] for k in KIND_ORDER], np.float64)
        kind_weights = kw / kw.sum()
    if groups is None:
        groups = assign_stage_groups(arch_names, stage_exec, kind_weights)
    groups = np.asarray(groups, np.int32)
    if groups.shape != (len(arch_names),):
        raise ValueError(f"groups must be [{len(arch_names)}]")
    present = np.unique(groups)
    if present.min() < 0 or present.max() >= n_stages or \
            len(present) != n_stages:
        raise ValueError(
            f"groups must cover every stage id in [0, {n_stages})")
    _, frac, act = stage_layer_stats(n_stages)           # [K, S] each
    reshard = act.T.astype(np.float32) / float(link_bytes_per_s)
    mask = groups[None, :] == np.arange(n_stages)[:, None]
    return StagePlan(
        stage_exec=jnp.asarray(stage_exec, jnp.float32),
        stage_energy=jnp.asarray(stage_energy, jnp.float32),
        groups=jnp.asarray(groups),
        group_mask=jnp.asarray(mask),
        mac_frac=jnp.asarray(frac.T, jnp.float32),
        reshard_s=jnp.asarray(reshard))


def stage_spec(spec: PlatformSpec, plan: StagePlan, s) -> PlatformSpec:
    """Per-stage view of the platform tables.  ``platform_step`` runs on
    it unchanged — a stage sub-task is just a task with stage-sized
    exec/energy columns.  The gvalue scales stay whole-model so rewards
    and summaries remain comparable across stage counts."""
    return PlatformSpec(
        exec_time=plan.stage_exec[s], energy=plan.stage_energy[s],
        gvalue_e_scale=spec.gvalue_e_scale,
        gvalue_t_scale=spec.gvalue_t_scale)


def _stage_task_view(plan: StagePlan, ring: jax.Array, row: TaskArrays,
                     s) -> TaskArrays:
    """Rewrite one task row as its stage-``s`` sub-task.

    Arrival becomes the upstream stage's finish (the ring entry written
    one wavefront column earlier) plus the boundary reshard latency, and
    the safety budget shrinks by the induced delay — so the FINAL stage's
    ``met`` is exactly the end-to-end deadline check.
    """
    prev = jnp.maximum(s - 1, 0)
    arrival = jnp.where(jnp.equal(s, 0), row.arrival,
                        ring[prev] + plan.reshard_s[prev, row.kind])
    return row._replace(arrival=arrival,
                        safety=row.safety - (arrival - row.arrival))


# ---------------------------------------------------------------------------
# placement policies (shared by every engine; all group-masked)
# ---------------------------------------------------------------------------

def _make_policy(policy: str, spec: PlatformSpec, plan: StagePlan,
                 backlog_scale: float):
    """``act(params, sp, state, trow, s) -> action`` closures.

    * ``"eft"``    — earliest finish time within the stage group (the
      heuristic baseline; params ignored).
    * ``"flexai"`` — greedy stage-placement Q argmax, masked to the group.
    * ``"task"``   — the ORIGINAL task-level observation + unmasked argmax
      (``_schedule_run``'s body verbatim).  Only meaningful with a 1-stage
      plan, where it makes the pipeline engines reproduce the existing
      data-parallel engine bit-exactly (the equivalence test).
    """
    feat = jnp.asarray(kind_feature_table())

    if policy == "eft":
        def act(params, sp, state, trow, s):
            # health-effective finish times: dead cores pay 1/HEALTH_FLOOR
            # so the argmin routes around them without shrinking the group
            # mask (an all-dead group still yields an in-group action);
            # all-healthy divides by exactly 1.0 — the pre-fault argmin
            ct = jnp.maximum(trow.arrival, state.avail) \
                + sp.exec_time[:, trow.kind] / health_capacity(state)
            ct = jnp.where(plan.group_mask[s], ct, jnp.inf)
            return jnp.argmin(ct).astype(jnp.int32)
    elif policy == "flexai":
        def act(params, sp, state, trow, s):
            sv = stage_state_vector(
                spec, feat, backlog_scale, state, trow,
                stage_exec=sp.exec_time,
                mac_frac=plan.mac_frac[s, trow.kind],
                group_mask=plan.group_mask[s],
                stage_frac=s.astype(jnp.float32) if hasattr(s, "astype")
                else jnp.float32(s))
            # mask to live group members; if the whole group is down fall
            # back to the bare group mask (least-bad in-group placement)
            gmask = plan.group_mask[s] & state.alive
            gmask = jnp.where(gmask.any(), gmask, plan.group_mask[s])
            q = jnp.where(gmask, qnet_apply(params, sv), -jnp.inf)
            return jnp.argmax(q).astype(jnp.int32)
    elif policy == "task":
        def act(params, sp, state, trow, s):
            sv = state_vector(spec, feat, backlog_scale, state, trow)
            amask = jnp.where(state.alive.any(), state.alive,
                              jnp.ones_like(state.alive))
            q = jnp.where(amask, qnet_apply(params, sv), -jnp.inf)
            return jnp.argmax(q).astype(jnp.int32)
    else:
        raise ValueError(f"unknown pipeline policy {policy!r}")
    return act


def _stage_obs(spec, plan, feat, backlog_scale, state, ring, row, s):
    """(stage sub-task view, stage observation) for the training paths."""
    S = plan.stage_exec.shape[0]
    trow = _stage_task_view(plan, ring, row, s)
    sv = stage_state_vector(
        spec, feat, backlog_scale, state, trow,
        stage_exec=plan.stage_exec[s],
        mac_frac=plan.mac_frac[s, row.kind],
        group_mask=plan.group_mask[s],
        stage_frac=s.astype(jnp.float32) / S)
    return trow, sv


# ---------------------------------------------------------------------------
# wavefront stream layout
# ---------------------------------------------------------------------------

def _wavefront_stream(tasks: TaskArrays, S: int):
    """Flatten a [T]-task route into the [(T+S-1)*S] wavefront stream.

    Column c holds steps (k = c - s, s); within a column stages run
    DESCENDING so stage s reads ring[s-1] (written at column c-1) before
    stage s-1 overwrites it — the single-device serialization of the
    per-column parallel wavefront.  Out-of-range corners become invalid
    rows (clip-gathered, state passthrough).
    """
    T = tasks.arrival.shape[0]
    C = T + S - 1
    s_seq = jnp.tile(jnp.arange(S - 1, -1, -1), C)
    k_seq = jnp.repeat(jnp.arange(C), S) - s_seq
    ok = (k_seq >= 0) & (k_seq < T)
    rows = jax.tree_util.tree_map(
        lambda a: a[jnp.clip(k_seq, 0, T - 1)], tasks)
    return rows._replace(valid=rows.valid & ok), s_seq


def _record_order(T: int, S: int) -> jax.Array:
    """[T, S] gather indices mapping the flat wavefront record stream back
    to task-major ``recs[k, s]`` (step (k, s) ran at flat position
    ``(k+s)*S + (S-1-s)``)."""
    k = jnp.arange(T)[:, None]
    s = jnp.arange(S)[None, :]
    return (k + s) * S + (S - 1 - s)


# ---------------------------------------------------------------------------
# inference engines
# ---------------------------------------------------------------------------

def _pipeline_segment_run(spec: PlatformSpec, plan: StagePlan,
                          backlog_scale: float = 1.0,
                          policy: str = "flexai"):
    """Un-jitted runner over a PRE-FLATTENED wavefront segment: the
    serving seam.  ``run(params, rows, s_seq, state0, ring0) -> (state,
    ring, recs)`` — QoS waves slice the flat stream into micro-batch
    segments and checkpoint ``(state, ring)`` at the (stage-boundary)
    segment cuts."""
    act = _make_policy(policy, spec, plan, backlog_scale)
    S = int(plan.stage_exec.shape[0])

    def body(params, carry, x):
        state, ring = carry
        row, s, hrow = x
        # health rows are indexed by TASK: every stage of task k installs
        # row k before acting, so the wavefront interleaving and the
        # task-major reference agree step-for-step under the same trace
        state = with_health(state, hrow)
        sp = stage_spec(spec, plan, s)
        trow = _stage_task_view(plan, ring, row, s)
        action = act(params, sp, state, trow, s)
        state2, rec = platform_step(sp, state, trow, action)
        ring2 = ring.at[s].set(jnp.where(row.valid, rec.finish, ring[s]))
        return (state2, ring2), rec

    def run(params, rows, s_seq, state0=None, ring0=None, health=None):
        init = platform_init(spec.n) if state0 is None else state0
        ring = jnp.zeros((S,), jnp.float32) if ring0 is None else ring0
        trace = (jnp.ones((rows.arrival.shape[0], spec.n), jnp.float32)
                 if health is None else jnp.asarray(health, jnp.float32))
        (final, ringf), recs = jax.lax.scan(
            functools.partial(body, params), (init, ring),
            (rows, s_seq, trace))
        return final, ringf, recs

    return run


def _pipeline_run(spec: PlatformSpec, plan: StagePlan,
                  backlog_scale: float = 1.0, policy: str = "flexai"):
    """Un-jitted full-route wavefront episode: flatten, scan, regather.
    ``run(params, tasks) -> (final_state, ring, recs[T, S])``."""
    seg = _pipeline_segment_run(spec, plan, backlog_scale, policy)
    S = int(plan.stage_exec.shape[0])

    def run(params, tasks: TaskArrays, state0=None, ring0=None,
            health=None):
        T = tasks.arrival.shape[0]
        rows, s_seq = _wavefront_stream(tasks, S)
        hflat = None
        if health is not None:
            # [T, n] task-indexed trace -> flat wavefront order (the
            # clip-gather mirrors _wavefront_stream; corner rows are
            # overwritten before any later action, so clipping is safe)
            k_seq = jnp.repeat(jnp.arange(T + S - 1), S) \
                - jnp.tile(jnp.arange(S - 1, -1, -1), T + S - 1)
            hflat = jnp.asarray(health, jnp.float32)[
                jnp.clip(k_seq, 0, T - 1)]
        final, ring, recs = seg(params, rows, s_seq, state0, ring0,
                                health=hflat)
        recs = jax.tree_util.tree_map(
            lambda a: a[_record_order(T, S)], recs)
        return final, ring, recs

    return run


def make_pipeline_schedule_fn(spec: PlatformSpec, plan: StagePlan,
                              backlog_scale: float = 1.0,
                              policy: str = "flexai",
                              batched: bool = False):
    """Compile the flattened wavefront scheduler; ``batched=True`` vmaps a
    [R, T] route batch (params shared)."""
    run = _pipeline_run(spec, plan, backlog_scale, policy)
    if batched:
        single = run

        def run(params, tasks, health=None):
            if health is None:
                return jax.vmap(single, in_axes=(None, 0))(params, tasks)
            return jax.vmap(lambda p, t, h: single(p, t, health=h),
                            in_axes=(None, 0, 0))(params, tasks, health)
    return jax.jit(run)


def _pipeline_reference_run(spec: PlatformSpec, plan: StagePlan,
                            backlog_scale: float = 1.0,
                            policy: str = "flexai"):
    """Unpipelined task-major reference: every task runs all S stages to
    completion before the next task starts (stages unrolled in the scan
    body).  Per-group commit sequences are identical to the wavefront's,
    so final states and records match the pipelined engines bit-exactly —
    the parity oracle of the ISSUE-7 contract."""
    act = _make_policy(policy, spec, plan, backlog_scale)
    S = int(plan.stage_exec.shape[0])

    def body(params, carry, x):
        row, hrow = x
        state, ring = carry
        state = with_health(state, hrow)
        out = []
        for s_i in range(S):
            s = jnp.int32(s_i)
            sp = stage_spec(spec, plan, s)
            trow = _stage_task_view(plan, ring, row, s)
            action = act(params, sp, state, trow, s)
            state, rec = platform_step(sp, state, trow, action)
            ring = ring.at[s_i].set(
                jnp.where(row.valid, rec.finish, ring[s_i]))
            out.append(rec)
        recs = jax.tree_util.tree_map(lambda *r: jnp.stack(r), *out)
        return (state, ring), recs

    def run(params, tasks: TaskArrays, health=None):
        t = tasks.arrival.shape[0]
        trace = (jnp.ones((t, spec.n), jnp.float32) if health is None
                 else jnp.asarray(health, jnp.float32))
        init = (platform_init(spec.n), jnp.zeros((S,), jnp.float32))
        (final, ring), recs = jax.lax.scan(
            functools.partial(body, params), init, (tasks, trace))
        return final, ring, recs

    return run


def make_pipeline_reference_fn(spec: PlatformSpec, plan: StagePlan,
                               backlog_scale: float = 1.0,
                               policy: str = "flexai",
                               batched: bool = False):
    run = _pipeline_reference_run(spec, plan, backlog_scale, policy)
    if batched:
        single = run

        def run(params, tasks, health=None):
            if health is None:
                return jax.vmap(single, in_axes=(None, 0))(params, tasks)
            return jax.vmap(lambda p, t, h: single(p, t, health=h),
                            in_axes=(None, 0, 0))(params, tasks, health)
    return jax.jit(run)


def make_sharded_pipeline_fn(spec: PlatformSpec, plan: StagePlan, mesh,
                             backlog_scale: float = 1.0,
                             policy: str = "flexai",
                             stage_axis: str = "stages",
                             route_axis: str = "routes"):
    """Compile the stage-sharded wavefront over a 2-D ``(stages, routes)``
    mesh: each stage group runs on its own device shard, scanning
    wavefront columns over its local routes, and the finish ring hops
    stage s -> s+1 through ``lax.ppermute`` after every column — the
    cross-mesh resharding collective (the payload whose latency
    ``plan.reshard_s`` charges to the downstream arrival).

    ``fn(params, tasks[R, T]) -> (states [S, R, ...], ring [S, R],
    recs [S, R, T])`` where ``recs[s, r, k]`` equals the flattened
    engine's ``recs[r][k, s]`` bit-exactly and
    :func:`combine_stage_states` folds the per-shard states back into the
    global platform state.  R must be a multiple of the route-axis size
    (``tasks.pad_route_batch``).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    S = int(plan.stage_exec.shape[0])
    if mesh.shape[stage_axis] != S:
        raise ValueError(
            f"mesh axis {stage_axis!r} has size {mesh.shape[stage_axis]}, "
            f"plan has {S} stages")
    act = _make_policy(policy, spec, plan, backlog_scale)

    def block(params, tasks: TaskArrays):
        my_s = jax.lax.axis_index(stage_axis)
        R, T = tasks.arrival.shape
        C = T + S - 1
        sp = stage_spec(spec, plan, my_s)

        def col(carry, c):
            states, ring, recv = carry
            k = c - my_s
            okc = (k >= 0) & (k < T)
            rows = jax.tree_util.tree_map(
                lambda a: a[:, jnp.clip(k, 0, T - 1)], tasks)
            rows = rows._replace(valid=rows.valid & okc)

            def one(state, row, rv):
                prev = jnp.maximum(my_s - 1, 0)
                arrival = jnp.where(
                    jnp.equal(my_s, 0), row.arrival,
                    rv + plan.reshard_s[prev, row.kind])
                trow = row._replace(
                    arrival=arrival,
                    safety=row.safety - (arrival - row.arrival))
                action = act(params, sp, state, trow, my_s)
                return platform_step(sp, state, trow, action)

            states2, recs = jax.vmap(one)(states, rows, recv)
            ring2 = jnp.where(rows.valid, recs.finish, ring)
            if S > 1:
                nxt = jax.lax.ppermute(
                    ring2, stage_axis, [(i, i + 1) for i in range(S - 1)])
            else:
                nxt = recv
            return (states2, ring2, nxt), recs

        states0 = jax.vmap(lambda _: platform_init(spec.n))(jnp.arange(R))
        z = jnp.zeros((R,), jnp.float32)
        (statesF, ringF, _), recs = jax.lax.scan(
            col, (states0, z, z), jnp.arange(C))
        recs = jax.tree_util.tree_map(
            lambda a: jnp.moveaxis(a, 0, 1), recs)          # [R, C]
        cols = my_s + jnp.arange(T)                          # own diagonal
        recs = jax.tree_util.tree_map(lambda a: a[:, cols], recs)
        lead = lambda a: a[None]  # noqa: E731
        return (jax.tree_util.tree_map(lead, statesF), ringF[None],
                jax.tree_util.tree_map(lead, recs))

    sharded = shard_map(
        block, mesh=mesh, in_specs=(P(), P(route_axis)),
        out_specs=(P(stage_axis, route_axis), P(stage_axis, route_axis),
                   P(stage_axis, route_axis)))
    return jax.jit(sharded)


def combine_stage_states(plan: StagePlan, states: PlatformState
                         ) -> PlatformState:
    """Fold per-stage-shard states ([S, ...] leading axis, optional route
    axis next) into the global platform state: accelerator i's row comes
    from its own group's shard, and the running scales are recomputed —
    they equal the flattened engine's finals because both are running
    maxima of monotone totals."""
    idx = jnp.arange(plan.groups.shape[0])

    def pick(a):
        b = jnp.moveaxis(a, 0, -1)                   # [..., n, S]
        return b[..., idx, plan.groups]

    E, T = pick(states.E), pick(states.T)
    return PlatformState(
        avail=pick(states.avail), busy=pick(states.busy), E=E, T=T,
        MS=pick(states.MS), R_Balance=pick(states.R_Balance),
        num_tasks=pick(states.num_tasks),
        e_scale=jnp.maximum(jnp.float32(1e-9), E.sum(-1)),
        t_scale=jnp.maximum(jnp.float32(1e-9), T.max(-1)),
        alive=pick(states.alive), cap=pick(states.cap))


def pipeline_summarize(spec: PlatformSpec, state: PlatformState,
                       recs) -> dict:
    """Route summary from [.., T, S] stage records: end-to-end verdicts
    (met/response/wait) come from the FINAL stage, whose safety budget
    already absorbed every upstream delay."""
    last = jax.tree_util.tree_map(lambda a: a[..., -1], recs)
    summ = summarize(spec, state, last)
    summ["stages"] = int(recs.valid.shape[-1])
    return summ


# ---------------------------------------------------------------------------
# stage-level FlexAI training
# ---------------------------------------------------------------------------

def _next_valid_flat(valid: jax.Array):
    """Per flat step i: index of the next valid step (> i), self + done
    when none remains — the wavefront analogue of ``_train_run``'s
    next-task pairing.  State/ring never change across the skipped invalid
    corners, so bootstrapping with the CURRENT post-step state is exact.
    ``valid`` may carry leading batch axes; the scan runs on the last."""
    L = valid.shape[-1]
    ar = jnp.arange(L)
    pos = jnp.where(valid, ar, L)
    suff = jax.lax.associative_scan(jnp.minimum, pos, reverse=True,
                                    axis=pos.ndim - 1)
    nv = jnp.concatenate(
        [suff[..., 1:], jnp.full(valid.shape[:-1] + (1,), L, suff.dtype)],
        axis=-1)
    done = valid & (nv >= L)
    return jnp.where(nv >= L, ar, nv), done


def _pipeline_train_run(spec: PlatformSpec, plan: StagePlan, cfg):
    """Single-lane fused stage-placement training episode: ``_train_run``
    on the flattened wavefront stream.  Exploration samples uniformly
    WITHIN the stage group (a stage action outside its group is not in
    the action support), greedy is the group-masked Q argmax."""
    feat = jnp.asarray(kind_feature_table())
    n_actions = spec.n
    S = int(plan.stage_exec.shape[0])

    def body(carry, x):
        ts, plat, ring, sv = carry
        row, s, nrow, ns, done = x
        key, k_eps, k_act, k_smp = jax.random.split(ts.key, 4)

        frac = jnp.minimum(
            1.0, ts.env_steps.astype(jnp.float32)
            / max(cfg.eps_decay_steps, 1))
        eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
        maskf = plan.group_mask[s].astype(jnp.float32)
        explore = jax.random.uniform(k_eps) < eps
        greedy = jnp.argmax(jnp.where(plan.group_mask[s],
                                      qnet_apply(ts.eval_p, sv), -jnp.inf))
        rand = jax.random.choice(k_act, n_actions, p=maskf / maskf.sum())
        action = jnp.where(explore, rand, greedy).astype(jnp.int32)

        sp = stage_spec(spec, plan, s)
        trow = _stage_task_view(plan, ring, row, s)
        plat2, rec = platform_step(sp, plat, trow, action)
        ring2 = ring.at[s].set(jnp.where(row.valid, rec.finish, ring[s]))
        reward = reward_from_states(spec, plat, plat2)
        _, nsv = _stage_obs(spec, plan, feat, cfg.backlog_scale,
                            plat2, ring2, nrow, ns)

        valid = row.valid
        replay = device_replay_add(ts.replay, sv, action, reward, nsv,
                                   done.astype(jnp.float32), write=valid)
        env_steps = ts.env_steps + valid.astype(jnp.int32)
        do_update = (valid & (replay.size >= cfg.min_replay)
                     & (env_steps % cfg.update_every == 0))

        def upd(_):
            batch = device_replay_sample(replay, k_smp, cfg.batch_size)
            new_p, new_opt, loss = dqn_td_update(
                ts.eval_p, ts.targ_p, ts.opt, batch,
                gamma=cfg.gamma, lr=cfg.lr)
            updates = ts.updates + 1
            sync = (updates % cfg.target_sync_every) == 0
            targ = jax.tree_util.tree_map(
                lambda t, e: jnp.where(sync, e, t), ts.targ_p, new_p)
            return new_p, targ, new_opt, updates, loss

        def skip(_):
            return (ts.eval_p, ts.targ_p, ts.opt, ts.updates,
                    jnp.float32(0.0))

        eval_p, targ_p, opt, updates, loss = jax.lax.cond(
            do_update, upd, skip, None)
        ts2 = TrainState(eval_p=eval_p, targ_p=targ_p, opt=opt,
                         replay=replay, env_steps=env_steps,
                         updates=updates, key=key)
        return (ts2, plat2, ring2, nsv), (rec, loss, do_update)

    def run(ts: TrainState, tasks: TaskArrays):
        T = tasks.arrival.shape[0]
        rows, s_seq = _wavefront_stream(tasks, S)
        nv, done = _next_valid_flat(rows.valid)
        nrows = jax.tree_util.tree_map(lambda a: a[nv], rows)
        ns = s_seq[nv]
        plat0 = platform_init(spec.n)
        ring0 = jnp.zeros((S,), jnp.float32)
        _, sv0 = _stage_obs(
            spec, plan, feat, cfg.backlog_scale, plat0, ring0,
            jax.tree_util.tree_map(lambda a: a[0], rows), s_seq[0])
        (ts_f, plat_f, _, _), (recs, losses, upd) = jax.lax.scan(
            body, (ts, plat0, ring0, sv0), (rows, s_seq, nrows, ns, done))
        recs = jax.tree_util.tree_map(
            lambda a: a[_record_order(T, S)], recs)
        return ts_f, plat_f, recs, losses, upd

    return run


def make_pipeline_train_fn(spec: PlatformSpec, plan: StagePlan, cfg,
                           batched: bool = False):
    """Compile the fused stage-placement trainer; ``batched=True`` vmaps
    independent population lanes (stacked TrainState x stacked routes)."""
    run = _pipeline_train_run(spec, plan, cfg)
    if batched:
        run = jax.vmap(run, in_axes=(0, 0))
    return jax.jit(run)


def make_sharded_pipeline_train_fn(spec: PlatformSpec, plan: StagePlan,
                                   cfg, mesh, axis: str = "routes"):
    """Population training sharded over ``axis``: independent per-lane
    stage agents, no collectives (the pipeline analogue of
    ``make_sharded_train_fn``)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    run = jax.vmap(_pipeline_train_run(spec, plan, cfg), in_axes=(0, 0))
    sharded = shard_map(run, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis))
    return jax.jit(sharded)


def _pipeline_dp_train_run(spec: PlatformSpec, plan: StagePlan, cfg,
                           lanes: int, axis=None, n_shards: int = 1):
    """Data-parallel stage-placement training: ONE synchronized agent over
    ``lanes`` local route lanes (x ``n_shards`` devices), the pipeline
    analogue of ``_dp_train_run`` — with the chunked-collective layout:
    a tiny per-step stats psum gates the update, and the gradient
    all-reduce + Adam step run inside ``lax.cond`` only on optimizer
    steps (the predicate is shard-uniform by construction, so every shard
    takes the same branch and the conditional collective cannot
    deadlock)."""
    feat = jnp.asarray(kind_feature_table())
    n_actions = spec.n
    S = int(plan.stage_exec.shape[0])

    if axis is None:
        psum = pmean = lambda x: x
        n_shards = 1
    else:
        psum = functools.partial(jax.lax.psum, axis_name=axis)
        pmean = functools.partial(jax.lax.pmean, axis_name=axis)

    def body(gidx, carry, x):
        ts, plats, rings, svs = carry
        row, s, nrow, ns, done = x          # row leaves [lanes]; s scalar
        key, k_eps, k_act, k_smp = jax.random.split(ts.key, 4)

        def lane_keys(k):
            ks = jax.vmap(lambda g: jax.random.fold_in(k, g))(gidx)
            return jnp.where((gidx == 0)[:, None], k[None, :], ks)

        frac = jnp.minimum(
            1.0, ts.env_steps.astype(jnp.float32)
            / max(cfg.eps_decay_steps, 1))
        eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
        sp = stage_spec(spec, plan, s)
        maskf = plan.group_mask[s].astype(jnp.float32)

        def act_step(plat, ring, sv, row_l, nrow_l, ns_l, ke, ka):
            explore = jax.random.uniform(ke) < eps
            greedy = jnp.argmax(jnp.where(
                plan.group_mask[s], qnet_apply(ts.eval_p, sv), -jnp.inf))
            rand = jax.random.choice(ka, n_actions, p=maskf / maskf.sum())
            action = jnp.where(explore, rand, greedy).astype(jnp.int32)
            trow = _stage_task_view(plan, ring, row_l, s)
            plat2, rec = platform_step(sp, plat, trow, action)
            ring2 = ring.at[s].set(
                jnp.where(row_l.valid, rec.finish, ring[s]))
            reward = reward_from_states(spec, plat, plat2)
            _, nsv = _stage_obs(spec, plan, feat, cfg.backlog_scale,
                                plat2, ring2, nrow_l, ns_l)
            return plat2, ring2, rec, action, reward, nsv

        plats2, rings2, recs, actions, rewards, nsvs = jax.vmap(act_step)(
            plats, rings, svs, row, nrow, ns,
            lane_keys(k_eps), lane_keys(k_act))
        replay = jax.vmap(device_replay_add)(
            ts.replay, svs, actions, rewards, nsvs,
            done.astype(jnp.float32), row.valid)

        # chunked collectives: only the 2-float gate stats all-reduce
        # every step; the gradient all-reduce waits for an optimizer step
        stats = psum(jnp.stack([
            row.valid.astype(jnp.float32).sum(),
            (replay.size.min() >= cfg.min_replay).astype(jnp.float32)]))
        env_steps = ts.env_steps + stats[0].astype(jnp.int32)
        crossed = (env_steps // cfg.update_every
                   > ts.env_steps // cfg.update_every)
        do_update = crossed & (stats[1] == float(n_shards))

        def upd(_):
            batches = jax.vmap(
                lambda b, k: device_replay_sample(b, k, cfg.batch_size)
            )(replay, lane_keys(k_smp))
            losses, grads = jax.vmap(
                lambda b: dqn_td_grads(ts.eval_p, ts.targ_p, b,
                                       gamma=cfg.gamma))(batches)
            flat, unravel = jax.flatten_util.ravel_pytree(
                (losses.mean(),
                 jax.tree_util.tree_map(lambda g: g.mean(0), grads)))
            loss, g = unravel(pmean(flat))
            new_p, new_opt = adam_apply(ts.eval_p, ts.opt, g, lr=cfg.lr)
            return new_p, new_opt, loss

        def skip(_):
            return ts.eval_p, ts.opt, jnp.float32(0.0)

        eval_p, opt, loss = jax.lax.cond(do_update, upd, skip, None)
        updates = ts.updates + do_update.astype(jnp.int32)
        sync = do_update & (updates % cfg.target_sync_every == 0)
        targ_p = jax.tree_util.tree_map(
            lambda e, t: jnp.where(sync, e, t), eval_p, ts.targ_p)
        ts2 = TrainState(eval_p=eval_p, targ_p=targ_p, opt=opt,
                         replay=replay, env_steps=env_steps,
                         updates=updates, key=key)
        return (ts2, plats2, rings2, nsvs), (recs, loss, do_update)

    def run(ts: TrainState, tasks: TaskArrays):
        base = 0 if axis is None else jax.lax.axis_index(axis) * lanes
        gidx = base + jnp.arange(lanes)
        T = tasks.arrival.shape[1]
        C = T + S - 1
        L = C * S
        s_seq = jnp.tile(jnp.arange(S - 1, -1, -1), C)
        k_seq = jnp.repeat(jnp.arange(C), S) - s_seq
        ok = (k_seq >= 0) & (k_seq < T)
        rows = jax.tree_util.tree_map(
            lambda a: a[:, jnp.clip(k_seq, 0, T - 1)], tasks)
        rows = rows._replace(valid=rows.valid & ok[None, :])
        nv, done = _next_valid_flat(rows.valid)       # [lanes, L] each
        nrows = jax.tree_util.tree_map(
            lambda a: jnp.take_along_axis(a, nv, axis=1), rows)
        ns = s_seq[nv]
        plats0 = jax.vmap(lambda _: platform_init(spec.n))(jnp.arange(lanes))
        rings0 = jnp.zeros((lanes, S), jnp.float32)
        svs0 = jax.vmap(
            lambda p, r, rw: _stage_obs(spec, plan, feat, cfg.backlog_scale,
                                        p, r, rw, s_seq[0])[1]
        )(plats0, rings0, jax.tree_util.tree_map(lambda a: a[:, 0], rows))
        swap = lambda a: jnp.swapaxes(a, 0, 1)  # noqa: E731
        xs = (jax.tree_util.tree_map(swap, rows), s_seq,
              jax.tree_util.tree_map(swap, nrows), swap(ns), swap(done))
        (ts_f, plats_f, _, _), (recs, losses, upd) = jax.lax.scan(
            functools.partial(body, gidx), (ts, plats0, rings0, svs0), xs)
        recs = jax.tree_util.tree_map(
            lambda a: swap(a)[:, _record_order(T, S)], recs)
        return ts_f, plats_f, recs, losses, upd

    return run


def make_pipeline_dp_train_fn(spec: PlatformSpec, plan: StagePlan, cfg,
                              lanes: int, mesh=None,
                              axis: str = "routes"):
    """Compile the data-parallel stage trainer (contract mirrors
    ``make_dp_train_fn``: [lanes, T] route batch, shared agent, per-lane
    replay; with ``mesh`` the lane axis shards over ``axis``)."""
    if mesh is None:
        return jax.jit(_pipeline_dp_train_run(spec, plan, cfg, lanes))
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    if lanes < 1 or lanes % mesh.size:
        raise ValueError(f"lanes={lanes} must be a positive multiple of "
                         f"the mesh size {mesh.size}")
    run = _pipeline_dp_train_run(spec, plan, cfg, lanes // mesh.size,
                                 axis=axis, n_shards=mesh.size)
    ts_specs = TrainState(eval_p=P(), targ_p=P(), opt=P(), replay=P(axis),
                          env_steps=P(), updates=P(), key=P())
    sharded = shard_map(run, mesh=mesh, in_specs=(ts_specs, P(axis)),
                        out_specs=(ts_specs, P(axis), P(axis), P(), P()))
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# host-side wrapper
# ---------------------------------------------------------------------------

class PipelineFlexAI:
    """Stage-placement FlexAI on the pipeline wavefront engines:
    ``ScanFlexAI``'s train/schedule surface where the action places a
    *stage* onto its accelerator group.

    Modes mirror ``ScanFlexAI``: single lane (default), ``lanes > 1``
    population agents (optionally sharded over ``mesh``), or ``dp=True``
    for one synchronized agent trained data-parallel over a lane batch.
    """

    def __init__(self, platform, cfg, n_stages: int = 2, lanes: int = 1,
                 mesh=None, dp: bool = False, plan: StagePlan = None):
        self.cfg = cfg
        self.spec = spec_from_platform(platform)
        self.plan = plan if plan is not None \
            else build_stage_plan(platform, n_stages)
        self.n_stages = int(self.plan.stage_exec.shape[0])
        self.n_actions = platform.n
        self.state_dim = stage_state_dim(platform.n)
        self.lanes = lanes
        self.mesh = mesh
        self.dp = dp
        key = jax.random.PRNGKey(cfg.seed)
        if dp:
            self.ts = dp_train_init(key, self.state_dim, self.n_actions,
                                    cfg.replay_capacity, lanes)
            self._train_fn = make_pipeline_dp_train_fn(
                self.spec, self.plan, cfg, lanes, mesh=mesh,
                axis=mesh.axis_names[-1] if mesh is not None else "routes")
        elif lanes == 1:
            self.ts = train_init(key, self.state_dim, self.n_actions,
                                 cfg.replay_capacity)
            self._train_fn = make_pipeline_train_fn(self.spec, self.plan,
                                                    cfg)
        else:
            self.ts = jax.vmap(
                lambda k: train_init(k, self.state_dim, self.n_actions,
                                     cfg.replay_capacity)
            )(jax.random.split(key, lanes))
            if mesh is not None:
                if lanes < 2 or lanes % mesh.size:
                    raise ValueError(
                        f"lanes={lanes} must be >= 2 and a multiple of "
                        f"the mesh size {mesh.size}")
                self._train_fn = make_sharded_pipeline_train_fn(
                    self.spec, self.plan, cfg, mesh,
                    axis=mesh.axis_names[-1])
            else:
                self._train_fn = make_pipeline_train_fn(
                    self.spec, self.plan, cfg, batched=True)
        self._sched_fn = make_pipeline_schedule_fn(
            self.spec, self.plan, cfg.backlog_scale)
        self._eval_fn = None
        self.losses: list = []
        self.best_eval_stm = None
        self._best_stm: float = -1.0
        self._best_params = None

    def _as_arrays(self, tasks) -> TaskArrays:
        return tasks if isinstance(tasks, TaskArrays) else \
            tasks_to_arrays(tasks)

    def train_episode(self, tasks) -> dict:
        if self.lanes > 1 or self.dp:
            ta = tasks if isinstance(tasks, TaskArrays) else \
                stack_task_arrays([self._as_arrays(q) for q in tasks])
            if self.dp and ta.arrival.ndim == 1:
                ta = TaskArrays(*[np.asarray(f)[None] for f in ta])
        else:
            ta = self._as_arrays(tasks)
        self.ts, plat, recs, losses, upd = self._train_fn(self.ts, ta)
        losses, upd = np.asarray(losses), np.asarray(upd, bool)
        if upd.any():
            self.losses.extend(losses[upd].tolist())
        lanes_out = 1 if (self.lanes == 1 and not self.dp) else self.lanes
        if lanes_out == 1 and not self.dp:
            s = pipeline_summarize(self.spec, plat, recs)
            s["mean_loss"] = float(losses[upd].mean()) if upd.any() else None
            return s
        summ = []
        for i in range(lanes_out):
            lane = pipeline_summarize(
                self.spec,
                jax.tree_util.tree_map(lambda a, i=i: a[i], plat),
                jax.tree_util.tree_map(lambda a, i=i: a[i], recs))
            if not self.dp:
                m = upd[i]
                lane["mean_loss"] = (float(losses[i][m].mean())
                                     if m.any() else None)
            summ.append(lane)
        if self.dp:
            mean_loss = float(losses[upd].mean()) if upd.any() else None
            if lanes_out == 1:
                summ[0]["mean_loss"] = mean_loss
                return summ[0]
            return {"lanes": summ, "mean_loss": mean_loss}
        return {"lanes": summ}

    def train(self, queues: list, episodes: int, eval_queue=None,
              eval_every: int = 5) -> list:
        """Cycle the queue pool with ``ScanFlexAI.train``'s cadence and
        model selection (best-eval EvalNet restored at the end)."""
        routes = [self._as_arrays(q) for q in queues]
        if self.lanes > 1 or self.dp:
            t_max = max(r.arrival.shape[-1] for r in routes)
            routes = [pad_task_arrays(r, t_max)
                      if r.arrival.shape[-1] < t_max else r for r in routes]
        ta_eval = self._as_arrays(eval_queue) \
            if eval_queue is not None else None
        history = []
        self._best_stm, self._best_params = -1.0, None
        per_lane = 1 if (self.lanes == 1 and not self.dp) else self.lanes
        for ep in range(episodes):
            if per_lane == 1:
                history.append(self.train_episode(routes[ep % len(routes)]))
            else:
                history.append(self.train_episode(
                    [routes[(ep * per_lane + i) % len(routes)]
                     for i in range(per_lane)]))
            if ta_eval is not None and (ep + 1) % eval_every == 0:
                stms = self._eval_stms(ta_eval)
                history[-1]["eval_stm"] = stms[0] if len(stms) == 1 else stms
                lane = int(np.argmax(stms))
                if stms[lane] > self._best_stm:
                    self._best_stm = stms[lane]
                    self._best_params = self.eval_params(lane)
        if self._best_params is not None:
            self.set_params(self._best_params)
            self.best_eval_stm = self._best_stm
        return history

    def _eval_stms(self, ta_eval: TaskArrays) -> list:
        if self.dp or self.lanes == 1:
            final, _, recs = self._sched_fn(self.eval_params(), ta_eval)
            return [pipeline_summarize(self.spec, final, recs)["stm_rate"]]
        if self._eval_fn is None:
            self._eval_fn = jax.jit(jax.vmap(
                _pipeline_run(self.spec, self.plan, self.cfg.backlog_scale),
                in_axes=(0, None)))
        finals, _, recs = self._eval_fn(self.ts.eval_p, ta_eval)
        return [pipeline_summarize(
            self.spec,
            jax.tree_util.tree_map(lambda a, i=i: a[i], finals),
            jax.tree_util.tree_map(lambda a, i=i: a[i], recs))["stm_rate"]
            for i in range(self.lanes)]

    def eval_params(self, lane: int = 0) -> DQNParams:
        if self.dp or self.lanes == 1:
            return self.ts.eval_p
        return jax.tree_util.tree_map(lambda a: a[lane], self.ts.eval_p)

    def set_params(self, params: DQNParams) -> None:
        if self.dp or self.lanes == 1:
            eval_p = params
        else:
            eval_p = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a, (self.lanes,) + a.shape).copy(), params)
        self.ts = self.ts._replace(
            eval_p=eval_p, targ_p=eval_p,
            opt=jax.tree_util.tree_map(jnp.zeros_like, self.ts.opt))

    def save_weights(self, path: str, lane: int = 0) -> None:
        from repro.core.flexai.dqn import save_dqn_npz
        save_dqn_npz(path, self.eval_params(lane))

    def load_weights(self, path: str) -> None:
        from repro.core.flexai.dqn import load_dqn_npz
        self.set_params(load_dqn_npz(path))

    def schedule(self, tasks, lane: int = 0) -> dict:
        ta = self._as_arrays(tasks)
        t0 = time.perf_counter()
        final, _, recs = self._sched_fn(self.eval_params(lane), ta)
        jax.block_until_ready(final)
        dt = time.perf_counter() - t0
        summ = pipeline_summarize(self.spec, final, recs)
        summ["schedule_time_s"] = dt
        summ["schedule_time_per_task_s"] = dt / max(ta.num_tasks, 1)
        summ["placements"] = np.asarray(recs.action)   # [T, S]
        return summ
