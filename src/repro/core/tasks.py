"""Task descriptors for the driving-automation workload (paper §7.1).

A Task is one camera frame needing one CNN inference (DET via YOLO or SSD,
TRA via GOTURN).  Task-Info fed to the RL agent is (Amount, LayerNum,
safety_time) exactly as §7.1 specifies; Amount/LayerNum derive from the
perception model definitions (Table 1), not hard-coded constants.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import lru_cache
from typing import NamedTuple


class TaskKind(enum.Enum):
    YOLO = "yolo"      # DET, small/medium objects
    SSD = "ssd"        # DET, large objects
    GOTURN = "goturn"  # TRA


# canonical integer encoding shared by the NumPy platform's cached tables
# and the device-resident scan engine (``platform_jax``)
KIND_ORDER = tuple(TaskKind)
KIND_INDEX = {k: i for i, k in enumerate(KIND_ORDER)}
GOTURN_INDEX = KIND_INDEX[TaskKind.GOTURN]
GROUP_ORDER = ("FC", "FLSC", "RLSC", "FRSC", "RRSC", "RC")
GROUP_INDEX = {g: i for i, g in enumerate(GROUP_ORDER)}


@lru_cache(maxsize=1)
def _model_stats() -> dict:
    from repro.models.perception.nets import perception_stats
    return perception_stats()


@dataclasses.dataclass(frozen=True)
class Task:
    uid: int
    kind: TaskKind
    camera_group: str    # FC / FLSC / RLSC / FRSC / RRSC / RC
    camera_id: int
    arrival_time: float  # seconds since route start
    safety_time: float   # response budget (criteria.camera_safety_time)

    @property
    def amount(self) -> float:
        """Computation amount (MACs)."""
        return float(_model_stats()[self.kind.value]["macs"])

    @property
    def layer_num(self) -> int:
        return int(_model_stats()[self.kind.value]["layers"])


def task_features(task: Task) -> tuple[float, float, float]:
    """Task-Info vector for the RL agent: (Amount, LayerNum, safety_time),
    scaled to O(1) ranges."""
    return (task.amount / 30e9, task.layer_num / 100.0, task.safety_time)


# ---------------------------------------------------------------------------
# serving deadlines (Table 5 period requirements)
# ---------------------------------------------------------------------------

# Table 5, urban go-straight row, split per model: the fleet must sustain
# these aggregate FPS, so each submitted frame of a kind has 1/FPS seconds
# of serving slack before the next frame of that kind lands.  (TL/RE rows
# are tighter/looser by ~10%; GS is the steady-state requirement the
# serving layer is sized for — scenario-specific tightening rides on
# ``scale``.)
TABLE5_FPS = {TaskKind.YOLO: 435.0, TaskKind.SSD: 435.0,
              TaskKind.GOTURN: 840.0}


def kind_period_s(kind: TaskKind) -> float:
    """Required processing period (s/frame) for one task of ``kind``."""
    return 1.0 / TABLE5_FPS[kind]


@lru_cache(maxsize=1)
def kind_period_table():
    """[n_kinds] f32 periods in KIND_INDEX order (vectorized lookup for
    ``TaskArrays.kind``)."""
    import numpy as np
    return np.asarray([kind_period_s(k) for k in KIND_ORDER], np.float32)


def route_deadline_budget(ta: "TaskArrays", scale: float = 1.0) -> float:
    """Serving-deadline budget (s) for a placement request: the whole queue
    must be placed before its frames' Table-5 periods elapse, so the budget
    is the summed per-task period over valid tasks, scaled by ``scale``
    (``--deadline-scale``; <1 tightens, >1 relaxes)."""
    import numpy as np
    periods = kind_period_table()[np.asarray(ta.kind)]
    return float(scale * periods[np.asarray(ta.valid, bool)].sum())


def token_deadline_budget(prompt_len: int, max_new_tokens: int,
                          scale: float = 1.0,
                          per_token: float = 2.0) -> float:
    """Deadline budget for a token-serving request, in engine step units:
    ``per_token`` steps of slack per token of total length (prompt replay +
    decode), scaled by ``scale``.  The default 2.0 admits one full wave of
    queueing ahead of the request before its deadline is at risk."""
    return scale * per_token * max(prompt_len + max_new_tokens, 1)


# ---------------------------------------------------------------------------
# struct-of-arrays form (the "precompiled" queue fed to lax.scan engines)
# ---------------------------------------------------------------------------

class TaskArrays(NamedTuple):
    """A task queue as parallel arrays, [T] each (or scalars inside a scan
    body).  ``valid`` marks real tasks; padding rows (added so routes share
    a static shape for jit/vmap) carry valid=False and leave the platform
    state untouched."""
    kind: "object"      # [T] i32, KIND_INDEX encoding
    arrival: "object"   # [T] f32 seconds
    safety: "object"    # [T] f32 seconds
    group: "object"     # [T] i32, GROUP_INDEX encoding
    valid: "object"     # [T] bool

    @property
    def num_tasks(self) -> int:
        return int(self.arrival.shape[-1])


def tasks_to_arrays(tasks: list) -> TaskArrays:
    """Precompile a ``Task`` list into struct-of-arrays form (one-time host
    cost; after this the queue never leaves the device)."""
    import numpy as np
    return TaskArrays(
        kind=np.asarray([KIND_INDEX[t.kind] for t in tasks], np.int32),
        arrival=np.asarray([t.arrival_time for t in tasks], np.float32),
        safety=np.asarray([t.safety_time for t in tasks], np.float32),
        group=np.asarray([GROUP_INDEX[t.camera_group] for t in tasks],
                         np.int32),
        valid=np.ones(len(tasks), bool),
    )


def pad_task_arrays(ta: TaskArrays, to_len: int) -> TaskArrays:
    """Right-pad with invalid rows to a static length (shape bucketing)."""
    import numpy as np
    n = ta.arrival.shape[0]
    if to_len < n:
        raise ValueError(f"cannot pad {n} tasks down to {to_len}")
    if to_len == n:
        return ta
    pad = to_len - n

    def ext(a, fill):
        return np.concatenate(
            [np.asarray(a), np.full((pad,), fill, np.asarray(a).dtype)])

    return TaskArrays(kind=ext(ta.kind, 0), arrival=ext(ta.arrival, 0.0),
                      safety=ext(ta.safety, 1.0), group=ext(ta.group, 0),
                      valid=ext(ta.valid, False))


def stack_task_arrays(routes: list) -> TaskArrays:
    """Stack per-route ``TaskArrays`` into a [R, T_max] batch for vmap,
    padding every route to the longest."""
    import numpy as np
    t_max = max(r.arrival.shape[0] for r in routes)
    padded = [pad_task_arrays(r, t_max) for r in routes]
    return TaskArrays(*[np.stack([getattr(p, f) for p in padded])
                        for f in TaskArrays._fields])


def window_task_arrays(ta: TaskArrays, window: int) -> TaskArrays:
    """Right-pad a [T] route with invalid zero rows to a ``window``
    multiple and fold it to [n_windows, window] — the shared layout of
    the windowed scan schedulers (Min-Min, device GA/SA).  jnp-based so
    it can run inside a traced function (vmap-safe: shapes are static).
    """
    import jax.numpy as jnp
    t = ta.arrival.shape[0]
    pad = -t % window
    return TaskArrays(*[
        jnp.concatenate([jnp.asarray(a),
                         jnp.zeros((pad,), jnp.asarray(a).dtype)]
                        ).reshape(-1, window)
        for a in ta])


def invalid_task_arrays(length: int) -> TaskArrays:
    """An all-padding route: every row carries ``valid=False`` so the scan
    engine passes the platform state through untouched."""
    import numpy as np
    return TaskArrays(
        kind=np.zeros((length,), np.int32),
        arrival=np.zeros((length,), np.float32),
        safety=np.ones((length,), np.float32),
        group=np.zeros((length,), np.int32),
        valid=np.zeros((length,), bool),
    )


# ---------------------------------------------------------------------------
# pipeline-stage DAG form (one route -> chunk tasks -> pipeline stages)
# ---------------------------------------------------------------------------

class StageGraph(NamedTuple):
    """A route compiled to a pipeline DAG: every chunk task of ``tasks``
    flows through ``n_stages`` stages (stage s of task k depends on stage
    s-1 of task k — the camera->perception->planning chain cut into
    MAC-balanced layer windows).

    Static per-kind metadata (NumPy, not scanned over):

    * ``layer_splits`` [n_kinds, S+1]: layer index boundaries — stage s of
      kind ``k`` runs layers ``splits[k, s]:splits[k, s+1]``;
    * ``mac_frac``     [n_kinds, S]: MAC fraction per stage (rows sum to 1);
    * ``act_bytes``    [n_kinds, S]: activation bytes crossing the boundary
      AFTER stage s (the cross-stage reshard payload; last column is the
      network output, which stays on the final group -> 0).

    ``edges_src``/``edges_dst`` ([S-1] each) spell out the producer ->
    consumer stage edges; the chain DAG makes them ``s -> s+1``, but the
    fields keep the representation honest for future branching graphs.
    """
    tasks: TaskArrays
    n_stages: int
    layer_splits: "object"   # [n_kinds, S+1] i32
    mac_frac: "object"       # [n_kinds, S] f32
    act_bytes: "object"      # [n_kinds, S] f32
    edges_src: "object"      # [S-1] i32
    edges_dst: "object"      # [S-1] i32


@lru_cache(maxsize=8)
def stage_layer_stats(n_stages: int):
    """MAC-balanced layer windows for every perception model (Table 1).

    Returns ``(layer_splits [n_kinds, S+1], mac_frac [n_kinds, S],
    act_bytes [n_kinds, S])`` in KIND_INDEX order.  Splits are chosen
    greedily so each stage's MAC share approaches 1/S — the same
    equal-FLOPs stage construction alpa's inter-op pass starts from.
    Activation bytes at a boundary = the boundary layer's output tensor
    (c_out x (hw/stride)^2 fp32 for conv, c_out fp32 for fc).
    """
    import numpy as np
    stats = _model_stats()
    splits = np.zeros((len(KIND_ORDER), n_stages + 1), np.int32)
    frac = np.zeros((len(KIND_ORDER), n_stages), np.float32)
    act = np.zeros((len(KIND_ORDER), n_stages), np.float32)
    for ki, kind in enumerate(KIND_ORDER):
        per_layer = stats[kind.value]["per_layer"]
        macs = np.asarray([l["macs"] for l in per_layer], np.float64)
        csum = np.concatenate([[0.0], np.cumsum(macs)])
        total = csum[-1]
        bounds = [0]
        for s in range(1, n_stages):
            target = total * s / n_stages
            # first layer boundary at/after the equal-MACs target, but at
            # least one layer per stage so every stage exists
            b = int(np.searchsorted(csum, target))
            b = min(max(b, bounds[-1] + 1), len(per_layer) - (n_stages - s))
            bounds.append(b)
        bounds.append(len(per_layer))
        splits[ki] = np.asarray(bounds, np.int32)
        for s in range(n_stages):
            lo, hi = bounds[s], bounds[s + 1]
            frac[ki, s] = (csum[hi] - csum[lo]) / total
            if s < n_stages - 1:
                out = per_layer[hi - 1]
                hw = out.get("hw", 1) // max(out.get("stride", 1), 1)
                act[ki, s] = 4.0 * out["c_out"] * max(hw, 1) ** 2
    return splits, frac, act


def route_to_stage_graph(tasks, n_stages: int) -> StageGraph:
    """Compile one route (a ``Task`` list or ``TaskArrays``) into its
    pipeline DAG for ``n_stages`` stages.  ``n_stages == 1`` degenerates to
    the whole-task representation (one stage owning every layer)."""
    import numpy as np
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    ta = tasks if isinstance(tasks, TaskArrays) else tasks_to_arrays(tasks)
    splits, frac, act = stage_layer_stats(n_stages)
    s = np.arange(n_stages - 1, dtype=np.int32)
    return StageGraph(tasks=ta, n_stages=n_stages, layer_splits=splits,
                      mac_frac=frac, act_bytes=act,
                      edges_src=s, edges_dst=s + 1)


def pad_route_batch(batch: TaskArrays, multiple: int) -> TaskArrays:
    """Pad the leading route axis of a [R, T] batch to a multiple of
    ``multiple`` with all-invalid routes.

    This is what makes the sharded engine device-count-agnostic: any route
    batch can be split evenly over however many devices the mesh has, and
    the padding lanes cost one no-op scan each.
    """
    import numpy as np
    r, t = batch.arrival.shape
    pad = (-r) % multiple
    if pad == 0:
        return batch
    inv = invalid_task_arrays(t)
    return TaskArrays(*[
        np.concatenate(
            [np.asarray(b), np.broadcast_to(f, (pad, t)).copy()])
        for b, f in zip(batch, inv)])
