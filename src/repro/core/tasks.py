"""Task descriptors for the driving-automation workload (paper §7.1).

A Task is one camera frame needing one CNN inference (DET via YOLO or SSD,
TRA via GOTURN).  Task-Info fed to the RL agent is (Amount, LayerNum,
safety_time) exactly as §7.1 specifies; Amount/LayerNum derive from the
perception model definitions (Table 1), not hard-coded constants.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import lru_cache


class TaskKind(enum.Enum):
    YOLO = "yolo"      # DET, small/medium objects
    SSD = "ssd"        # DET, large objects
    GOTURN = "goturn"  # TRA


@lru_cache(maxsize=1)
def _model_stats() -> dict:
    from repro.models.perception.nets import perception_stats
    return perception_stats()


@dataclasses.dataclass(frozen=True)
class Task:
    uid: int
    kind: TaskKind
    camera_group: str    # FC / FLSC / RLSC / FRSC / RRSC / RC
    camera_id: int
    arrival_time: float  # seconds since route start
    safety_time: float   # response budget (criteria.camera_safety_time)

    @property
    def amount(self) -> float:
        """Computation amount (MACs)."""
        return float(_model_stats()[self.kind.value]["macs"])

    @property
    def layer_num(self) -> int:
        return int(_model_stats()[self.kind.value]["layers"])


def task_features(task: Task) -> tuple[float, float, float]:
    """Task-Info vector for the RL agent: (Amount, LayerNum, safety_time),
    scaled to O(1) ranges."""
    return (task.amount / 30e9, task.layer_num / 100.0, task.safety_time)
