"""Dynamic driving environment (paper §2.2, §8.1).

Generates task queues: a route through an area (UB / UHW / HW) is a timeline
of scenario segments (go-straight, with randomized turn / reverse segments
bounded by the Table-13 parameters); each camera group fires at its
(area, scenario)-dependent rate; every frame becomes a DET task (YOLO and
SSD alternating per camera, §2.1) and — except rear cameras outside
reversing — a TRA task (GOTURN).

Camera rate calibration: the paper publishes only the urban aggregate
requirements (Table 5: GS 870/840, TL 950/920, RE 740/740 FPS for DET/TRA).
The per-group rates below are chosen to reproduce those aggregates exactly
with the Table-4 camera counts; UHW/HW scale them by the Fig-1 trend
(higher speed -> higher required frame rate), since Fig 1's numeric labels
are not recoverable from the text.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.criteria import camera_safety_time
from repro.core.tasks import Task, TaskKind


class Area(str, enum.Enum):
    UB = "UB"
    UHW = "UHW"
    HW = "HW"


class Scenario(str, enum.Enum):
    GS = "GS"  # go straight
    TL = "TL"  # turn (left/right symmetric, §8.1)
    RE = "RE"  # reverse


@dataclasses.dataclass(frozen=True)
class CameraGroup:
    name: str
    count: int


# Table 4
CAMERA_GROUPS = (
    CameraGroup("FC", 11),
    CameraGroup("FLSC", 4),
    CameraGroup("RLSC", 4),
    CameraGroup("FRSC", 4),
    CameraGroup("RRSC", 4),
    CameraGroup("RC", 3),
)

# per-camera Hz by (scenario, group) in URBAN; reproduces Table 5 aggregates:
#   GS:  DET = 11*40 + 16*25 + 3*10  = 870 ; TRA (no RC) = 840
#   TL:  DET = 11*40 + 16*30 + 3*10  = 950 ; TRA (no RC) = 920
#   RE:  DET = 11*20 + 16*25 + 3*40  = 740 ; TRA (RC tracked while
#        reversing) = 740
_URBAN_HZ = {
    Scenario.GS: {"FC": 40.0, "FLSC": 25.0, "RLSC": 25.0, "FRSC": 25.0,
                  "RRSC": 25.0, "RC": 10.0},
    Scenario.TL: {"FC": 40.0, "FLSC": 30.0, "RLSC": 30.0, "FRSC": 30.0,
                  "RRSC": 30.0, "RC": 10.0},
    Scenario.RE: {"FC": 20.0, "FLSC": 25.0, "RLSC": 25.0, "FRSC": 25.0,
                  "RRSC": 25.0, "RC": 40.0},
}

# Fig-1 trend: faster areas need higher frame rates
_AREA_SCALE = {Area.UB: 1.0, Area.UHW: 1.15, Area.HW: 1.3}


def camera_hz(area: Area, scenario: Scenario, group: str) -> float:
    if area == Area.HW and scenario == Scenario.RE:
        raise ValueError("reversing is not allowed on the highway")
    return _URBAN_HZ[scenario][group] * _AREA_SCALE[area]


@dataclasses.dataclass(frozen=True)
class EnvironmentParams:
    """Table 12/13 parameters."""
    area: Area = Area.UB
    route_km: float = 1.0
    velocity_kmh: float = 60.0
    max_times_turn: int = 10
    max_times_reverse: int = 10
    max_duration_turn: float = 10.0
    max_duration_reverse: float = 20.0
    rate_scale: float = 1.0  # subsample factor for CPU-scale experiments
    seed: int = 0


@dataclasses.dataclass
class Segment:
    scenario: Scenario
    start: float
    duration: float


class DrivingEnvironment:
    """Builds the scenario timeline and emits the task queue."""

    def __init__(self, params: EnvironmentParams):
        self.params = params
        self.rng = np.random.default_rng(params.seed)
        self.route_s = params.route_km / params.velocity_kmh * 3600.0
        self.segments = self._build_segments()

    def _build_segments(self) -> list:
        p = self.params
        rng = self.rng
        n_turn = int(rng.integers(0, p.max_times_turn + 1))
        n_rev = (0 if p.area == Area.HW
                 else int(rng.integers(0, p.max_times_reverse + 1)))
        events = []
        for _ in range(n_turn):
            d = rng.uniform(1.0, p.max_duration_turn)
            events.append((Scenario.TL, d))
        for _ in range(n_rev):
            d = rng.uniform(1.0, p.max_duration_reverse)
            events.append((Scenario.RE, d))
        rng.shuffle(events)
        # place events at random non-overlapping starts; GS fills the rest
        total_event = sum(d for _, d in events)
        free = max(self.route_s - total_event, 0.0)
        gaps = rng.dirichlet(np.ones(len(events) + 1)) * free \
            if events else np.array([free])
        segs: list = []
        t = 0.0
        for i, (sc, d) in enumerate(events):
            if gaps[i] > 0:
                segs.append(Segment(Scenario.GS, t, gaps[i]))
                t += gaps[i]
            segs.append(Segment(sc, t, d))
            t += d
        if gaps[-1] > 0:
            segs.append(Segment(Scenario.GS, t, gaps[-1]))
        return segs

    def scenario_at(self, t: float) -> Scenario:
        for seg in self.segments:
            if seg.start <= t < seg.start + seg.duration:
                return seg.scenario
        return Scenario.GS

    def build_task_queue(self) -> list:
        """All tasks for the route, sorted by arrival time."""
        p = self.params
        tasks: list = []
        uid = 0
        det_toggle: dict = {}
        for seg in self.segments:
            for group in CAMERA_GROUPS:
                hz = camera_hz(p.area, seg.scenario, group.name) * p.rate_scale
                if hz <= 0:
                    continue
                period = 1.0 / hz
                for cam in range(group.count):
                    t = seg.start + self.rng.uniform(0, period)
                    while t < seg.start + seg.duration:
                        st = camera_safety_time(group.name, p.area.value,
                                                seg.scenario.value)
                        # DET task: YOLO/SSD alternate per camera (§2.1)
                        key = (group.name, cam)
                        use_yolo = det_toggle.get(key, True)
                        det_toggle[key] = not use_yolo
                        tasks.append(Task(
                            uid=uid,
                            kind=TaskKind.YOLO if use_yolo else TaskKind.SSD,
                            camera_group=group.name, camera_id=cam,
                            arrival_time=t, safety_time=st))
                        uid += 1
                        # TRA task: rear cameras only while reversing
                        if group.name != "RC" or seg.scenario == Scenario.RE:
                            tasks.append(Task(
                                uid=uid, kind=TaskKind.GOTURN,
                                camera_group=group.name, camera_id=cam,
                                arrival_time=t, safety_time=st))
                            uid += 1
                        t += period
        tasks.sort(key=lambda task: task.arrival_time)
        return tasks


def build_task_queue(params: EnvironmentParams) -> list:
    return DrivingEnvironment(params).build_task_queue()


def build_task_arrays(params: EnvironmentParams):
    """Precompiled struct-of-arrays queue for the device-resident scan
    engine (``tasks.TaskArrays``): one host-side pass, then the route is
    a handful of jnp arrays."""
    from repro.core.tasks import tasks_to_arrays
    return tasks_to_arrays(DrivingEnvironment(params).build_task_queue())


def build_route_batch(params_list: list):
    """Stack several routes (different seeds/areas) into one [R, T_max]
    ``TaskArrays`` batch for the vmapped engine paths."""
    from repro.core.tasks import stack_task_arrays, tasks_to_arrays
    return stack_task_arrays(
        [tasks_to_arrays(DrivingEnvironment(p).build_task_queue())
         for p in params_list])
