"""CNN-accelerator taxonomy (paper §5.1).

Three orthogonal axes:

* **Data processing style** — how much of a convolution one BasicUnit covers:
  Sconv (a whole 2D conv per iteration), SSconv (part of a 2D conv),
  Mconv (multiple 2D convs per iteration).
* **Data propagation type** — which operand moves between PEs:
  OP (ofmaps/psums propagate, filters fixed), IP (ifmaps propagate,
  ofmaps fixed), MP (multiple kinds propagate).
* **Register allocation** — DR (registers dispersed per-PE) vs
  CR (concentrated storage, never holds psums).

The paper instantiates three corners for HMAI:
  SconvOD = Sconv-OP-DR (NeuFlow-style), SconvIC = SSconv-IP-CR
  (ShiDianNao-style), MconvMC = Mconv-MP-CR (Origami-style).

TPU adaptation (see DESIGN.md): per-PE registers/FIFOs have no TPU
analogue; the surviving dimension is *stationarity* — which operand a
Pallas kernel keeps resident in VMEM across its inner grid loop.  The
mapping below ties each archetype to its kernel implementation in
``repro.kernels.conv_dataflow``.
"""
from __future__ import annotations

import dataclasses
import enum


class DataProcessing(enum.Enum):
    SCONV = "Sconv"      # whole 2D conv per BasicUnit
    SSCONV = "SSconv"    # part of a 2D conv per BasicUnit
    MCONV = "Mconv"      # multiple 2D convs per BasicUnit


class Propagation(enum.Enum):
    OP = "ofmaps"        # psums propagate between PEs, filters fixed
    IP = "ifmaps"        # ifmaps propagate, ofmaps fixed in PEs
    MP = "multiple"      # more than one operand propagates


class RegisterAlloc(enum.Enum):
    DR = "dispersive"    # per-PE registers
    CR = "concentrated"  # central register file, never stores psums


@dataclasses.dataclass(frozen=True)
class AcceleratorArch:
    name: str
    processing: DataProcessing
    propagation: Propagation
    registers: RegisterAlloc
    exemplar: str            # the published design it abstracts
    tpu_stationarity: str    # Pallas-kernel analogue (VMEM-resident operand)
    uses_ocb: bool           # on-chip buffer (Table 10: only Mconv)
    macs_per_pe: int         # Table 10: 1 for Sconv/SSconv, >1 for Mconv

    def validate(self) -> None:
        # Table 10 invariants
        if self.processing in (DataProcessing.SCONV, DataProcessing.SSCONV):
            assert self.macs_per_pe == 1, "Sconv/SSconv: 1 MAC per PE"
            assert not self.uses_ocb, "Sconv/SSconv: no on-chip buffer"
        else:
            assert self.macs_per_pe > 1, "Mconv: multiple MACs per PE"
            assert self.uses_ocb, "Mconv: requires on-chip buffer"


SCONV_OD = AcceleratorArch(
    name="SconvOD",
    processing=DataProcessing.SCONV,
    propagation=Propagation.OP,
    registers=RegisterAlloc.DR,
    exemplar="NeuFlow (Farabet et al., CVPRW'11)",
    tpu_stationarity="weight-stationary",
    uses_ocb=False,
    macs_per_pe=1,
)

SCONV_IC = AcceleratorArch(
    name="SconvIC",
    processing=DataProcessing.SSCONV,
    propagation=Propagation.IP,
    registers=RegisterAlloc.CR,
    exemplar="ShiDianNao (Du et al., ISCA'15)",
    tpu_stationarity="output-stationary",
    uses_ocb=False,
    macs_per_pe=1,
)

MCONV_MC = AcceleratorArch(
    name="MconvMC",
    processing=DataProcessing.MCONV,
    propagation=Propagation.MP,
    registers=RegisterAlloc.CR,
    exemplar="Origami (Cavigelli & Benini, TCSVT'17)",
    tpu_stationarity="im2col-GEMM (MXU tiles)",
    uses_ocb=True,
    macs_per_pe=4,
)

TAXONOMY = {a.name: a for a in (SCONV_OD, SCONV_IC, MCONV_MC)}
for _a in TAXONOMY.values():
    _a.validate()
