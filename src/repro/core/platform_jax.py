"""Device-resident HMAI platform: a JAX pytree mirror of ``HMAIPlatform``.

``HMAIPlatform`` (``hmai.py``) is an event-driven queue simulator whose
state mutates per task — one Python call, and one host<->device roundtrip
for the RL agent, per camera frame.  This module ports that state into a
``PlatformState`` pytree with a *pure* transition ``platform_step`` so the
whole schedule->execute->reward loop can live inside one ``lax.scan`` (one
device dispatch per route) and be ``jax.vmap``-ed across routes.

The NumPy platform remains the reference implementation (the oracle);
``tests/test_scan_engine.py`` holds the two paths to fp32 parity.  See
DESIGN.md ("Device-resident platform") for the layout rationale.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tasks import GOTURN_INDEX, KIND_ORDER, TaskArrays


class PlatformSpec(NamedTuple):
    """Static (per-platform, per-route-batch) tables; not scanned over.

    ``exec_time`` / ``energy`` are the TaskKind x accelerator matrices the
    NumPy platform caches in ``reset()`` (transposed: [n_accel, n_kinds]).
    """
    exec_time: jax.Array       # [n_accel, n_kinds] f32, seconds
    energy: jax.Array          # [n_accel, n_kinds] f32, joules
    gvalue_e_scale: jax.Array  # scalar f32 (per-task energy scale, §6.2)
    gvalue_t_scale: jax.Array  # scalar f32 (per-task time scale)

    @property
    def n(self) -> int:
        return self.exec_time.shape[0]


class PlatformState(NamedTuple):
    """The mutable half of ``HMAIPlatform`` as arrays (HW-Info, §7.2).

    ``alive`` / ``cap`` are the per-accelerator health vector (ISSUE 8):
    ``alive`` masks failed cores out of every policy's action support, and
    ``cap`` is the capacity scale of the survivors (thermal throttle 0.5x
    -> exec/energy lookups inflate by 1/0.5).  Default all-alive at
    ``cap=1.0``, where every lookup divides by exactly 1.0 — bit-identical
    to the pre-health engine.  The scan engines refresh both fields from a
    fault-schedule trace (``core.faults``) before each step.
    """
    avail: jax.Array       # [n] next-free time per accelerator
    busy: jax.Array        # [n] cumulative busy seconds
    E: jax.Array           # [n] energy
    T: jax.Array           # [n] max finish time
    MS: jax.Array          # [n] summed Matching Score
    R_Balance: jax.Array   # [n] running mean utilization
    num_tasks: jax.Array   # [n] i32
    e_scale: jax.Array     # scalar: running max total energy (HW-Info norm)
    t_scale: jax.Array     # scalar: running max makespan
    alive: jax.Array       # [n] bool health mask (False = failed core)
    cap: jax.Array         # [n] f32 capacity scale of alive cores


# Effective-capacity floor for a dead core that a policy places on anyway
# (blind replay of a fault trace): exec/energy inflate by 1/HEALTH_FLOOR
# instead of dividing by zero, so the penalty is huge but finite and the
# engines stay parity-comparable.
HEALTH_FLOOR = 1e-3

# Observation-side slowdown cap (state_vector only): a dead core's
# 1/HEALTH_FLOOR = 1000x exec entry would saturate the Q-net's inputs and
# corrupt its ranking of the *alive* cores; the alive-mask already carries
# "dead", so the observation advertises slowdowns only up to this factor.
# Timing/energy accounting (platform_step) is NOT clamped.
OBS_SLOWDOWN_CAP = 10.0


def health_capacity(state: PlatformState) -> jax.Array:
    """[n] effective capacity: ``cap`` for alive cores, ``HEALTH_FLOOR``
    for dead ones.  Every exec/energy lookup divides by this — the single
    place the health vector meets the timing model."""
    return jnp.maximum(jnp.where(state.alive, state.cap, 0.0), HEALTH_FLOOR)


def with_health(state: PlatformState, hrow: jax.Array) -> PlatformState:
    """Install one fault-trace row ([n] f32; 0 = dead, (0, 1] = capacity)
    into the state's health vector."""
    return state._replace(alive=hrow > 0.0,
                          cap=jnp.where(hrow > 0.0, hrow, 1.0))


class StepRecord(NamedTuple):
    """Per-decision outputs of ``platform_step`` (a ``TaskRecord`` row)."""
    action: jax.Array
    start: jax.Array
    finish: jax.Array
    wait: jax.Array
    exec_time: jax.Array
    response: jax.Array
    ms: jax.Array
    energy: jax.Array
    met: jax.Array     # response <= safety_time (STM hit)
    valid: jax.Array   # False for padding tasks: state passed through


def spec_from_platform(platform) -> PlatformSpec:
    """Build the static tables from an ``HMAIPlatform`` (uses the cached
    exec/energy tables the platform builds in ``reset()``)."""
    return PlatformSpec(
        exec_time=jnp.asarray(platform.exec_time_table, jnp.float32),
        energy=jnp.asarray(platform.energy_table, jnp.float32),
        gvalue_e_scale=jnp.float32(platform.gvalue_e_scale),
        gvalue_t_scale=jnp.float32(platform.gvalue_t_scale),
    )


def spec_from_tables(exec_time: np.ndarray, energy: np.ndarray) -> PlatformSpec:
    exec_time = jnp.asarray(exec_time, jnp.float32)
    energy = jnp.asarray(energy, jnp.float32)
    return PlatformSpec(
        exec_time=exec_time, energy=energy,
        gvalue_e_scale=jnp.float32(jnp.mean(energy)),
        gvalue_t_scale=jnp.float32(jnp.mean(exec_time)),
    )


def platform_init(n: int) -> PlatformState:
    z = jnp.zeros((n,), jnp.float32)
    return PlatformState(
        avail=z, busy=z, E=z, T=z, MS=z, R_Balance=z,
        num_tasks=jnp.zeros((n,), jnp.int32),
        e_scale=jnp.float32(1e-9), t_scale=jnp.float32(1e-9),
        alive=jnp.ones((n,), bool), cap=jnp.ones((n,), jnp.float32),
    )


def state_from_platform(platform) -> PlatformState:
    """Snapshot a live ``HMAIPlatform`` into a ``PlatformState``.

    This is the scratch-evaluation seam for the windowed metaheuristics:
    a search can fork any mid-route platform into a device-side snapshot,
    score candidate window assignments against it (``window_fitness``)
    without mutating the oracle, and commit only the winner.
    """
    f32 = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
    return PlatformState(
        avail=f32(platform.avail), busy=f32(platform.busy),
        E=f32(platform.E), T=f32(platform.T), MS=f32(platform.MS),
        R_Balance=f32(platform.R_Balance),
        num_tasks=jnp.asarray(platform.num_tasks, jnp.int32),
        e_scale=jnp.float32(platform._e_scale),
        t_scale=jnp.float32(platform._t_scale),
        alive=jnp.ones((platform.n,), bool),
        cap=jnp.ones((platform.n,), jnp.float32),
    )


def state_to_platform(state: PlatformState, platform) -> None:
    """Restore a ``PlatformState`` snapshot into a live ``HMAIPlatform`` —
    the inverse of :func:`state_from_platform`.

    This is the resume half of the serving preemption seam: a preempted
    wave checkpoints its device-side state, and either path (the scan
    engines via ``state0=`` or the NumPy oracle via this restore) can
    continue the route from the checkpoint.  ``records`` is bookkeeping
    the snapshot does not carry; the restored platform keeps its own.
    """
    platform.avail = np.asarray(state.avail, np.float64).copy()
    platform.busy = np.asarray(state.busy, np.float64).copy()
    platform.E = np.asarray(state.E, np.float64).copy()
    platform.T = np.asarray(state.T, np.float64).copy()
    platform.MS = np.asarray(state.MS, np.float64).copy()
    platform.R_Balance = np.asarray(state.R_Balance, np.float64).copy()
    platform.num_tasks = np.asarray(state.num_tasks, np.int64).copy()
    platform._e_scale = float(state.e_scale)
    platform._t_scale = float(state.t_scale)


def stack_states(states: list) -> PlatformState:
    """Stack per-lane ``PlatformState``s into one [L, ...] batch (the
    state0 layout of the vmapped resume path)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def platform_step(spec: PlatformSpec, state: PlatformState, task: TaskArrays,
                  action: jax.Array, valid=None
                  ) -> tuple[PlatformState, StepRecord]:
    """Pure mirror of ``HMAIPlatform.execute`` (§7.2 update formulas).

    ``task`` holds scalar fields (one ``TaskArrays`` row, e.g. a scan slice).
    When ``valid`` is False the state passes through unchanged (padding
    row) and the record is flagged invalid.
    """
    if valid is None:
        valid = task.valid
    a = action.astype(jnp.int32)
    kind = task.kind
    # health folds into the lookups: a core at capacity c runs 1/c slower
    # at constant power draw (1/c the energy too); all-healthy divides by
    # exactly 1.0, preserving the pre-health engine bit-for-bit
    eff = health_capacity(state)[a]
    et = spec.exec_time[a, kind] / eff
    en = spec.energy[a, kind] / eff
    start = jnp.maximum(task.arrival, state.avail[a])
    finish = start + et
    wait = start - task.arrival
    response = finish - task.arrival
    # Matching Score: GOTURN tasks are TRA (step function, Fig 7b), the
    # detectors use the linear DET ramp (Fig 7a)
    met = response <= task.safety
    ms_det = jnp.where(met & (task.safety > 0),
                       response / jnp.maximum(task.safety, 1e-12), -1.0)
    ms_tra = jnp.where(met, 1.0, -1.0)
    ms = jnp.where(kind == GOTURN_INDEX, ms_tra, ms_det)

    avail = state.avail.at[a].set(finish)
    busy = state.busy.at[a].add(et)
    E = state.E.at[a].add(en)
    T = state.T.at[a].max(finish)
    MS = state.MS.at[a].add(ms)
    num_tasks = state.num_tasks.at[a].add(1)
    # paper: R_Balance_i = (r_j + R_Balance_i) / num
    r_j = busy[a] / jnp.maximum(finish, 1e-9)
    n = num_tasks[a].astype(jnp.float32)
    R_Balance = state.R_Balance.at[a].set(
        (r_j + state.R_Balance[a] * (n - 1.0)) / n)
    new = PlatformState(
        avail=avail, busy=busy, E=E, T=T, MS=MS, R_Balance=R_Balance,
        num_tasks=num_tasks,
        e_scale=jnp.maximum(state.e_scale, E.sum()),
        t_scale=jnp.maximum(state.t_scale, T.max()),
        alive=state.alive, cap=state.cap,
    )
    new = jax.tree_util.tree_map(
        lambda nv, ov: jnp.where(valid, nv, ov), new, state)
    rec = StepRecord(action=a, start=start, finish=finish, wait=wait,
                     exec_time=et, response=response, ms=ms, energy=en,
                     met=met, valid=valid)
    return new, rec


# ---------------------------------------------------------------------------
# metrics (pure mirrors of the HMAIPlatform properties)
# ---------------------------------------------------------------------------

def gvalue_state(spec: PlatformSpec, state: PlatformState) -> jax.Array:
    """Global State Value = (-E - T + R_Balance)/3 after §6.2 normalization
    (same formula as ``criteria.gvalue`` + ``HMAIPlatform.gvalue``)."""
    total_e = state.E.sum()
    makespan = state.T.max()
    rb = state.R_Balance.mean()
    e_scale = spec.gvalue_e_scale * jnp.maximum(
        state.num_tasks.sum().astype(jnp.float32), 1.0)
    e = total_e / jnp.maximum(e_scale, 1e-12)
    t = makespan / jnp.maximum(spec.gvalue_t_scale, 1e-12)
    return (-e - t + rb) / 3.0


def hw_info_state(state: PlatformState, now: jax.Array) -> jax.Array:
    """[n, 4] HW-Info = (E_i, T_i, R_Balance_i, MS_i), same reading as
    ``HMAIPlatform.hw_info`` (T_i = backlog relative to ``now``)."""
    return jnp.stack([
        state.E / jnp.maximum(state.e_scale, 1e-9),
        jnp.maximum(state.avail - now, 0.0),
        state.R_Balance,
        state.MS / jnp.maximum(state.num_tasks.astype(jnp.float32), 1.0),
    ], axis=1)


def state_vector(spec: PlatformSpec, feat_table: jax.Array,
                 backlog_scale, state: PlatformState,
                 task: TaskArrays) -> jax.Array:
    """FlexAI observation for one task: Task-Info + HW-Info + exec column —
    the array mirror of ``FlexAIAgent.state_vector``.

    The exec column is the health-EFFECTIVE one (Table-8 times divided by
    the capacity vector): a throttled core advertises its true slowdown to
    the Q-net, so the degradation-trained agent can reroute on magnitude
    and not just the dead/alive mask.  The advertised slowdown saturates
    at ``OBS_SLOWDOWN_CAP`` — a dead core's 1/HEALTH_FLOOR entry would
    blow up the net's inputs and scramble its ranking of the survivors,
    and the argmax mask already excludes dead cores.  All-healthy divides
    by 1.0 (under the cap) — the observation (and hence the loop-agent
    parity) is unchanged.
    """
    tf = jnp.concatenate([feat_table[task.kind],
                          jnp.asarray(task.safety, jnp.float32)[None]])
    hw = hw_info_state(state, task.arrival)
    backlog = jnp.log1p(hw[:, 1] / backlog_scale)
    slow = jnp.minimum(1.0 / health_capacity(state), OBS_SLOWDOWN_CAP)
    hw = jnp.stack([hw[:, 0], backlog, hw[:, 2], hw[:, 3],
                    spec.exec_time[:, task.kind] * slow],
                   axis=1)
    return jnp.concatenate([tf, hw.reshape(-1)])


def stage_state_vector(spec: PlatformSpec, feat_table: jax.Array,
                       backlog_scale, state: PlatformState, task: TaskArrays,
                       *, stage_exec: jax.Array, mac_frac: jax.Array,
                       group_mask: jax.Array,
                       stage_frac: jax.Array) -> jax.Array:
    """FlexAI observation for one pipeline-stage sub-task (``4 + 6n``).

    Unlike :func:`state_vector` this observation is *group-local and
    order-independent*: every per-accelerator feature is masked to the
    stage's accelerator group, and normalization is static
    (``gvalue_e_scale`` / per-accelerator task counts) instead of the
    running ``e_scale`` — the running scale is a *global* reduction whose
    value depends on how far other stage groups have progressed, which
    would break the bit-exact parity between the flattened single-device
    wavefront and the stage-sharded engine (core/pipeline.py).

    Task-Info scales by the stage's MAC fraction (a stage sub-task is that
    slice of the model) and appends the stage position; HW-Info gains the
    group-membership flag so the Q-net can tell its action support apart
    from a merely-idle accelerator.
    """
    mask = group_mask.astype(jnp.float32)
    tf = jnp.concatenate([
        feat_table[task.kind] * mac_frac,
        jnp.asarray(task.safety, jnp.float32)[None],
        jnp.asarray(stage_frac, jnp.float32)[None]])
    nt = jnp.maximum(state.num_tasks.astype(jnp.float32), 1.0)
    e_norm = state.E / (jnp.maximum(spec.gvalue_e_scale, 1e-12) * nt)
    backlog = jnp.log1p(
        jnp.maximum(state.avail - task.arrival, 0.0) / backlog_scale)
    ms_norm = state.MS / nt
    ex = stage_exec[:, task.kind] / health_capacity(state) \
        / jnp.maximum(spec.gvalue_t_scale, 1e-12)
    per = jnp.stack([e_norm, backlog, state.R_Balance, ms_norm, ex, mask],
                    axis=1) * mask[:, None]
    return jnp.concatenate([tf, per.reshape(-1)])


def summarize(spec: PlatformSpec, state: PlatformState,
              recs: StepRecord) -> dict:
    """Host-side summary matching ``HMAIPlatform.summary`` keys."""
    valid = np.asarray(recs.valid, bool)
    n_valid = int(valid.sum())
    n = max(n_valid, 1)
    met = int(np.asarray(recs.met)[valid].sum())
    wait = np.asarray(recs.wait)[valid]
    return {
        "tasks": n_valid,
        "makespan_s": float(jnp.max(state.T)),
        "total_energy_j": float(jnp.sum(state.E)),
        "r_balance": float(jnp.mean(state.R_Balance)),
        "total_ms": float(jnp.sum(state.MS)),
        "mean_wait_s": float(wait.mean()) if n_valid else 0.0,
        "stm_rate": met / n,
        "gvalue": float(gvalue_state(spec, state)),
    }


def kind_feature_table() -> np.ndarray:
    """[n_kinds, 2] scaled (Amount, LayerNum) Task-Info features, matching
    ``tasks.task_features`` — kind-dependent only, so built once."""
    from repro.core.tasks import _model_stats
    stats = _model_stats()
    return np.asarray(
        [[stats[k.value]["macs"] / 30e9, stats[k.value]["layers"] / 100.0]
         for k in KIND_ORDER], np.float32)
