"""HMAI — the heterogeneous multicore AI platform (paper §5.2, §8.2).

The paper evaluates HMAI with a cycle-accurate simulator + TSMC-12nm
synthesis; neither is available here, so the per-accelerator performance
model is *calibrated to the paper's published measurements* (Table 8 FPS)
and the power budget to §8.2's ratios (HMAI ~= 2x Tesla T4 power with the
(4 SconvOD, 4 SconvIC, 3 MconvMC) configuration).  Every calibrated
constant is marked below.

The platform object is an event-driven queue simulator: schedulers
(FlexAI / Min-Min / ATA / GA / SA / worst-case) assign each arriving task
to an accelerator; the platform tracks per-accelerator time, energy,
utilization balance and Matching Score — the four reward metrics of §7.2.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.criteria import gvalue, matching_score
from repro.core.taxonomy import TAXONOMY, AcceleratorArch
from repro.core.tasks import KIND_INDEX, KIND_ORDER, Task, TaskKind


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    name: str
    arch: AcceleratorArch
    fps: dict            # TaskKind.value -> frames/s   [Table 8, measured]
    power_w: float       # [calibrated: (4,4,3) config ~= 137 W ~= 2x T4]

    def exec_time(self, kind: TaskKind) -> float:
        return 1.0 / self.fps[kind.value]

    def energy(self, kind: TaskKind) -> float:
        return self.power_w * self.exec_time(kind)


# Table 8 (paper-measured FPS per accelerator per model)
ACCELERATOR_SPECS = {
    "SconvOD": AcceleratorSpec(
        name="SconvOD", arch=TAXONOMY["SconvOD"],
        fps={"yolo": 170.37, "ssd": 74.99, "goturn": 352.69},
        power_w=12.0),
    "SconvIC": AcceleratorSpec(
        name="SconvIC", arch=TAXONOMY["SconvIC"],
        fps={"yolo": 132.54, "ssd": 82.94, "goturn": 350.34},
        power_w=11.0),
    "MconvMC": AcceleratorSpec(
        name="MconvMC", arch=TAXONOMY["MconvMC"],
        fps={"yolo": 149.32, "ssd": 82.57, "goturn": 500.54},
        power_w=15.0),
}

# NVIDIA Tesla T4 baseline [calibrated so HMAI ~= 5x speedup, Fig 10]
T4_SPEC = AcceleratorSpec(
    name="TeslaT4", arch=TAXONOMY["MconvMC"],
    fps={"yolo": 120.0, "ssd": 55.0, "goturn": 250.0},
    power_w=70.0)

# HMAI configuration chosen in §8.2 via Fig 2 resource-utilization analysis
HMAI_CONFIG = (("SconvOD", 4), ("SconvIC", 4), ("MconvMC", 3))

# homogeneous baselines (§8.2): max accelerator count over all scenarios
HOMOGENEOUS_CONFIGS = {
    "homo-SconvOD": (("SconvOD", 13),),
    "homo-SconvIC": (("SconvIC", 13),),
    "homo-MconvMC": (("MconvMC", 12),),
}


def accelerator_fps(name: str, kind: TaskKind) -> float:
    return ACCELERATOR_SPECS[name].fps[kind.value]


@dataclasses.dataclass
class TaskRecord:
    task: Task
    accel_index: int
    start: float
    finish: float
    wait: float
    exec_time: float
    response_time: float
    ms: float
    energy: float


class HMAIPlatform:
    """Queue-level simulator of a (possibly heterogeneous) accelerator pool.

    Per-accelerator state (HW-Info, §7.2): E_i, T_i, R_Balance_i, MS_i.
    """

    def __init__(self, config=HMAI_CONFIG, capacity_scale: float = 1.0,
                 specs: list | None = None):
        """``capacity_scale`` scales accelerator FPS.  Experiments that
        subsample camera rates (``EnvironmentParams.rate_scale``) pass the
        same factor here so the load ratio (arrival rate / service rate)
        matches the full-rate deployment while the task count stays
        CPU-tractable.  ``specs`` overrides ``config`` with explicit
        AcceleratorSpec objects (e.g. a Tesla-T4 baseline platform)."""
        if specs is None:
            specs = []
            for name, count in config:
                specs.extend([ACCELERATOR_SPECS[name]] * count)
        self.specs = [
            dataclasses.replace(
                s, fps={k: v * capacity_scale for k, v in s.fps.items()})
            if capacity_scale != 1.0 else s
            for s in specs
        ]
        self.n = len(self.specs)
        self.capacity_scale = capacity_scale
        self.reset()

    def reset(self) -> None:
        self.avail = np.zeros(self.n)        # next-free time per accelerator
        self.busy = np.zeros(self.n)         # cumulative busy seconds
        self.E = np.zeros(self.n)
        self.T = np.zeros(self.n)
        self.MS = np.zeros(self.n)
        self.R_Balance = np.zeros(self.n)
        self.num_tasks = np.zeros(self.n, dtype=np.int64)
        self.records: list[TaskRecord] = []
        self._e_scale = 1e-9   # running scale (HW-Info display)
        self._t_scale = 1e-9
        # TaskKind x accelerator tables, built once: schedulers and the RL
        # state vector read these instead of re-deriving per task, and the
        # device-resident engine (platform_jax) lifts them to jnp wholesale.
        self.exec_time_table = np.asarray(
            [[s.exec_time(k) for k in KIND_ORDER] for s in self.specs])
        self.energy_table = np.asarray(
            [[s.energy(k) for k in KIND_ORDER] for s in self.specs])
        # Gvalue normalization (§6.2 "after normalization"): per-task scales
        # — mean task exec time / energy across the platform — so the T and
        # E terms of Gvalue exert per-decision pressure comparable to MS.
        # (A running-max normalization makes dT vanish as the route grows,
        # which rewards deadline-edge queueing; see DESIGN.md.)
        self.gvalue_t_scale = float(self.exec_time_table.mean())
        self.gvalue_e_scale = float(self.energy_table.mean())

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------

    @property
    def total_energy(self) -> float:
        return float(self.E.sum())

    @property
    def makespan(self) -> float:
        return float(self.T.max()) if self.n else 0.0

    @property
    def r_balance(self) -> float:
        return float(self.R_Balance.mean())

    @property
    def total_ms(self) -> float:
        return float(self.MS.sum())

    def gvalue(self) -> float:
        return gvalue(self.total_energy, self.makespan, self.r_balance,
                      e_scale=self.gvalue_e_scale * max(
                          sum(self.num_tasks), 1),
                      t_scale=self.gvalue_t_scale)

    def hw_info(self, now: float = 0.0) -> np.ndarray:
        """[n, 4] HW-Info = (E_i, T_i, R_Balance_i, MS_i) per §7.2.

        T_i is exposed as *backlog relative to now* (seconds until H_i is
        free) — the actionable reading of "longest execution time among all
        cores" for an agent scheduling the task arriving at ``now``; E_i is
        normalized by the running scale, MS_i by its task count.
        """
        return np.stack([
            self.E / max(self._e_scale, 1e-9),
            np.maximum(self.avail - now, 0.0),
            self.R_Balance,
            self.MS / np.maximum(self.num_tasks, 1),
        ], axis=1)

    # ------------------------------------------------------------------
    # simulation
    # ------------------------------------------------------------------

    def exec_time(self, task: Task, accel_index: int) -> float:
        return float(self.exec_time_table[accel_index, KIND_INDEX[task.kind]])

    def predicted_response(self, task: Task, accel_index: int) -> float:
        """Response time if the task were scheduled now (no commit)."""
        start = max(task.arrival_time, self.avail[accel_index])
        return start + self.exec_time(task, accel_index) - task.arrival_time

    def execute(self, task: Task, accel_index: int) -> TaskRecord:
        """Commit a scheduling decision; update HW-Info (§7.2 formulas)."""
        i = accel_index
        spec = self.specs[i]
        et = spec.exec_time(task.kind)
        e = spec.energy(task.kind)
        start = max(task.arrival_time, self.avail[i])
        finish = start + et
        wait = start - task.arrival_time
        response = finish - task.arrival_time
        ms = matching_score(task.kind.value if task.kind != TaskKind.GOTURN
                            else "TRA", response, task.safety_time)

        self.avail[i] = finish
        self.busy[i] += et
        self.E[i] += e
        self.T[i] = max(self.T[i], finish)
        self.MS[i] += ms
        # paper: R_Balance_i = (r_j + R_Balance_i) / num
        r_j = self.busy[i] / max(finish, 1e-9)  # utilization of H_i so far
        self.num_tasks[i] += 1
        n = float(self.num_tasks[i])
        self.R_Balance[i] = (r_j + self.R_Balance[i] * (n - 1)) / n
        # running normalization scales for Gvalue
        self._e_scale = max(self._e_scale, self.total_energy)
        self._t_scale = max(self._t_scale, self.makespan)

        rec = TaskRecord(task=task, accel_index=i, start=start, finish=finish,
                         wait=wait, exec_time=et, response_time=response,
                         ms=ms, energy=e)
        self.records.append(rec)
        return rec

    # ------------------------------------------------------------------
    # aggregate evaluation (used by benchmarks)
    # ------------------------------------------------------------------

    def summary(self) -> dict:
        recs = self.records
        n = max(len(recs), 1)
        met = sum(1 for r in recs if r.response_time <= r.task.safety_time)
        return {
            "tasks": len(recs),
            "makespan_s": self.makespan,
            "total_energy_j": self.total_energy,
            "r_balance": self.r_balance,
            "total_ms": self.total_ms,
            "mean_wait_s": float(np.mean([r.wait for r in recs])) if recs else 0.0,
            "stm_rate": met / n,
            "gvalue": self.gvalue(),
        }
