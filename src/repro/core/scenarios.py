"""Domain-randomized scenario generator: vmapped TaskArrays families.

The paper's variability claim needs more than one replayed Table-5 route:
this module turns a base route into a fleet of randomized scenarios, as
pure jnp transforms vmapped over PRNG keys, so thousands of scenario
variants generate in one device dispatch and feed straight into the
existing engines (training lanes, scan heuristics, replay evaluation).

Named families (``FAMILIES``):

* ``clean``          — the base route, untouched (the control arm).
* ``sensor_dropout`` — camera groups fail for the whole route: each
  non-front group drops with probability ``drop_p`` and its tasks become
  invalid rows (the front-center group always survives, as a driving
  platform would never mask its primary camera).
* ``weather``        — the task *rate* scales by r ~ U(0.6, 1.6) (rain
  doubles tracker load, empty highway halves it): arrival times divide
  by r, order-preserving.
* ``burst``          — a cut-in: tasks inside a window around a random
  route point compress toward it (arrival' = c + 0.2 * (arrival - c)),
  a local 5x rate spike; the map is monotone, so arrivals stay sorted.
* ``fault``          — the base route plus an accelerator fail/degrade/
  recover health trace (``core.faults`` semantics, drawn on-device so the
  family vmaps like the rest).

Every family also returns a ``[T, n]`` health trace (all-ones except
``fault``), so downstream consumers treat scenarios uniformly as
(tasks, health) pairs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tasks import GROUP_ORDER, TaskArrays

FAMILIES = ("clean", "sensor_dropout", "weather", "burst", "fault")


class ScenarioBatch(NamedTuple):
    """A generated scenario fleet: stacked tasks [S, T], aligned health
    traces [S, T, n], and the host-side family label per row."""
    tasks: TaskArrays
    health: jax.Array
    family: np.ndarray   # [S] indices into FAMILIES (host array)

    @property
    def num_scenarios(self) -> int:
        return int(self.health.shape[0])

    def family_rows(self, name: str) -> np.ndarray:
        return np.nonzero(self.family == FAMILIES.index(name))[0]


# ---------------------------------------------------------------------------
# per-family transforms (single scenario; vmapped over keys by the batcher)
# ---------------------------------------------------------------------------

def _clean(base: TaskArrays, key) -> TaskArrays:
    return base


def _sensor_dropout(base: TaskArrays, key, drop_p: float = 0.4
                    ) -> TaskArrays:
    n_groups = len(GROUP_ORDER)
    keep = jax.random.bernoulli(key, 1.0 - drop_p, (n_groups,))
    keep = keep.at[0].set(True)              # front-center never drops
    return base._replace(valid=base.valid & keep[base.group])


def _weather(base: TaskArrays, key, lo: float = 0.6, hi: float = 1.6
             ) -> TaskArrays:
    rate = jax.random.uniform(key, (), minval=lo, maxval=hi)
    return base._replace(arrival=base.arrival / rate)


def _burst(base: TaskArrays, key, span_frac: float = 0.15,
           squeeze: float = 0.2) -> TaskArrays:
    total = jnp.max(jnp.where(base.valid, base.arrival, 0.0))
    k_c, = jax.random.split(key, 1)
    center = jax.random.uniform(k_c, ()) * total
    width = span_frac * total
    near = jnp.abs(base.arrival - center) < width
    squeezed = center + squeeze * (base.arrival - center)
    return base._replace(arrival=jnp.where(near, squeezed, base.arrival))


def _fault_trace(key, t: int, n_cores: int, n_faults: int = 2,
                 p_fail: float = 0.5) -> jax.Array:
    """On-device fail/degrade/recover trace: ``n_faults`` distinct cores
    (never all of them) fault in the first two-thirds of the route and
    recover later — the jnp twin of ``faults.random_fault_events``."""
    n_faults = int(min(n_faults, max(n_cores - 1, 0)))
    k_core, k_at, k_back, k_fail, k_deg = jax.random.split(key, 5)
    cores = jax.random.permutation(k_core, n_cores)[:n_faults]
    at = jax.random.randint(k_at, (n_faults,), 1, max(2 * t // 3, 2))
    back = at + jax.random.randint(k_back, (n_faults,),
                                   max(t // 6, 1), max(t, 2))
    fail = jax.random.bernoulli(k_fail, p_fail, (n_faults,))
    degrade = jax.random.uniform(k_deg, (n_faults,),
                                 minval=0.25, maxval=0.75)
    factor = jnp.where(fail, 0.0, degrade)
    steps = jnp.arange(t)
    in_window = ((steps[None, :] >= at[:, None])
                 & (steps[None, :] < back[:, None]))        # [F, T]
    onehot = cores[:, None] == jnp.arange(n_cores)[None, :]  # [F, n]
    # cores are distinct, so the per-fault deltas sum without clashing
    delta = jnp.sum(in_window[:, :, None] * onehot[:, None, :]
                    * (factor[:, None, None] - 1.0), axis=0)
    return 1.0 + delta                                       # [T, n]


# ---------------------------------------------------------------------------
# the batcher
# ---------------------------------------------------------------------------

def scenario_batch(base: TaskArrays, n_cores: int, seed: int,
                   n_per_family: int = 8,
                   families: tuple = FAMILIES) -> ScenarioBatch:
    """Generate ``n_per_family`` scenarios per family from one base route,
    each family in a single vmapped dispatch.  Deterministic in ``seed``.
    """
    t = int(np.asarray(base.arrival).shape[0])
    transforms = {
        "clean": _clean,
        "sensor_dropout": _sensor_dropout,
        "weather": _weather,
        "burst": _burst,
        "fault": _clean,
    }
    key = jax.random.PRNGKey(seed)
    task_stacks, health_stacks, labels = [], [], []
    for fi, name in enumerate(families):
        fkey = jax.random.fold_in(key, fi)
        keys = jax.random.split(fkey, n_per_family)
        tasks = jax.vmap(transforms[name], in_axes=(None, 0))(base, keys)
        if name == "fault":
            health = jax.vmap(
                lambda k: _fault_trace(k, t, n_cores))(keys)
        else:
            health = jnp.ones((n_per_family, t, n_cores), jnp.float32)
        task_stacks.append(tasks)
        health_stacks.append(health)
        labels.extend([FAMILIES.index(name)] * n_per_family)
    tasks = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs), *task_stacks)
    return ScenarioBatch(tasks=tasks,
                         health=jnp.concatenate(health_stacks),
                         family=np.asarray(labels, np.int32))


def scenario_lane_batches(batch: ScenarioBatch, lanes: int):
    """Host-side iterator over [lanes, T] / [lanes, T, n] slices (order
    shuffled deterministically by scenario index) — the shape the
    population trainer's ``train_episode(tasks, health=...)`` consumes.
    The tail partial batch is dropped."""
    s = batch.num_scenarios
    order = np.random.default_rng(s).permutation(s)
    for i in range(0, s - lanes + 1, lanes):
        rows = np.sort(order[i:i + lanes])
        yield (jax.tree_util.tree_map(lambda a: a[rows], batch.tasks),
               batch.health[rows])
