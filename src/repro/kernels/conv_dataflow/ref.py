"""Pure-jnp conv2d oracle (im2col einsum — no lax.conv).

Layout: x [N, H, W, Cin], w [KH, KW, Cin, Cout], stride 1, VALID padding.
Output [N, H-KH+1, W-KW+1, Cout].
"""
from __future__ import annotations

import jax.numpy as jnp


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    n, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    ho, wo = h - kh + 1, wd - kw + 1
    out = jnp.zeros((n, ho, wo, cout), dtype=jnp.promote_types(x.dtype,
                                                               jnp.float32))
    for di in range(kh):
        for dj in range(kw):
            patch = x[:, di: di + ho, dj: dj + wo, :]  # [N, Ho, Wo, Cin]
            out = out + jnp.einsum(
                "nhwc,co->nhwo", patch.astype(jnp.float32),
                w[di, dj].astype(jnp.float32))
    return out.astype(x.dtype)
