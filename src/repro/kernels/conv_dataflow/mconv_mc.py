"""MconvMC — Mconv-MP-CR archetype (Origami) as a Pallas TPU kernel.

Taxonomy mapping (DESIGN.md §3):
  * Mconv: each BasicUnit iteration processes MULTIPLE 2D convolutions —
    a [Tc (in-channel) x Tm (out-channel)] tile of channel pairs at once,
    as an im2col matrix multiplication on the MXU (Origami's matrix unit;
    Table 10's ">1 MAC per PE" + on-chip buffer).
  * MP (multiple propagation): both ifmap patches and filter tiles stream
    through the systolic array each step.
  * CR: psums live in a shared VMEM accumulator across the sequential
    in-channel grid dimension.

Grid: (N, Cout_tiles, Cin_tiles) with Cin sequential ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, kh: int, kw: int):
    ci_step = pl.program_id(2)
    n_ci = pl.num_programs(2)

    @pl.when(ci_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ho, wo = o_ref.shape[0], o_ref.shape[1]
    tc = x_ref.shape[-1]
    tm = o_ref.shape[-1]
    # im2col GEMM: each tap contributes [Ho*Wo, Tc] @ [Tc, Tm] on the MXU
    acc = acc_ref[...].reshape(ho * wo, tm)
    for di in range(kh):
        for dj in range(kw):
            patch = x_ref[pl.ds(di, ho), pl.ds(dj, wo), :]   # [Ho, Wo, Tc]
            mat = patch.reshape(ho * wo, tc)
            acc += jax.lax.dot(
                mat.astype(jnp.float32),
                w_ref[di, dj, :, :].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    acc_ref[...] = acc.reshape(ho, wo, tm)

    @pl.when(ci_step == n_ci - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def mconv_mc(x: jax.Array, w: jax.Array, *, cout_tile: int = 128,
             cin_tile: int = 32, interpret: bool = False) -> jax.Array:
    """x [N,H,W,Cin], w [KH,KW,Cin,Cout] -> [N,Ho,Wo,Cout] (stride 1, VALID)."""
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, wd - kw + 1
    cout_tile = min(cout_tile, cout)
    cin_tile = min(cin_tile, cin)
    assert cout % cout_tile == 0 and cin % cin_tile == 0
    grid = (n, cout // cout_tile, cin // cin_tile)

    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, h, wd, cin_tile),
                         lambda b, co, ci: (b, 0, 0, ci)),
            pl.BlockSpec((kh, kw, cin_tile, cout_tile),
                         lambda b, co, ci: (0, 0, ci, co)),
        ],
        out_specs=pl.BlockSpec((None, ho, wo, cout_tile),
                               lambda b, co, ci: (b, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho, wo, cout_tile), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="mconv_mc",
    )(x, w)
