"""SconvOD — Sconv-OP-DR archetype (NeuFlow) as a Pallas TPU kernel.

Taxonomy mapping (DESIGN.md §3):
  * Sconv: one whole 2D convolution (one input channel's contribution to
    all output pixels) per BasicUnit iteration.
  * OP (ofmaps propagate): partial sums accumulate ACROSS sequential grid
    steps over input channels — the VMEM accumulator plays the role of the
    PE->PE psum FIFO chain.
  * DR (dispersive registers): the filter taps for the current channel
    slice stay resident (weight-stationary) while the ifmap streams —
    per-PE weight registers become the resident VMEM filter block.

Compute style: tap-by-tap shifted multiply-accumulate over the output
plane (VPU lanes = the PE array), NOT an MXU matmul — matching the
paper's "1 MAC per PE, no on-chip buffer" row of Table 10.

Grid: (N, Cin_tiles) with the channel dim sequential ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, kh: int, kw: int, cin_tile: int):
    ci_step = pl.program_id(1)
    n_ci = pl.num_programs(1)

    @pl.when(ci_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ho, wo = o_ref.shape[0], o_ref.shape[1]
    acc = acc_ref[...]
    # whole-2D-conv per channel: shifted planes x resident taps (VPU MACs)
    for ci in range(cin_tile):
        for di in range(kh):
            for dj in range(kw):
                plane = x_ref[di: di + ho, dj: dj + wo, ci]      # [Ho, Wo]
                taps = w_ref[di, dj, ci, :]                      # [Cout]
                acc += plane[:, :, None].astype(jnp.float32) * \
                    taps[None, None, :].astype(jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci_step == n_ci - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sconv_od(x: jax.Array, w: jax.Array, *, cin_tile: int = 8,
             interpret: bool = False) -> jax.Array:
    """x [N,H,W,Cin], w [KH,KW,Cin,Cout] -> [N,Ho,Wo,Cout] (stride 1, VALID)."""
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, wd - kw + 1
    # the channel grid covers ceil(cin / cin_tile) full tiles: prime
    # channel counts zero-pad to the next tile boundary (zero ifmap
    # channels contribute exactly nothing to the accumulator) instead of
    # degrading to cin_tile=1
    cin_tile = min(cin_tile, cin)
    n_ci = pl.cdiv(cin, cin_tile)
    cin_pad = n_ci * cin_tile
    if cin_pad != cin:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cin_pad - cin)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, cin_pad - cin), (0, 0)))
    grid = (n, n_ci)

    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, cin_tile=cin_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, h, wd, cin_tile),
                         lambda b, c: (b, 0, 0, c)),
            pl.BlockSpec((kh, kw, cin_tile, cout),
                         lambda b, c: (0, 0, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, ho, wo, cout),
                               lambda b, c: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho, wo, cout), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="sconv_od",
    )(x, w)
