"""jit'd wrappers for the three conv-dataflow kernels.

``conv2d(x, w, dataflow=...)`` handles SAME/VALID padding and stride by
pre-padding / post-slicing around the stride-1 VALID kernels, picks
hardware-aligned tile sizes, and falls back to interpret mode off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import pallas_interpret_default
from repro.kernels.conv_dataflow.mconv_mc import mconv_mc
from repro.kernels.conv_dataflow.ref import conv2d_ref
from repro.kernels.conv_dataflow.sconv_ic import sconv_ic
from repro.kernels.conv_dataflow.sconv_od import sconv_od

DATAFLOWS = ("SconvOD", "SconvIC", "MconvMC")


def _tile(n: int, target: int) -> int:
    # largest divisor <= target: still required by mconv_mc, whose grid
    # must divide the channel dims exactly.  sconv_ic / sconv_od pad to
    # the requested tile internally (masked/zero tail blocks), so they
    # take `target` directly and prime dims no longer degrade the grid.
    t = min(target, n)
    while n % t:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("dataflow", "stride", "padding",
                                             "interpret"))
def conv2d(x: jax.Array, w: jax.Array, *, dataflow: str = "MconvMC",
           stride: int = 1, padding: str = "VALID",
           interpret: bool | None = None) -> jax.Array:
    """Conv2d through one of the paper's accelerator dataflows.

    x [N,H,W,Cin], w [KH,KW,Cin,Cout].
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    kh, kw, cin, cout = w.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        x = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))

    if dataflow == "SconvOD":
        out = sconv_od(x, w, cin_tile=8, interpret=interpret)
    elif dataflow == "SconvIC":
        out = sconv_ic(x, w, row_tile=8, interpret=interpret)
    elif dataflow == "MconvMC":
        out = mconv_mc(x, w, cout_tile=_tile(cout, 128),
                       cin_tile=_tile(cin, 32), interpret=interpret)
    elif dataflow == "ref":
        out = conv2d_ref(x, w)
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out
