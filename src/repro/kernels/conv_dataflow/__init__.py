from repro.kernels.conv_dataflow.ops import conv2d, DATAFLOWS
from repro.kernels.conv_dataflow.ref import conv2d_ref
from repro.kernels.conv_dataflow.sconv_od import sconv_od
from repro.kernels.conv_dataflow.sconv_ic import sconv_ic
from repro.kernels.conv_dataflow.mconv_mc import mconv_mc
