"""SconvIC — SSconv-IP-CR archetype (ShiDianNao) as a Pallas TPU kernel.

Taxonomy mapping (DESIGN.md §3):
  * SSconv: each BasicUnit iteration covers PART of a 2D convolution —
    the grid tiles the OUTPUT rows, so one invocation computes one
    output-row band (a sub-rectangle of the conv).
  * IP (ifmaps propagate): the ifmap is VMEM-resident and read at kh*kw
    shifted offsets — the shift-register ifmap propagation between PEs
    becomes shifted slices of the resident block.
  * CR (concentrated registers, never psums): the OUTPUT band is the
    stationary operand (each "PE" owns one output neuron, ShiDianNao
    style); psums never leave the accumulator until the band is done.

Grid: (N, Ho_tiles) — fully parallel; no cross-step accumulation
(contrast with SconvOD, where psums flow across sequential grid steps).
The ifmap stays whole-height in VMEM (halo rows come for free); a
production variant would use BoundedSlice halo windows instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, cin: int,
            row_tile: int):
    r = pl.program_id(1)
    row0 = r * row_tile
    wo = o_ref.shape[1]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # output-stationary: every (di, dj, ci) step broadcasts one filter tap
    # to all output neurons; the ifmap slice "shifts" across the band (IP)
    for di in range(kh):
        for dj in range(kw):
            for ci in range(cin):
                plane = x_ref[pl.ds(row0 + di, row_tile),
                              pl.ds(dj, wo), ci]                # [rt, Wo]
                taps = w_ref[di, dj, ci, :]                     # [Cout]
                acc += plane[:, :, None].astype(jnp.float32) * \
                    taps[None, None, :].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def sconv_ic(x: jax.Array, w: jax.Array, *, row_tile: int = 8,
             interpret: bool = False) -> jax.Array:
    """x [N,H,W,Cin], w [KH,KW,Cin,Cout] -> [N,Ho,Wo,Cout] (stride 1, VALID)."""
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, wd - kw + 1
    # the grid tiles output rows evenly; for odd heights fall back to the
    # largest divisor of ho that fits the requested tile
    row_tile = min(row_tile, ho)
    while ho % row_tile:
        row_tile -= 1
    grid = (n, ho // row_tile)

    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, cin=cin, row_tile=row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, h, wd, cin), lambda b, r: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda b, r: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, row_tile, wo, cout),
                               lambda b, r: (b, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="sconv_ic",
    )(x, w)
