"""SconvIC — SSconv-IP-CR archetype (ShiDianNao) as a Pallas TPU kernel.

Taxonomy mapping (DESIGN.md §3):
  * SSconv: each BasicUnit iteration covers PART of a 2D convolution —
    the grid tiles the OUTPUT rows, so one invocation computes one
    output-row band (a sub-rectangle of the conv).
  * IP (ifmaps propagate): the ifmap row *window* for the band is
    VMEM-resident and read at kh*kw shifted offsets — the shift-register
    ifmap propagation between PEs becomes shifted slices of the window.
  * CR (concentrated registers, never psums): the OUTPUT band is the
    stationary operand (each "PE" owns one output neuron, ShiDianNao
    style); psums never leave the accumulator until the band is done.

VMEM residency is **bounded**: each grid step DMAs its own
``row_tile + kh - 1`` row window (the band's rows plus the ``kh - 1``
halo rows shared with the next band) from the un-blocked ifmap
(``memory_space=ANY``) into a fixed scratch buffer.  Whole-ifmap-height
residency — the old spec, which capped the kernel at feature maps that
fit VMEM — is gone; arbitrarily tall ifmaps stream through the same
window.

The output-row grid no longer requires ``row_tile | ho``: the host pads
H so the band grid covers ``ceil(ho / row_tile)`` full tiles, the tail
band computes on zero rows (every DMA stays in-bounds by construction)
and the caller slices the pad rows off.  Prime output heights keep the
requested tile instead of degrading to ``row_tile=1``.

Grid: (N, Ho_tiles) — fully parallel; no cross-step accumulation
(contrast with SconvOD, where psums flow across sequential grid steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _kernel(x_hbm, w_ref, o_ref, xwin_ref, sem, *, kh: int, kw: int,
            cin: int, row_tile: int):
    b = pl.program_id(0)
    r = pl.program_id(1)
    # halo window DMA: this band's row_tile rows + kh-1 shared halo rows
    pltpu.make_async_copy(
        x_hbm.at[b, pl.ds(r * row_tile, row_tile + kh - 1)],
        xwin_ref, sem).start()
    pltpu.make_async_copy(
        x_hbm.at[b, pl.ds(r * row_tile, row_tile + kh - 1)],
        xwin_ref, sem).wait()

    wo = o_ref.shape[1]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # output-stationary: every (di, dj, ci) step broadcasts one filter tap
    # to all output neurons; the ifmap slice "shifts" across the band (IP)
    for di in range(kh):
        for dj in range(kw):
            for ci in range(cin):
                plane = xwin_ref[pl.ds(di, row_tile),
                                 pl.ds(dj, wo), ci]              # [rt, Wo]
                taps = w_ref[di, dj, ci, :]                      # [Cout]
                acc += plane[:, :, None].astype(jnp.float32) * \
                    taps[None, None, :].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def sconv_ic(x: jax.Array, w: jax.Array, *, row_tile: int = 8,
             interpret: bool = False) -> jax.Array:
    """x [N,H,W,Cin], w [KH,KW,Cin,Cout] -> [N,Ho,Wo,Cout] (stride 1, VALID)."""
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, wd - kw + 1
    row_tile = min(row_tile, ho)
    nb = pl.cdiv(ho, row_tile)
    ho_pad = nb * row_tile
    if ho_pad != ho:
        # tail band: pad H so every window DMA is in-bounds; the padded
        # output rows are computed on zero rows and sliced off below
        x = jnp.pad(x, ((0, 0), (0, ho_pad - ho), (0, 0), (0, 0)))
    grid = (n, nb)

    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, cin=cin, row_tile=row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((kh, kw, cin, cout), lambda b, r: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, row_tile, wo, cout),
                               lambda b, r: (b, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho_pad, wo, cout), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((row_tile + kh - 1, wd, cin), x.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="sconv_ic",
    )(x, w)
    return out[:, :ho] if ho_pad != ho else out
