"""Pure-jnp attention oracle."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool, scale: float):
    """q [G, Sq, D], k/v [G, Skv, D] -> [G, Sq, D]."""
    s = jnp.einsum("gqd,gkd->gqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("gqk,gkd->gqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
