"""Flash attention (block-wise online softmax) as a Pallas TPU kernel.

Grid (B*H, nQ, nKV) with the KV dimension sequential; per-(head, q-block)
VMEM scratch carries the running max / normalizer / accumulator.  Causal
blocks strictly above the diagonal are SKIPPED via ``pl.when`` — unlike
the XLA fallback (``models.attention.chunked_attention``), which must
compute-and-mask them.  This kernel is the TPU fast path; the dry-run on
the CPU host platform measures the fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip kv blocks strictly above the diagonal
    needed = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # [bq, d]
        k = k_ref[...].astype(jnp.float32)            # [bk, d]
        v = v_ref[...].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...][:, 0]                      # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = (l_ref[...][:, 0] * alpha + jnp.sum(p, axis=1))[:, None]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new[:, None]

    @pl.when(j == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_flat(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool, scale: float, block_q: int = 128,
                         block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q [G, Sq, D], k/v [G, Skv, D] (G = batch*heads, pre-broadcast)."""
    g, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (g, sq // block_q, skv // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # normalizer
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
