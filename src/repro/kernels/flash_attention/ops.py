"""jit'd wrapper: [B,S,H,D] GQA interface over the flat flash kernel."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.compat import pallas_interpret_default
from repro.kernels.flash_attention.kernel import flash_attention_flat


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q [B,Sq,H,D]; k/v [B,Skv,K,D] with K dividing H (GQA broadcast)."""
    if interpret is None:
        interpret = pallas_interpret_default()
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if kh != h:
        reps = h // kh
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    scale = 1.0 / math.sqrt(d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    of = flash_attention_flat(qf, kf, vf, causal=causal, scale=scale,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
