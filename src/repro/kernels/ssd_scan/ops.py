"""jit'd wrapper for the SSD scan kernel ([B,S,H,P] interface)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import pallas_interpret_default
from repro.kernels.ssd_scan.kernel import ssd_scan_flat


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(u: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array, *,
             chunk: int = 128, interpret: bool | None = None):
    """u [B,S,H,P]; a [B,S,H]; Bm/Cm [B,S,N] (shared over heads).

    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    b, s, h, p = u.shape
    n = Bm.shape[-1]
    uf = u.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    af = a.transpose(0, 2, 1).reshape(b * h, s)
    y, sfin = ssd_scan_flat(uf, af, Bm, Cm, chunk=chunk, n_heads=h,
                            interpret=interpret)
    return (y.reshape(b, h, s, p).transpose(0, 2, 1, 3),
            sfin.reshape(b, h, n, p))
