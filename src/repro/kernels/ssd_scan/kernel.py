"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid (B*H, nChunks) with chunks sequential; the inter-chunk SSD state
[P, N] lives in VMEM scratch, so the recurrence never round-trips HBM.
Within a chunk everything is matmul-shaped (the SSD duality): the decay
matrix L, the C·Bᵀ score block, and the state update are MXU work.

B/C projections are shared across heads (ngroups=1); the wrapper indexes
them with g // H inside the BlockSpec index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _kernel(u_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[...].astype(jnp.float32)       # [Q, P]
    a = a_ref[...][:, 0].astype(jnp.float32)  # [Q]
    Bm = b_ref[...].astype(jnp.float32)      # [Q, N]
    Cm = c_ref[...].astype(jnp.float32)      # [Q, N]

    a_cum = jnp.cumsum(a)                    # [Q]
    # intra-chunk decay matrix L[i,j] = exp(a_cum[i]-a_cum[j]) for i >= j
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = a_cum[:, None] - a_cum[None, :]
    L = jnp.where(rows >= cols, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # [Q, Q]
    y_diag = jax.lax.dot(scores * L, u,
                         preferred_element_type=jnp.float32)  # [Q, P]

    s_prev = state_ref[...]                  # [N, P]
    in_decay = jnp.exp(a_cum)                # [Q]
    y_off = jax.lax.dot(Cm * in_decay[:, None], s_prev,
                        preferred_element_type=jnp.float32)   # [Q, P]

    decay_end = jnp.exp(a_cum[-1] - a_cum)   # [Q]
    s_chunk = jax.lax.dot_general(
        Bm * decay_end[:, None], u, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [N, P]
    state_ref[...] = s_chunk + jnp.exp(a_cum[-1]) * s_prev

    y_ref[...] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _flush():
        sfin_ref[...] = state_ref[...].astype(sfin_ref.dtype)


def ssd_scan_flat(u: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                  *, chunk: int = 128, n_heads: int = 1,
                  interpret: bool = False):
    """u [G, S, P]; a [G, S]; Bm/Cm [G//n_heads, S, N] (head-shared).

    Returns (y [G, S, P], final_state [G, N, P]).
    """
    g, s, p = u.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (g, s // chunk)

    y, sfin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda gi, ci: (gi, ci, 0)),
            pl.BlockSpec((None, chunk, 1), lambda gi, ci: (gi, ci, 0)),
            pl.BlockSpec((None, chunk, n),
                         lambda gi, ci: (gi // n_heads, ci, 0)),
            pl.BlockSpec((None, chunk, n),
                         lambda gi, ci: (gi // n_heads, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, p), lambda gi, ci: (gi, ci, 0)),
            pl.BlockSpec((None, n, p), lambda gi, ci: (gi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, s, p), u.dtype),
            jax.ShapeDtypeStruct((g, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="ssd_scan",
    )(u, a[..., None], Bm, Cm)
    return y, sfin
