"""Pure-jnp SSD oracle: direct per-token recurrence (lax.scan over time).

    h_t = exp(a_t) * h_{t-1} + B_t (outer) u_t
    y_t = C_t . h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(u, a, Bm, Cm):
    """u [G,S,P]; a [G,S]; Bm/Cm [G,S,N] (pre-broadcast to G).

    Returns (y [G,S,P], final state [G,N,P]).
    """
    g, s, p = u.shape
    n = Bm.shape[-1]

    def step(h, inp):
        u_t, a_t, b_t, c_t = inp
        h = jnp.exp(a_t)[:, None, None] * h + jnp.einsum(
            "gn,gp->gnp", b_t.astype(jnp.float32), u_t.astype(jnp.float32))
        y_t = jnp.einsum("gn,gnp->gp", c_t.astype(jnp.float32), h)
        return h, y_t

    h0 = jnp.zeros((g, n, p), jnp.float32)
    xs = (u.transpose(1, 0, 2), a.astype(jnp.float32).T,
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(u.dtype), h_fin
