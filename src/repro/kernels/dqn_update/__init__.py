"""Pallas fused DQN TD-update (see kernel.py for the dataflow design)."""
from .kernel import dqn_td_pallas  # noqa: F401
from .ops import (BATCH_TILE, dqn_td_grads_fused,  # noqa: F401
                  dqn_td_update_fused)
from .ref import dqn_td_grads_ref, dqn_td_update_ref  # noqa: F401
