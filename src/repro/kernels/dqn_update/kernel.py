"""Fused DQN TD-update as a single Pallas kernel.

The dataflow lesson of the HMAI conv kernels (and of Liu et al.'s
dataflow accelerator, arXiv:2109.07047) applied to the trainer's compute
floor: the p0..p5 MLP (two ReLU layers + linear head, a few hundred KB)
stays **resident in VMEM** while the [B, D] replay batch **streams**
through a sequential grid of row tiles.  One kernel invocation covers
what the XLA path spreads over a dozen HBM-bouncing ops:

  1. EvalNet forward on ``s``   (residuals z1/h1/z2/h2 kept in registers)
  2. double-DQN target: EvalNet argmax on ``s_next`` (first-max
     tie-break, computed as a min over matching lane indices — no
     ``argmax`` primitive needed), TargNet values the chosen action
  3. Huber TD loss against ``y = r + gamma * (1 - done) * q_tn``
     (``y`` is a constant of the backward pass, exactly like the
     oracle's ``stop_gradient``)
  4. hand-derived backward (see below) accumulated into VMEM scratch
     across batch tiles
  5. at the last tile: global-norm clip at 10.0, and either the clipped
     gradients are emitted (``fold_adam=False`` — the DP trainer
     ``pmean``s them before a shared Adam step) or Adam is applied in
     the same kernel (``fold_adam=True`` — the single-shard fast path).

Backward derivation (per sample, mask m in {0,1} for padded tail rows;
the 1/B of the mean loss is folded into g):

    g    = -(m / B) * clip(err, -1, 1)        # dL/dq_sel, Huber delta=1
    dq   = g * onehot(a)                      # [bt, A]
    dW3 += h2^T dq        db3 += sum_rows dq
    dh2  = (dq W3^T) * [z2 > 0]               # relu' (0 at z == 0, as
    dW2 += h1^T dh2       db2 += sum_rows dh2 #  jax.nn.relu's custom jvp)
    dh1  = (dh2 W2^T) * [z1 > 0]
    dW1 += s^T dh1        db1 += sum_rows dh1

Masked rows have err = 0, hence g = 0, hence zero contribution to every
accumulator — the tail block computes and discards, it never corrupts.

VMEM residency: params (12 tensors), one [bt, D] batch tile x 5, the six
gradient accumulators and a (1, 1) loss accumulator — bounded in B, so
arbitrarily long replay batches stream through a fixed footprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

GRAD_CLIP = 10.0
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def _forward(s, w1, b1, w2, b2, w3, b3):
    """2xReLU MLP + linear head, returning pre-activations for relu'."""
    z1 = jax.lax.dot(s, w1, preferred_element_type=jnp.float32) + b1
    h1 = jnp.maximum(z1, 0.0)
    z2 = jax.lax.dot(h1, w2, preferred_element_type=jnp.float32) + b2
    h2 = jnp.maximum(z2, 0.0)
    q = jax.lax.dot(h2, w3, preferred_element_type=jnp.float32) + b3
    return z1, h1, z2, h2, q


def _bdot(a, b):
    """[bt, M]^T @ [bt, N] -> [M, N] batch-contraction (MXU-friendly)."""
    return jax.lax.dot_general(a, b, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _td_kernel(*refs, bt: int, B: int, gamma: float, lr: float,
               fold_adam: bool):
    s_ref, a_ref, r_ref, sn_ref, dn_ref = refs[:5]
    ew = [r[...] for r in refs[5:11]]       # eval w1 b1 w2 b2 w3 b3
    tw = [r[...] for r in refs[11:17]]      # targ
    k = 17
    if fold_adam:
        mu_refs, nu_refs = refs[k:k + 6], refs[k + 6:k + 12]
        step_ref = refs[k + 12]
        k += 13
    loss_ref = refs[k]
    out_refs = refs[k + 1:k + 7]            # grads OR new params
    k += 7
    if fold_adam:
        outm_refs, outv_refs = refs[k:k + 6], refs[k + 6:k + 12]
        k += 12
    acc_refs, lacc_ref = refs[k:k + 6], refs[k + 6]

    i = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        for a in acc_refs:
            a[...] = jnp.zeros_like(a)
        lacc_ref[...] = jnp.zeros_like(lacc_ref)

    # ---- tile contribution -------------------------------------------
    n_actions = ew[4].shape[1]
    rows = i * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    msk = (rows < B).astype(jnp.float32)            # padded-tail mask
    s = s_ref[...]
    sn = sn_ref[...]

    z1, h1, z2, h2, q = _forward(s, *ew)            # EvalNet(s)
    _, _, _, _, qn_e = _forward(sn, *ew)            # EvalNet(s') — argmax
    _, _, _, _, qn_t = _forward(sn, *tw)            # TargNet(s') — value

    lane = jax.lax.broadcasted_iota(jnp.int32, (bt, n_actions), 1)
    # first-max tie-break == jnp.argmax: min lane index attaining the max
    a_star = jnp.min(
        jnp.where(qn_e == jnp.max(qn_e, axis=-1, keepdims=True),
                  lane, n_actions), axis=-1, keepdims=True)
    q_tn = jnp.sum(qn_t * (lane == a_star).astype(jnp.float32),
                   axis=-1, keepdims=True)          # [bt, 1]
    oh_a = (lane == a_ref[...]).astype(jnp.float32)
    q_sel = jnp.sum(q * oh_a, axis=-1, keepdims=True)

    y = r_ref[...] + gamma * (1.0 - dn_ref[...]) * q_tn
    err = (y - q_sel) * msk                         # masked rows: err = 0
    abse = jnp.abs(err)
    huber = jnp.where(abse <= 1.0, 0.5 * err * err, abse - 0.5)
    lacc_ref[...] += jnp.sum(huber)[None, None]

    g = -(1.0 / B) * jnp.clip(err, -1.0, 1.0)       # dL/dq_sel
    dq = g * oh_a
    dh2 = jax.lax.dot_general(dq, ew[4], (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) \
        * (z2 > 0.0).astype(jnp.float32)
    dh1 = jax.lax.dot_general(dh2, ew[2], (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) \
        * (z1 > 0.0).astype(jnp.float32)
    acc_refs[0][...] += _bdot(s, dh1)               # dW1
    acc_refs[1][...] += jnp.sum(dh1, axis=0, keepdims=True)
    acc_refs[2][...] += _bdot(h1, dh2)              # dW2
    acc_refs[3][...] += jnp.sum(dh2, axis=0, keepdims=True)
    acc_refs[4][...] += _bdot(h2, dq)               # dW3
    acc_refs[5][...] += jnp.sum(dq, axis=0, keepdims=True)

    # ---- finalize: clip, then emit grads or fold Adam ----------------
    @pl.when(i == nb - 1)
    def _finalize():
        loss_ref[...] = lacc_ref[...] / B
        sq = jnp.float32(0.0)
        for a in acc_refs:
            sq += jnp.sum(a[...] * a[...])
        gnorm = jnp.sqrt(sq)
        clip = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-9))
        if not fold_adam:
            for o, a in zip(out_refs, acc_refs):
                o[...] = a[...] * clip
        else:
            step = (step_ref[0, 0] + 1).astype(jnp.float32)
            c1 = 1.0 - ADAM_B1 ** step
            c2 = 1.0 - ADAM_B2 ** step
            for p, m_r, v_r, a, op, om, ov in zip(
                    refs[5:11], mu_refs, nu_refs, acc_refs,
                    out_refs, outm_refs, outv_refs):
                gg = a[...] * clip
                m = ADAM_B1 * m_r[...] + (1.0 - ADAM_B1) * gg
                v = ADAM_B2 * v_r[...] + (1.0 - ADAM_B2) * gg * gg
                om[...] = m
                ov[...] = v
                op[...] = p[...] - lr * (m / c1) / (jnp.sqrt(v / c2)
                                                   + ADAM_EPS)


def dqn_td_pallas(s, a, r, sn, done, eval_w, targ_w, *, gamma: float,
                  batch_tile: int, interpret: bool,
                  adam=None, lr: float = 0.0):
    """Raw kernel entry point over 2-D operands.

    s/sn [B, D] f32, a [B, 1] i32, r/done [B, 1] f32; ``eval_w``/
    ``targ_w`` are 6-tuples (w1 [D,H1], b1 [1,H1], w2, b2, w3, b3 [1,A]).
    Returns ``(loss [1,1], grads 6-tuple)`` — or, with ``adam=(mu6, nu6,
    step [1,1] i32)``, ``(loss, new_params 6-tuple, new_mu, new_nu)``.
    """
    B, d = s.shape
    fold_adam = adam is not None
    bt = min(batch_tile, B)
    nb = pl.cdiv(B, bt)
    bp = nb * bt
    if bp != B:
        pad = ((0, bp - B), (0, 0))
        s, a, r, sn, done = (jnp.pad(x, pad) for x in (s, a, r, sn, done))

    pshapes = [w.shape for w in eval_w]
    batch_dims = [d, 1, 1, d, 1]

    def bspec(dim):
        return pl.BlockSpec((bt, dim), lambda i: (i, 0))

    def pspec(shape):
        return pl.BlockSpec(shape, lambda i: (0, 0))

    in_specs = [bspec(dim) for dim in batch_dims]
    in_specs += [pspec(sh) for sh in pshapes] * 2
    inputs = [s, a, r, sn, done, *eval_w, *targ_w]
    if fold_adam:
        mu, nu, step = adam
        in_specs += [pspec(sh) for sh in pshapes] * 2 \
            + [pspec((1, 1))]
        inputs += [*mu, *nu, step]

    out_specs = [pspec((1, 1))] + [pspec(sh) for sh in pshapes]
    out_shape = [jax.ShapeDtypeStruct((1, 1), jnp.float32)] \
        + [jax.ShapeDtypeStruct(sh, jnp.float32) for sh in pshapes]
    if fold_adam:
        out_specs += [pspec(sh) for sh in pshapes] * 2
        out_shape += [jax.ShapeDtypeStruct(sh, jnp.float32)
                      for sh in pshapes] * 2

    scratch = [pltpu.VMEM(sh, jnp.float32) for sh in pshapes] \
        + [pltpu.VMEM((1, 1), jnp.float32)]

    outs = pl.pallas_call(
        functools.partial(_td_kernel, bt=bt, B=B, gamma=gamma, lr=lr,
                          fold_adam=fold_adam),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="dqn_td_update" if fold_adam else "dqn_td_grads",
    )(*inputs)

    loss = outs[0]
    if not fold_adam:
        return loss, tuple(outs[1:7])
    return loss, tuple(outs[1:7]), tuple(outs[7:13]), tuple(outs[13:19])
