"""Drop-in fused TD-update entry points.

``dqn_td_grads_fused`` / ``dqn_td_update_fused`` mirror the signatures of
:func:`repro.core.flexai.dqn.dqn_td_grads` / ``dqn_td_update`` exactly, so
the engine swaps them in behind ``ScanFlexAI(td_kernel=True)`` without
touching the ``(loss, grads)`` / ``adam_apply`` seam:

* the grads variant emits *clipped* gradients — the DP trainer still
  ``ravel_pytree``s and ``lax.pmean``s them across route shards before a
  shared :func:`adam_apply`, exactly as with the XLA oracle;
* the update variant folds the Adam step into the same kernel pass (the
  single-shard fast path); the ``AdamState.step`` counter increments
  host-side, matching ``adam_apply``.

This layer owns the batch-dict plumbing: 1-D replay fields reshape to the
2-D layouts Mosaic wants ([B] -> [B, 1], biases [H] -> [1, H]) and back.
Batch padding to the tile grid lives in ``kernel.py`` (masked tail
blocks).  ``interpret=None`` defers to
:func:`repro.compat.pallas_interpret_default`, which honors the
``REPRO_KERNEL_COMPILED`` hardware-run protocol (see
``repro.kernels.protocol``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.compat import pallas_interpret_default
from repro.core.flexai.dqn import AdamState, DQNParams

from .kernel import dqn_td_pallas

# Default batch-row tile: one tile covers the engine's replay batches
# (FlexAIConfig.batch_size <= 128 everywhere in the repo), so the grid is
# a single step and accumulation order matches the oracle's single matmul.
BATCH_TILE = 128


def _batch_2d(batch: dict):
    s = jnp.asarray(batch["s"], jnp.float32)
    b = s.shape[0]
    return (s,
            jnp.asarray(batch["a"], jnp.int32).reshape(b, 1),
            jnp.asarray(batch["r"], jnp.float32).reshape(b, 1),
            jnp.asarray(batch["s_next"], jnp.float32),
            jnp.asarray(batch["done"], jnp.float32).reshape(b, 1))


def _params_2d(p: DQNParams):
    return (p.w1, p.b1.reshape(1, -1), p.w2, p.b2.reshape(1, -1),
            p.w3, p.b3.reshape(1, -1))


def _params_back(flat, like: DQNParams) -> DQNParams:
    return DQNParams(flat[0], flat[1].reshape(like.b1.shape),
                     flat[2], flat[3].reshape(like.b2.shape),
                     flat[4], flat[5].reshape(like.b3.shape))


def dqn_td_grads_fused(eval_p: DQNParams, targ_p: DQNParams, batch: dict,
                       gamma: float = 0.95, *, batch_tile: int = BATCH_TILE,
                       interpret: bool | None = None):
    """Fused-kernel counterpart of :func:`dqn.dqn_td_grads`.

    Returns ``(loss, grads)`` with the 10.0 global-norm clip applied —
    the DP trainer's pmean seam consumes this unchanged.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    s, a, r, sn, dn = _batch_2d(batch)
    loss, grads = dqn_td_pallas(
        s, a, r, sn, dn, _params_2d(eval_p), _params_2d(targ_p),
        gamma=gamma, batch_tile=batch_tile, interpret=interpret)
    return loss[0, 0], _params_back(grads, eval_p)


def dqn_td_update_fused(eval_p: DQNParams, targ_p: DQNParams,
                        opt: AdamState, batch: dict, gamma: float = 0.95,
                        lr: float = 0.01, *, batch_tile: int = BATCH_TILE,
                        interpret: bool | None = None):
    """Fused-kernel counterpart of :func:`dqn.dqn_td_update` — gradients
    AND the Adam step in one kernel pass (single-shard path).

    Returns ``(new_eval_p, new_opt, loss)``.
    """
    if interpret is None:
        interpret = pallas_interpret_default()
    s, a, r, sn, dn = _batch_2d(batch)
    mu = _params_2d(opt.mu)
    nu = _params_2d(opt.nu)
    step = opt.step.astype(jnp.int32).reshape(1, 1)
    loss, new_p, new_mu, new_nu = dqn_td_pallas(
        s, a, r, sn, dn, _params_2d(eval_p), _params_2d(targ_p),
        gamma=gamma, batch_tile=batch_tile, interpret=interpret,
        adam=(mu, nu, step), lr=lr)
    new_opt = AdamState(opt.step + 1,
                        _params_back(new_mu, eval_p),
                        _params_back(new_nu, eval_p))
    return _params_back(new_p, eval_p), new_opt, loss[0, 0]
