"""Reference implementation for the fused TD-update kernel.

Unlike the conv kernels (whose pure-jnp references live beside them),
the TD-update oracle IS the production trainer math:
:func:`repro.core.flexai.dqn.dqn_td_grads` (``jax.value_and_grad`` over
the Huber double-DQN loss + global-norm clip) and ``dqn_td_update``
(grads + ``adam_apply``).  Re-exported here so kernel tests and the
benchmark pin parity against one canonical name, and so this package
follows the kernel-layer convention (kernel.py / ops.py / ref.py).
"""
from repro.core.flexai.dqn import (adam_apply, dqn_td_grads,  # noqa: F401
                                   dqn_td_update, qnet_apply)

dqn_td_grads_ref = dqn_td_grads
dqn_td_update_ref = dqn_td_update
