"""Hardware-run protocol for the Pallas kernel layer.

The kernel suite has two honest execution modes:

* **interpret** (always available): Pallas executes the kernel body as
  ordinary XLA ops.  This validates the *math* — parity against the
  pure-jnp/autodiff oracles — on any host, which is what CPU CI runs.
  It validates nothing about Mosaic lowering, VMEM budgets or real tiles.
* **compiled** (``REPRO_KERNEL_COMPILED=1`` on a TPU/GPU host): the same
  call sites lower through Mosaic/Triton and run on the accelerator.
  This is the only mode whose timings mean anything; CI runs it when the
  hardware exists and otherwise prints an explicit SKIPPED line — a
  kernel gate must never be silently green.

``repro.compat.pallas_interpret_default`` consumes the same env contract
(it is the default for every kernel's ``interpret=`` argument); this
module is the introspection side used by tests, ``benchmarks/kernels.py``
and ``scripts/ci.sh``.
"""
from __future__ import annotations

import os

import jax


def accelerator_platform() -> str | None:
    """"tpu" / "gpu" when the default backend is one, else None."""
    plat = jax.devices()[0].platform
    return plat if plat in ("tpu", "gpu") else None


def compiled_requested() -> bool:
    """True when the env asked for the compiled hardware run."""
    return os.environ.get("REPRO_KERNEL_COMPILED") == "1"


def compiled_available() -> bool:
    """True when kernels will actually run compiled: hardware present AND
    either it is a TPU (compiles by default) or the compiled run was
    requested explicitly.  ``REPRO_KERNEL_COMPILED=0`` vetoes both."""
    from repro.compat import pallas_interpret_default
    return not pallas_interpret_default() \
        and accelerator_platform() is not None


def status() -> dict:
    """Protocol stamp for BENCH_kernels.json and skip messages."""
    plat = jax.devices()[0].platform
    return {
        "backend": plat,
        "accelerator": accelerator_platform(),
        "REPRO_KERNEL_COMPILED": os.environ.get("REPRO_KERNEL_COMPILED"),
        "compiled_run": compiled_available(),
        "mode": "compiled" if compiled_available() else "interpret",
    }
