"""Production mesh construction.

``make_production_mesh`` is a function (not module-level state) so importing
this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benchmarks see the real (1-device) platform.

Mesh construction goes through ``repro.compat.make_mesh``: on new JAX every
axis is explicitly ``AxisType.Auto``; on 0.4.x (no ``AxisType``) the kwarg
is dropped, which means the same thing.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_platform_mesh(n_stages: int = 1, devices: int | None = None):
    """Mesh for the device-resident platform engines: 1-D ``("routes",)``
    for pure data parallelism over route lanes, 2-D ``("stages",
    "routes")`` when pipeline stages are placed on accelerator groups
    (``core/pipeline.py``).  The stage axis size must equal the
    ``StagePlan``'s stage count; the route axis takes the remaining
    devices.
    """
    n_dev = devices if devices is not None else len(jax.devices())
    if n_stages <= 1:
        return make_mesh((n_dev,), ("routes",))
    if n_dev % n_stages:
        raise RuntimeError(
            f"{n_dev} device(s) not divisible into {n_stages} stage "
            f"groups; force a device count with XLA_FLAGS="
            f"--xla_force_host_platform_device_count=<k*{n_stages}>")
    return make_mesh((n_stages, n_dev // n_stages), ("stages", "routes"))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests)."""
    import numpy as np
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices; run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    return make_mesh(shape, axes)
