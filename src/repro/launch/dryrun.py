import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins for every input (state,
batch, caches — no device allocation), constructs NamedShardings from the
logical-axis rules, lowers the appropriate step (train / prefill / serve),
compiles it, and records:

  * memory_analysis()  — proves the cell fits per-device HBM
  * cost_analysis()    — HLO FLOPs / bytes for the §Roofline terms
  * collective operand bytes parsed from the optimized HLO text

Usage:
    python -m repro.launch.dryrun --arch mistral-large-123b --shape train_4k
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    python -m repro.launch.dryrun --all          # every cell, both meshes

Results append to experiments/dryrun/results.jsonl (one JSON per cell).
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cell_applicable, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.api import model_api
from repro.serve.engine import make_serve_step
from repro.sharding import (DEFAULT_RULES, Param, activate, tree_shardings,
                            unbox)
from repro.sharding.partition import DECODE_RULES
from repro.train.loop import TrainHyper, make_train_step, train_state_boxed

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO text.

    HLO printers include operand types inline, e.g.
    ``%ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %p), ...`` — the
    first typed shape on the line is the output; subsequent ones are
    operands.  We sum operand bytes per op type (the data each collective
    reads, the §Roofline collective-term numerator).
    """
    out: dict = {op: {"count": 0, "operand_bytes": 0, "output_bytes": 0}
                 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*[a-z0-9]+\[[0-9,]*\][^ ]*\s+([a-z0-9-]+)", stripped)
        if not m:
            continue
        opname = m.group(1)
        base = None
        for op in COLLECTIVE_OPS:
            if opname == op or opname.startswith(op + "-"):
                base = op
                break
        if base is None:
            continue
        shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        out_b = _shape_bytes(*shapes[0])
        opnd_b = sum(_shape_bytes(d, s) for d, s in shapes[1:])
        # tuple-shaped outputs print multiple leading shapes before the op
        # name; fall back to output bytes when operands aren't inline.
        if opnd_b == 0:
            opnd_b = out_b
        rec = out[base]
        rec["count"] += 1
        rec["operand_bytes"] += opnd_b
        rec["output_bytes"] += out_b
    out["total_operand_bytes"] = sum(
        v["operand_bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_count"] = sum(
        v["count"] for k, v in out.items() if isinstance(v, dict))
    return out


def _shardings_for(boxed_tree, mesh, rules):
    return tree_shardings(boxed_tree, mesh, rules)


def build_cell(arch_id: str, shape_name: str, multi_pod: bool,
               rules=DEFAULT_RULES, cfg_overrides: dict | None = None):
    """Returns (jitted_fn, example_args, in_shardings) ready to lower."""
    cfg = get_config(arch_id)
    if cfg_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_overrides)
    cell = SHAPES[shape_name]
    if cell.step == "decode":
        if rules is DEFAULT_RULES:
            rules = DECODE_RULES
        # serving params in bf16: halves any weight movement + HBM reads
        import dataclasses as _dc2
        cfg = _dc2.replace(cfg, param_dtype="bfloat16")
    api = model_api(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    build_cell.last_rules = rules

    boxed_batch = input_specs(cfg, shape_name)
    batch_shardings = _shardings_for(boxed_batch, mesh, rules)
    batch_sds = unbox(boxed_batch)

    if cell.step == "train":
        hyper = TrainHyper()
        step_fn = make_train_step(api, hyper)
        boxed_params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        boxed_state = train_state_boxed(boxed_params, hyper)
        state_shardings = _shardings_for(boxed_state, mesh, rules)
        state_sds = unbox(boxed_state)
        jitted = jax.jit(step_fn,
                         in_shardings=(state_shardings, batch_shardings),
                         donate_argnums=(0,))
        args = (state_sds, batch_sds)
    elif cell.step == "prefill":
        step_fn = lambda params, batch: api.prefill(params, batch)
        boxed_params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        param_shardings = _shardings_for(boxed_params, mesh, rules)
        jitted = jax.jit(step_fn,
                         in_shardings=(param_shardings, batch_shardings))
        args = (unbox(boxed_params), batch_sds)
    else:  # decode
        serve_step = make_serve_step(api)
        boxed_params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        param_shardings = _shardings_for(boxed_params, mesh, rules)
        boxed_cache = jax.eval_shape(
            lambda: api.init_cache(cell.global_batch, cell.seq_len))
        cache_shardings = _shardings_for(boxed_cache, mesh, rules)
        tok_shardings = batch_shardings["token"]
        jitted = jax.jit(
            serve_step,
            in_shardings=(param_shardings, cache_shardings, tok_shardings,
                          None),
            donate_argnums=(1,))
        args = (unbox(boxed_params), unbox(boxed_cache), batch_sds["token"],
                jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args, mesh, cfg


def _probe_overrides(cfg, n_layers: int) -> dict:
    """Overrides for a FLOPs-probe compile: unrolled layers, trip-1 inner
    loops (single-chunk attention/SSD, no grad-accum scan) so XLA's
    cost_analysis — which counts while-loop bodies ONCE — is exact."""
    out = {
        "num_layers": n_layers,
        "scan_layers": False,
        "use_grad_accum_microbatches": 1,
        "attn_chunk_kv": 1 << 30,
        "ssm_chunk": 1 << 30,
    }
    if cfg.is_encoder_decoder:
        out["num_encoder_layers"] = n_layers
    return out


def probe_flops(arch_id: str, shape_name: str, multi_pod: bool,
                rules=DEFAULT_RULES, cfg_overrides=None) -> dict:
    """Two unrolled shallow compiles -> exact per-layer HLO cost, linearly
    extrapolated to full depth:  F(L) = F1 + (L/period - 1) * (F2 - F1).

    Needed because XLA cost_analysis counts a scan body once; the production
    (scanned) compile is still what memory_analysis is taken from.
    """
    from repro.models.transformer import superblock_period
    import dataclasses as _dc
    cfg = get_config(arch_id)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    period = superblock_period(cfg)
    n_super = cfg.num_layers // period
    results = []
    for mult in (1, 2):
        over = dict(cfg_overrides or {})
        over.update(_probe_overrides(cfg, period * mult))
        jitted, args, mesh, _ = build_cell(arch_id, shape_name, multi_pod,
                                           rules, over)
        eff_rules = getattr(build_cell, "last_rules", rules)
        with activate(mesh, eff_rules):
            compiled = jitted.lower(*args).compile()
        ca = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
        results.append({
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_operand_bytes"]),
        })
    f1, f2 = results

    def extrap(key):
        # clamp: one-off setup costs in the 1-layer compile can exceed the
        # 2-layer per-layer share, which would extrapolate negative
        slope = max(0.0, f2[key] - f1[key])
        return f1[key] + max(0, n_super - 1) * slope

    return {
        "flops_per_device": extrap("flops"),
        "bytes_accessed_per_device": extrap("bytes"),
        "collective_operand_bytes": extrap("coll_bytes"),
        "per_superblock_flops": f2["flops"] - f1["flops"],
        "per_superblock_coll_bytes": f2["coll_bytes"] - f1["coll_bytes"],
        "n_superblocks": n_super,
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             rules=DEFAULT_RULES, cfg_overrides=None, save_hlo: str = "",
             rules_tag: str = "default", do_probe: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "rules": rules_tag, "status": "ok"}
    ok, why = cell_applicable(get_config(arch_id), shape_name)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    try:
        jitted, args, mesh, cfg = build_cell(
            arch_id, shape_name, multi_pod, rules, cfg_overrides)
        eff_rules = getattr(build_cell, "last_rules", rules)
        with activate(mesh, eff_rules):
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        cell = SHAPES[shape_name]
        n_tokens = cell.global_batch * cell.seq_len if cell.step != "decode" \
            else cell.global_batch
        rec.update({
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "devices": int(mesh.size),
            "tokens": n_tokens,
            "peak_bytes_per_device": int(ma.peak_memory_in_bytes),
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
            "transcendentals_per_device": float(
                ca.get("transcendentals", 0.0)),
            "collectives": coll,
            "param_count": int(cfg.param_count()),
            "active_param_count": int(cfg.active_param_count()),
            "hlo_bytes": len(hlo),
        })
        if do_probe:
            try:
                rec["probe"] = probe_flops(arch_id, shape_name, multi_pod,
                                           rules, cfg_overrides)
            except Exception as e:  # noqa: BLE001
                rec["probe"] = {"error": f"{type(e).__name__}: {e}"}
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun/results.jsonl")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args(argv)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        # the roofline table reads single-pod cells only; skip the probe
        # compiles for multi-pod (memory/collective parse still recorded)
        rec = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                       do_probe=not mp)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"peak={rec['peak_bytes_per_device']/2**30:.2f}GiB "
                     f"flops={rec['flops_per_device']:.3g} "
                     f"coll={rec['collectives']['total_operand_bytes']/2**30:.2f}GiB "
                     f"compile={rec['compile_s']}s")
        elif status == "failed":
            failures += 1
            extra = rec["error"]
        print(f"[{status:7s}] {arch} x {shape} x "
              f"{'multi' if mp else 'single'}-pod {extra}", flush=True)
    if failures:
        print(f"{failures} cell(s) FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
