"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt

Runs the fault-tolerant training driver (checkpoint every N steps, SIGTERM
preemption handling, deterministic restart).  On a real pod the same entry
point runs per host with jax.distributed initialization; on this container
it exercises the identical code path on the local device.

With ``--flexai`` the launcher instead trains the FlexAI scheduling agent
on the device-resident fused engine (the "long offline run" producing the
benchmark checkpoints) — data-parallel over all visible devices with
``--dp --shard``:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --flexai --area UB \
        --episodes 100 --dp --dp-lanes 4 --shard \
        --weights experiments/flexai/agent_ub.npz

``--td-kernel`` swaps the TD update inside the training scan for the
fused Pallas kernel (``repro.kernels.dqn_update``): EvalNet forward,
double-DQN target, Huber loss, hand-derived backward, global-norm clip
and Adam in one VMEM-resident pass.  On CPU hosts it runs in interpret
mode (numerics-faithful, not a speed claim); on TPU/GPU hosts set
``REPRO_KERNEL_COMPILED=1`` to run the compiled Mosaic/Triton kernel
(see ``repro.kernels.protocol`` and ``benchmarks/kernels.py``).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import model_api
from repro.sharding import unbox
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, batch_fn
from repro.train.fault_tolerance import (PreemptionGuard, elastic_restore,
                                         run_with_fault_tolerance)
from repro.train.loop import TrainHyper, init_train_state, make_train_step


def _trainer_snapshot(trainer, episode: int) -> dict:
    """Checkpoint pytree for a ``ScanFlexAI``: the full ``TrainState``
    (EvalNet/TargNet/Adam/replay/counters/key — every dtype the manifest
    path must round-trip), the episode cursor, and the model-selection
    best-so-far, so an interrupted run resumes bit-exactly."""
    has_best = trainer._best_params is not None
    return {
        "ts": trainer.ts,
        "episode": np.int32(episode),
        "best_stm": np.float64(trainer._best_stm),
        "has_best": np.bool_(has_best),
        "best_p": (trainer._best_params if has_best
                   else trainer.eval_params()),
    }


def run_flexai_training(args) -> int:
    """Device-resident FlexAI training: fused episodes, optional
    data-parallel sharding, eval-based model selection, npz checkpoint
    (+ loss-history sidecar) shared with ``FlexAIAgent``."""
    from repro.compat import make_mesh
    from repro.core.environment import (Area, EnvironmentParams,
                                        build_task_queue)
    from repro.core.flexai import FlexAIConfig, ScanFlexAI
    from repro.core.hmai import HMAIPlatform

    cfg = FlexAIConfig(lr=args.lr, gamma=0.98, min_replay=256,
                       update_every=2, eps_decay_steps=40_000,
                       target_sync_every=500, seed=args.seed)
    plat = HMAIPlatform(capacity_scale=args.rate_scale)
    mesh = None
    if args.shard:
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("routes",))
        print(f"training mesh: {n_dev} device(s) on axis 'routes'")
    lanes = args.dp_lanes if args.dp else 1
    trainer = ScanFlexAI(plat, cfg, lanes=lanes, mesh=mesh, dp=args.dp,
                         td_kernel=args.td_kernel)
    if args.td_kernel:
        from repro.compat import pallas_interpret_default
        mode = ("interpret (CPU host — plain XLA ops, not a speed claim)"
                if pallas_interpret_default() else "compiled")
        print(f"TD update: fused Pallas kernel, {mode}")
    if args.weights and os.path.exists(args.weights):
        trainer.load_weights(args.weights)
        print(f"resumed weights from {args.weights}")

    # full-state snapshots (TrainState + episode + model-selection best):
    # unlike --weights, a resume from these is bit-exact — the replay
    # ring, PRNG key and counters all ride along
    saver = None
    start_ep = 0
    if args.snapshot_dir:
        saver = ckpt_lib.AsyncCheckpointer(args.snapshot_dir)
        if args.resume:
            path = ckpt_lib.latest_checkpoint(args.snapshot_dir)
            if path is not None:
                snap = ckpt_lib.restore_checkpoint(
                    path, _trainer_snapshot(trainer, 0))
                trainer.ts = snap["ts"]
                # scalars come from the raw manifest arrays: device_put
                # under disabled x64 would round the float64 best-stm
                # through float32 and could flip a later model-selection
                # comparison
                _, raw, names = ckpt_lib.load_checkpoint_arrays(path)
                host = dict(zip(names, raw))
                start_ep = int(host["['episode']"])
                if bool(host["['has_best']"]):
                    trainer._best_stm = float(host["['best_stm']"])
                    trainer._best_params = snap["best_p"]
                print(f"resumed trainer snapshot at episode {start_ep}")

    def on_episode(ep, tr):
        if saver is not None and args.snapshot_every > 0 \
                and (ep + 1) % args.snapshot_every == 0:
            saver.save(ep + 1, _trainer_snapshot(tr, ep + 1))

    area = Area(args.area)
    queues = [build_task_queue(EnvironmentParams(
        area=area, route_km=args.route_km,
        rate_scale=args.rate_scale, seed=args.seed + i))
        for i in range(args.routes)]
    val_q = build_task_queue(EnvironmentParams(
        area=area, route_km=args.route_km,
        rate_scale=args.rate_scale, seed=args.seed + 50))
    n_tasks = sum(len(q) for q in queues)
    mode = f"dp lanes={lanes}" if args.dp else "single-lane"
    print(f"flexai {mode}: {args.routes} routes / {n_tasks} tasks, "
          f"{args.episodes} episodes, area={args.area}")

    t0 = time.perf_counter()
    # --episodes counts *new* episodes; the engine's `episodes` is the
    # global end index (range(start_episode, episodes))
    history = trainer.train(queues, episodes=start_ep + args.episodes,
                            eval_queue=val_q, eval_every=args.eval_every,
                            on_episode=on_episode, start_episode=start_ep)
    if saver is not None:
        saver.wait()
    dt = time.perf_counter() - t0
    for ep, h in enumerate(history):
        if "eval_stm" in h:
            print(f"  episode {start_ep + ep + 1}: eval_stm={h['eval_stm']}")
    steps = int(np.asarray(trainer.ts.env_steps).sum())
    print(f"trained {steps} env steps in {dt:.2f}s "
          f"({steps / max(dt, 1e-9):.0f} steps/s), "
          f"best_eval_stm={trainer.best_eval_stm}")
    if args.weights:
        os.makedirs(os.path.dirname(args.weights) or ".", exist_ok=True)
        trainer.save_weights(args.weights)
        np.save(args.weights[: -len(".npz")] + "_losses.npy",
                np.asarray(trainer.losses, np.float64))
        print(f"saved weights to {args.weights}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--flexai", action="store_true",
                    help="train the FlexAI scheduling agent on the fused "
                         "device-resident engine instead of an LLM arch")
    ap.add_argument("--area", default="UB",
                    help="[flexai] driving area (UB/UHW/HW)")
    ap.add_argument("--episodes", type=int, default=50)
    ap.add_argument("--routes", type=int, default=4)
    ap.add_argument("--route-km", type=float, default=0.15)
    ap.add_argument("--rate-scale", type=float, default=0.05)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--dp", action="store_true",
                    help="[flexai] data-parallel trainer (one synchronized "
                         "agent over a route batch)")
    ap.add_argument("--dp-lanes", type=int, default=4)
    ap.add_argument("--td-kernel", action="store_true",
                    help="use the fused Pallas TD-update kernel "
                         "(kernels/dqn_update) inside the training scan; "
                         "interpret mode on CPU hosts, compiled on "
                         "TPU/GPU under REPRO_KERNEL_COMPILED=1")
    ap.add_argument("--shard", action="store_true",
                    help="[flexai] shard lanes over all visible devices")
    ap.add_argument("--weights", default=None,
                    help="[flexai] npz checkpoint to resume from / save to")
    ap.add_argument("--snapshot-dir", default=None,
                    help="[flexai] directory for full-state trainer "
                         "snapshots (TrainState + episode + best)")
    ap.add_argument("--snapshot-every", type=int, default=1,
                    help="[flexai] snapshot cadence in episodes (0=off)")
    ap.add_argument("--resume", action="store_true",
                    help="[flexai] resume bit-exactly from the latest "
                         "snapshot in --snapshot-dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.flexai:
        if args.shard and not args.dp:
            ap.error("--shard requires --dp: sharding splits the DP "
                     "route batch (use --dp-lanes for its width)")
        if args.weights and not args.weights.endswith(".npz"):
            # np.savez appends .npz on write; normalize up front so the
            # resume check and the loss-sidecar path see the real file
            args.weights += ".npz"
        return run_flexai_training(args)
    if args.arch is None:
        ap.error("--arch is required (unless --flexai)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = model_api(cfg)
    hyper = TrainHyper(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps, compression=args.compression)
    data = DataConfig(batch_size=args.batch_size, seq_len=args.seq_len)
    bat = batch_fn(cfg, data)
    step = jax.jit(make_train_step(api, hyper))

    params = unbox(api.init(jax.random.PRNGKey(0)))
    state = init_train_state(params, hyper)
    n_params = sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps} compression={hyper.compression}")

    restored, start = elastic_restore(args.ckpt_dir, jax.device_get(state))
    if restored is not None:
        state = restored
        print(f"restored checkpoint at step {start}")

    guard = PreemptionGuard()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % args.log_every == 0:
            print(f"step {s}: loss={losses[-1]:.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f}",
                  flush=True)

    res = run_with_fault_tolerance(
        step, state, bat, num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, start_step=start, guard=guard,
        on_metrics=on_metrics)
    print(f"done: steps={res.completed_steps} interrupted={res.interrupted} "
          f"final_loss={losses[-1] if losses else float('nan'):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
