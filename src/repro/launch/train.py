"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt

Runs the fault-tolerant training driver (checkpoint every N steps, SIGTERM
preemption handling, deterministic restart).  On a real pod the same entry
point runs per host with jax.distributed initialization; on this container
it exercises the identical code path on the local device.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import model_api
from repro.sharding import unbox
from repro.train.data import DataConfig, batch_fn
from repro.train.fault_tolerance import (PreemptionGuard, elastic_restore,
                                         run_with_fault_tolerance)
from repro.train.loop import TrainHyper, init_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = model_api(cfg)
    hyper = TrainHyper(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps, compression=args.compression)
    data = DataConfig(batch_size=args.batch_size, seq_len=args.seq_len)
    bat = batch_fn(cfg, data)
    step = jax.jit(make_train_step(api, hyper))

    params = unbox(api.init(jax.random.PRNGKey(0)))
    state = init_train_state(params, hyper)
    n_params = sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"steps={args.steps} compression={hyper.compression}")

    restored, start = elastic_restore(args.ckpt_dir, jax.device_get(state))
    if restored is not None:
        state = restored
        print(f"restored checkpoint at step {start}")

    guard = PreemptionGuard()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % args.log_every == 0:
            print(f"step {s}: loss={losses[-1]:.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f}",
                  flush=True)

    res = run_with_fault_tolerance(
        step, state, bat, num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, start_step=start, guard=guard,
        on_metrics=on_metrics)
    print(f"done: steps={res.completed_steps} interrupted={res.interrupted} "
          f"final_loss={losses[-1] if losses else float('nan'):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
