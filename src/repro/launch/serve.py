"""Serving launcher: batched wave serving of a smoke-config model, or —
with ``--placement`` — FlexAI multi-vehicle placement serving on the
(optionally sharded) device-resident scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
        --requests 8 --max-new 16

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --placement --shard \
        --routes 8 --route-km 0.03

Deadline-aware QoS serving (``repro.serve.qos``): ``--qos edf`` admits
waves earliest-effective-deadline-first with aging credit, preemption and
shedding; ``--deadline-scale`` tightens/relaxes the Table-5 budgets:

    PYTHONPATH=src python -m repro.launch.serve --placement --qos edf \
        --routes 8 --route-km 0.01 --arrival-gap 0.02

Production-serving extras (ISSUE 10): ``--continuous`` refills freed
wave lanes at segment boundaries instead of draining, ``--measured-svc``
replaces the virtual service clock with a measured per-bucket EMA, and
``--shard`` now also shards plain (non-durable) QoS waves over the
``("routes",)`` mesh — bit-exact against the single-device path.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import model_api
from repro.serve.engine import FlexAIPlacementService, Request, ServeEngine
from repro.sharding import unbox


def run_token_serving(args) -> int:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_decoder:
        print("serve launcher currently targets decoder-only archs")
        return 1
    api = model_api(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    eng = ServeEngine(api, params, slots=args.slots, max_seq=args.max_seq,
                      temperature=args.temperature,
                      qos=args.qos or "fifo",
                      deadline_scale=args.deadline_scale
                      if args.deadline_scale is not None else 1.0)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in eng.finished)
    qs = eng.qos_stats()
    print(f"served {len(eng.finished)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print(f"qos[{qs['policy']}]: miss_rate {qs['miss_rate']:.3f} "
          f"shed {qs['shed']} p50_slack {qs['p50_slack']:.1f} "
          f"p99_slack {qs['p99_slack']:.1f} (steps)")
    for r in eng.finished[:3]:
        print(f"  req {r.uid}: {r.generated[:8]}...")
    return 0


def _durable_mode(args) -> bool:
    """Any durability-shaped flag routes the QoS engine through
    ``DurableQoSEngine`` (snapshots / resume / fault injection / mesh)."""
    return bool(args.snapshot_dir or args.resume or args.state_out
                or args.serve_waves or args.inject_core is not None)


def run_qos_placement_serving(args) -> int:
    """Deadline-aware placement serving: routes arrive over a virtual
    timeline and are admitted EDF (or bucket-FIFO) with Table-5-derived
    deadlines, aging, preemption and shedding (see ``repro.serve.qos``).

    Durability flags (``repro.serve.durability``): ``--snapshot-dir`` /
    ``--snapshot-every`` write crash-recovery snapshots on a segment
    cadence, ``--resume`` restores the latest one (optionally onto a
    different mesh with ``--shard``), ``--serve-waves K`` stops after K
    admission rounds (the crash-point control of the recovery tests),
    ``--inject-core/--inject-at/--inject-factor`` degrade an accelerator
    mid-run (``--no-degrade`` disables the graceful-degradation
    response), and ``--state-out`` writes the bit-exactness digest npz.
    """
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.hmai import HMAIPlatform
    from repro.serve.qos import QoSConfig, QoSPlacementEngine

    durable = _durable_mode(args)
    if durable and (args.continuous or args.measured_svc):
        print("--continuous/--measured-svc are incompatible with "
              "durability flags (the snapshot format packs whole-wave "
              "checkpoints and crash replay needs the deterministic "
              "virtual clock)")
        return 1
    if args.stages > 1 and durable:
        print("--stages > 1 is incompatible with durability flags "
              "(pipeline waves checkpoint (state, ring); the snapshot "
              "format and fault-masked executors are single-stage)")
        return 1
    plat = HMAIPlatform(capacity_scale=args.rate_scale)
    if args.inject_core is not None and not (0 <= args.inject_core < plat.n):
        print(f"--inject-core {args.inject_core} out of range: the "
              f"platform has {plat.n} accelerators (valid: 0..{plat.n - 1})")
        return 1
    if args.stages > 1:
        # stage-level placement needs stage-shaped Q params
        from repro.core.pipeline import PipelineFlexAI
        pipe = PipelineFlexAI(plat, FlexAIConfig(seed=args.seed),
                              n_stages=args.stages)
        if args.weights:
            pipe.load_weights(args.weights)
        params, backlog_scale = pipe.eval_params(), pipe.cfg.backlog_scale
    else:
        agent = FlexAIAgent(plat, FlexAIConfig(seed=args.seed))
        if args.weights:
            agent.load_weights(args.weights)
        params, backlog_scale = agent.learner.eval_p, agent.cfg.backlog_scale
    cfg = QoSConfig(policy=args.qos or "fifo",
                    deadline_scale=args.deadline_scale
                    if args.deadline_scale is not None else 1.0,
                    slots=args.slots, min_bucket=args.min_bucket,
                    stages=args.stages, continuous=args.continuous,
                    measured_svc=args.measured_svc)

    if durable:
        from repro.serve.durability import (DurableQoSEngine,
                                            FaultInjection, serving_digest)
        from repro.train.fault_tolerance import PreemptionGuard
        mesh = None
        if args.shard:
            from repro.compat import make_mesh
            n_dev = len(jax.devices())
            mesh = make_mesh((n_dev,), ("routes",))
            print(f"durable QoS mesh: {n_dev} device(s) on axis 'routes'")
        guard = PreemptionGuard()
        if args.resume:
            eng = DurableQoSEngine.restore(
                args.snapshot_dir, plat,
                backlog_scale=backlog_scale, mesh=mesh,
                guard=guard, snapshot_every=args.snapshot_every or None,
                trace=args.trace, segment_sleep=args.segment_sleep)
            print(f"resumed snapshot: now={eng.now:.4f} "
                  f"completed={len(eng.completed)} "
                  f"waves={len(eng.wave_log)}", flush=True)
        else:
            faults = []
            if args.inject_core is not None:
                faults.append(FaultInjection(
                    at_time=args.inject_at, core=args.inject_core,
                    factor=args.inject_factor,
                    handled=not args.no_degrade))
            eng = DurableQoSEngine(
                plat, params, cfg,
                backlog_scale=backlog_scale,
                snapshot_dir=args.snapshot_dir,
                snapshot_every=args.snapshot_every, faults=faults,
                mesh=mesh, guard=guard, trace=args.trace,
                segment_sleep=args.segment_sleep)
    else:
        mesh = None
        if args.shard:
            if args.stages > 1:
                print("--shard is single-stage (pipeline waves have "
                      "their own 2-D mesh path)")
                return 1
            from repro.compat import make_mesh
            n_dev = len(jax.devices())
            mesh = make_mesh((n_dev,), ("routes",))
            print(f"QoS wave mesh: {n_dev} device(s) on axis 'routes'")
        eng = QoSPlacementEngine(plat, params, cfg,
                                 backlog_scale=backlog_scale, mesh=mesh)

    if not args.resume:
        gap = args.arrival_gap if args.arrival_gap is not None else 0.05
        t = 0.0
        for i in range(args.routes):
            queue = build_task_queue(EnvironmentParams(
                route_km=args.route_km, rate_scale=args.rate_scale,
                seed=args.seed + i))
            eng.submit(queue, arrival=t)
            t += gap
    t0 = time.perf_counter()
    if durable and args.serve_waves:
        n = eng.serve_waves(args.serve_waves)
        eng.snapshot()  # boundary snapshot so a --resume continues here
        if eng.saver is not None:
            eng.saver.wait()
        print(f"partial run: served {n} waves, snapshotted", flush=True)
    else:
        eng.run_until_done()
        if durable and eng.saver is not None:
            eng.snapshot()
            eng.saver.wait()
    dt = time.perf_counter() - t0
    s = eng.stats()
    print(f"qos[{s['policy']}] served {s['completed']}/{s['submitted']} "
          f"routes in {dt:.2f}s wall ({s['virtual_time_s']:.3f}s virtual): "
          f"miss_rate {s['miss_rate']:.3f} shed {s['shed']} "
          f"preemptions {s['preemptions']} refills {s['refills']} "
          f"p50_slack {s['p50_slack_s']:.4f}s "
          f"p99_slack {s['p99_slack_s']:.4f}s "
          f"mean_stm {s['mean_stm_rate']:.3f}")
    if durable:
        print(f"durability: snapshots {s['snapshots_written']} "
              f"segments {s['segments_done']} faults {s['faults_fired']} "
              f"masked {s['cores_masked']} "
              f"interrupted {s['interrupted']}")
        if args.state_out:
            np.savez(args.state_out, **serving_digest(eng))
            print(f"state digest -> {args.state_out}")
    return 0


def run_placement_serving(args) -> int:
    """Each request is one vehicle's route; placements come from the
    device-resident scan engine, sharded over all visible devices with
    ``--shard`` (run under ``--xla_force_host_platform_device_count=N``
    on CPU)."""
    from repro.compat import make_mesh
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.core.hmai import HMAIPlatform

    plat = HMAIPlatform(capacity_scale=args.rate_scale)
    agent = FlexAIAgent(plat, FlexAIConfig(seed=args.seed))
    if args.weights:
        agent.load_weights(args.weights)

    mesh = None
    if args.shard:
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("routes",))
        print(f"placement mesh: {n_dev} device(s) on axis 'routes'")
    svc = FlexAIPlacementService(plat, agent.learner.eval_p,
                                 min_bucket=args.min_bucket, mesh=mesh)

    queues = [build_task_queue(EnvironmentParams(
        route_km=args.route_km, rate_scale=args.rate_scale,
        seed=args.seed + i)) for i in range(args.routes)]
    n_tasks = sum(len(q) for q in queues)
    t0 = time.perf_counter()
    results = svc.place(queues)
    dt = time.perf_counter() - t0
    stm = float(np.mean([r["stm_rate"] for r in results]))
    print(f"placed {len(queues)} routes / {n_tasks} tasks in {dt:.2f}s "
          f"({n_tasks/dt:.0f} tasks/s, {svc.dispatches} dispatches, "
          f"mean stm_rate {stm:.3f})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    # deadline-aware QoS (both serving modes); any of these explicitly set
    # routes --placement through the QoS wave engine (None = unset)
    ap.add_argument("--qos", choices=["fifo", "edf"], default=None,
                    help="wave admission policy (edf = deadline-aware; "
                         "default fifo)")
    ap.add_argument("--deadline-scale", type=float, default=None,
                    help="scales every derived deadline budget "
                         "(default 1.0)")
    ap.add_argument("--arrival-gap", type=float, default=None,
                    help="virtual seconds between route arrivals "
                         "(placement QoS mode; default 0.05)")
    # FlexAI placement serving
    ap.add_argument("--placement", action="store_true",
                    help="serve FlexAI route placements instead of tokens")
    ap.add_argument("--shard", action="store_true",
                    help="shard the placement engine over all devices")
    ap.add_argument("--routes", type=int, default=8)
    ap.add_argument("--route-km", type=float, default=0.03)
    ap.add_argument("--rate-scale", type=float, default=0.05)
    ap.add_argument("--min-bucket", type=int, default=64)
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages per wave (>1 serves stage-level "
                         "placements via core.pipeline; QoS mode only, "
                         "incompatible with durability flags)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: refill freed wave lanes at "
                         "segment boundaries instead of draining (QoS "
                         "mode only, incompatible with durability flags)")
    ap.add_argument("--measured-svc", action="store_true",
                    help="advance the serving clock by measured segment "
                         "wall time (per-bucket EMA) instead of the "
                         "deterministic virtual constant")
    ap.add_argument("--weights", type=str, default=None,
                    help="npz of trained EvalNet weights")
    ap.add_argument("--seed", type=int, default=0)
    # durability / crash recovery (repro.serve.durability)
    ap.add_argument("--snapshot-dir", type=str, default=None,
                    help="write crash-recovery snapshots here")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot cadence in service segments (0 = only "
                         "explicit boundary snapshots)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest snapshot in --snapshot-dir "
                         "instead of submitting fresh routes")
    ap.add_argument("--serve-waves", type=int, default=0,
                    help="stop after N admission rounds and snapshot "
                         "(crash-point control; 0 = run to completion)")
    ap.add_argument("--state-out", type=str, default=None,
                    help="write the serving-outcome digest npz here "
                         "(the recovery bit-exactness contract)")
    ap.add_argument("--inject-core", type=int, default=None,
                    help="fault injection: degrade this accelerator")
    ap.add_argument("--inject-at", type=float, default=0.0,
                    help="virtual-clock time the fault fires")
    ap.add_argument("--inject-factor", type=float, default=50.0,
                    help="exec-time degradation factor (large = dead)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="disable the graceful-degradation response "
                         "(the no-mitigation baseline)")
    ap.add_argument("--segment-sleep", type=float, default=0.0,
                    help="wall sleep per segment (widens the kill window "
                         "for the crash-recovery subprocess test)")
    ap.add_argument("--trace", action="store_true",
                    help="print per-segment/snapshot/fault progress lines")
    args = ap.parse_args(argv)

    if args.placement:
        # any QoS- or durability-shaped flag (even an explicit default
        # value) routes to the deadline-aware wave engine; the plain
        # batch service has no timeline for them to act on
        if (args.qos is not None or args.arrival_gap is not None
                or args.deadline_scale is not None or args.stages > 1
                or args.continuous or args.measured_svc
                or _durable_mode(args)):
            return run_qos_placement_serving(args)
        return run_placement_serving(args)
    if args.arch is None:
        ap.error("--arch is required unless --placement is given")
    return run_token_serving(args)


if __name__ == "__main__":
    sys.exit(main())
