"""Serving launcher: batched wave serving of a smoke-config model.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import model_api
from repro.serve.engine import Request, ServeEngine
from repro.sharding import unbox


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_decoder:
        print("serve launcher currently targets decoder-only archs")
        return 1
    api = model_api(cfg)
    params = unbox(api.init(jax.random.PRNGKey(0)))
    eng = ServeEngine(api, params, slots=args.slots, max_seq=args.max_seq,
                      temperature=args.temperature)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in eng.finished)
    print(f"served {len(eng.finished)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    for r in eng.finished[:3]:
        print(f"  req {r.uid}: {r.generated[:8]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
