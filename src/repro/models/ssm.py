"""Mamba-2 (SSD — state-space duality) blocks.

Chunked matmul formulation for train/prefill (scan over chunks carries the
inter-chunk state), O(1)-state single-token decode for serving.  Heads shard
over the "model" mesh axis; B/C projections (ngroups=1) are replicated.

State per layer: conv ring buffer [B, W-1, d_conv] + SSD state [B, H, P, N].
This is why the ``long_500k`` cell is runnable for SSM/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import with_logical_constraint as wlc


class SSMState(NamedTuple):
    conv: jax.Array  # [B, W-1, d_inner + 2*N]
    ssd: jax.Array   # [B, H, P, N] fp32


def init_mamba(key, cfg: ModelConfig, param_dtype) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads
    w = cfg.ssm_conv_width
    keys = jax.random.split(key, 8)
    # dt bias init: softplus^{-1}(dt) for dt ~ U[1e-3, 1e-1] — use mid value
    dt_init = jnp.log(jnp.expm1(jnp.full((h,), 0.01, dtype=jnp.float32)))
    return {
        "wz": L.dense_init(keys[0], (d, di), ("embed", "mlp"), param_dtype, fan_in=d),
        "wx": L.dense_init(keys[1], (d, di), ("embed", "mlp"), param_dtype, fan_in=d),
        "wB": L.dense_init(keys[2], (d, n), ("embed", "ssm_state"), param_dtype, fan_in=d),
        "wC": L.dense_init(keys[3], (d, n), ("embed", "ssm_state"), param_dtype, fan_in=d),
        "wdt": L.dense_init(keys[4], (d, h), ("embed", "ssm_heads"), param_dtype, fan_in=d),
        "conv_w": L.dense_init(keys[5], (w, di + 2 * n), ("conv_kernel", "mlp"),
                               param_dtype, fan_in=w, scale=1.0),
        "conv_b": L.zeros_init((di + 2 * n,), ("mlp",), param_dtype),
        "A_log": L.const_init(jnp.log(jnp.linspace(1.0, 16.0, h)).astype(param_dtype),
                              ("ssm_heads",)),
        "dt_bias": L.const_init(dt_init.astype(param_dtype), ("ssm_heads",)),
        "D": L.ones_init((h,), ("ssm_heads",), param_dtype),
        "norm": L.ones_init((di,), ("mlp",), param_dtype),
        "wo": L.dense_init(keys[6], (di, d), ("mlp", "embed"), param_dtype, fan_in=di),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv via shifted adds. xbc [B, S, C]; w [W, C]."""
    width = w.shape[0]
    if history is None:
        padded = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        padded = jnp.concatenate([history.astype(xbc.dtype), xbc], axis=1)
    s = xbc.shape[1]
    out = jnp.zeros_like(xbc) + b.astype(xbc.dtype)
    for i in range(width):
        out = out + padded[:, i : i + s, :] * w[i].astype(xbc.dtype)
    return jax.nn.silu(out)


def _segsum_exp(a_cum: jax.Array) -> jax.Array:
    """L[..., i, j] = exp(a_cum[..., i] - a_cum[..., j]) masked to i >= j.

    a_cum: [..., Q]. Returns [..., Q, Q].
    """
    q = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    # iota-based mask (never a materialized q*q constant at compile time)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    return jnp.where(rows >= cols, jnp.exp(diff), 0.0)


def ssd_chunked(u: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, init_state: jax.Array | None = None):
    """Chunked SSD scan.

    u  [B, S, H, P]   discretized inputs (x * dt)
    a  [B, S, H]      log-decay per step (dt * A, negative)
    Bm [B, S, N], Cm [B, S, N]  input/output projections (shared over heads)

    Returns y [B, S, H, P] and final state [B, H, P, N].
    """
    b, s, h, p = u.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    uf = u.astype(jnp.float32).reshape(b, nc, q, h, p)
    af = a.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, q, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, q, n)

    a_cum = jnp.cumsum(af, axis=2)  # [b, nc, q, h]

    # ---- intra-chunk (diagonal blocks) ----
    scores = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)          # [b,nc,q,q]
    Lmask = _segsum_exp(a_cum.transpose(0, 1, 3, 2))         # [b,nc,h,q,q]
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, Lmask, uf)

    # ---- chunk summary states ----
    decay_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)         # [b,nc,q,h]
    S_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bf, decay_end, uf)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                # [b,nc,h]

    # ---- inter-chunk recurrence (scan over chunks) ----
    def step(S_prev, inp):
        S_c, dec = inp  # [b,h,p,n], [b,h]
        S_new = S_c + dec[:, :, None, None] * S_prev
        return S_new, S_prev

    S0 = (jnp.zeros((b, h, p, n), dtype=jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    S_final, S_prevs = jax.lax.scan(
        step, S0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)  # [b,nc,h,p,n]

    # ---- off-diagonal contribution ----
    in_decay = jnp.exp(a_cum)  # [b,nc,q,h]
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cf, in_decay, S_prevs)

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(u.dtype), S_final


def mamba_apply(p: dict, cfg: ModelConfig, x: jax.Array,
                return_state: bool = False):
    """x [B,S,E] -> [B,S,E] (+ final SSMState for prefill->decode handoff)."""
    dt_ = x.dtype
    b, s, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads, cfg.ssm_head_dim

    z = jnp.einsum("bse,ei->bsi", x, p["wz"].astype(dt_))
    xs = jnp.einsum("bse,ei->bsi", x, p["wx"].astype(dt_))
    Bm = jnp.einsum("bse,en->bsn", x, p["wB"].astype(dt_))
    Cm = jnp.einsum("bse,en->bsn", x, p["wC"].astype(dt_))
    dt_raw = jnp.einsum("bse,eh->bsh", x, p["wdt"].astype(dt_))

    xbc_pre = jnp.concatenate([xs, Bm, Cm], axis=-1)  # conv INPUT (cached)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    xs = wlc(xs, ("batch", None, "mlp"))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]
    a = dt * A  # log-decay
    u = xs.reshape(b, s, h, pdim) * dt[..., None].astype(dt_)

    y, S_final = ssd_chunked(u, a, Bm, Cm, cfg.ssm_chunk)
    y = y + xs.reshape(b, s, h, pdim) * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = wlc(y, ("batch", None, "mlp"))
    out = jnp.einsum("bsi,ie->bse", y, p["wo"].astype(dt_))
    out = wlc(out, ("batch", None, None))
    if return_state:
        width = cfg.ssm_conv_width
        if s >= width - 1:
            conv_hist = xbc_pre[:, s - (width - 1):, :]
        else:
            conv_hist = jnp.pad(xbc_pre, ((0, 0), (width - 1 - s, 0), (0, 0)))
        return out, SSMState(conv=conv_hist, ssd=S_final)
    return out


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: SSMState):
    """Single-token decode. x [B,1,E]; returns (y [B,1,E], new state)."""
    dt_ = x.dtype
    b = x.shape[0]
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads, cfg.ssm_head_dim
    width = cfg.ssm_conv_width

    z = jnp.einsum("bse,ei->bsi", x, p["wz"].astype(dt_))
    xs = jnp.einsum("bse,ei->bsi", x, p["wx"].astype(dt_))
    Bm = jnp.einsum("bse,en->bsn", x, p["wB"].astype(dt_))
    Cm = jnp.einsum("bse,en->bsn", x, p["wC"].astype(dt_))
    dt_raw = jnp.einsum("bse,eh->bsh", x, p["wdt"].astype(dt_))

    xbc_new = jnp.concatenate([xs, Bm, Cm], axis=-1)  # [B,1,C]
    window = jnp.concatenate([state.conv.astype(dt_), xbc_new], axis=1)  # [B,W,C]
    conv_out = jnp.einsum("bwc,wc->bc", window,
                          p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    conv_out = jax.nn.silu(conv_out)[:, None, :]  # [B,1,C]
    new_conv = window[:, 1:, :]

    xs, Bm, Cm = (conv_out[..., :di], conv_out[..., di : di + n],
                  conv_out[..., di + n :])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)  # [B,H]
    u = (xs.reshape(b, h, pdim) * dt[..., None].astype(dt_)).astype(jnp.float32)

    S_new = (decay[:, :, None, None] * state.ssd.astype(jnp.float32)
             + jnp.einsum("bhp,bn->bhpn", u, Bm[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), S_new)
    y = y.astype(dt_) + xs.reshape(b, h, pdim) * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(b, 1, di)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsi,ie->bse", y, p["wo"].astype(dt_))
    return out, SSMState(conv=new_conv, ssd=S_new)
