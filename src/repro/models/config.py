"""Model configuration shared by every architecture in the zoo.

One dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM / audio
families; family-specific fields default to "off".  Architecture configs in
``repro.configs`` are instances of this class.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention ---
    attention_kind: str = "gqa"  # gqa | mha | mla
    sliding_window: Optional[int] = None  # SWA window (tokens), None = full
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    # --- MLA (multi-head latent attention) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_layer_period: int = 1  # every k-th layer is MoE (1 = all)
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001
    moe_impl: str = "gspmd"  # gspmd (scatter) | shard_map (explicit a2a EP)

    # --- SSM / hybrid ---
    # layer pattern: string over {"A" (attention), "M" (mamba)}, one char per
    # layer within a repeating period; replicated to num_layers.
    layer_pattern: Optional[str] = None
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    hybrid_attn_window: Optional[int] = None  # window for attn layers in hybrids

    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_ratio: int = 4  # src_len = tgt_len // ratio for shape cells

    # --- modality frontends (stubs: precomputed embeddings as inputs) ---
    frontend: Optional[str] = None  # "vision_stub" | "audio_stub"
    num_frontend_tokens: int = 0  # patches / frames consumed per example

    # --- numerics ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    logits_dtype: str = "float32"

    # --- runtime / performance knobs (hillclimbed in §Perf) ---
    attention_impl: str = "chunked"  # chunked | naive
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    remat: str = "full"  # full | none
    scan_layers: bool = True
    use_grad_accum_microbatches: int = 1  # >1 -> grad-accumulation scan
    decode_seq_shards: bool = True  # flash-decoding style KV-seq sharding

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.attention_kind == "mla" and self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    # ------------------------------------------------------------------
    @property
    def pattern(self) -> str:
        """Per-layer block types, length == num_layers."""
        if self.layer_pattern is None:
            base = "M" if self.family == "ssm" else "A"
            return base * self.num_layers
        reps = -(-self.num_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.num_layers]

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        if self.moe_layer_period <= 1:
            return True
        # Jamba/DeepSeek convention: every `period`-th layer starting at 1
        return (i % self.moe_layer_period) == (self.moe_layer_period - 1)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb + d  # final norm
        for i, kind in enumerate(self.pattern):
            total += 2 * d  # pre-norms
            if kind == "A":
                total += self._attn_params()
            else:
                total += self._ssm_params()
            if kind == "A" or self.family != "ssm":
                total += self._ffn_params(i)
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                total += 2 * d + self._attn_params() + self._ffn_params(0)
            # decoder cross-attention
            total += self.num_layers * (self._attn_params() + d)
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention_kind == "mla":
            hd = self.qk_nope_dim + self.qk_rope_dim
            q = (
                d * self.q_lora_rank + self.q_lora_rank * self.num_heads * hd
                if self.q_lora_rank
                else d * self.num_heads * hd
            )
            kv = d * (self.kv_lora_rank + self.qk_rope_dim)
            kv += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
            o = self.num_heads * self.v_head_dim * d
            return q + kv + o
        h, k, hd = self.num_heads, self.num_kv_heads, self.head_dim
        return d * h * hd + 2 * d * k * hd + h * hd * d

    def _ffn_params(self, layer_idx: int) -> int:
        d, f = self.d_model, self.d_ff
        dense = 3 * d * f  # SwiGLU
        if self.is_moe_layer(layer_idx):
            e = self.num_experts + self.num_shared_experts
            return e * dense + d * self.num_experts  # + router
        return dense

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        n, hds = self.ssm_state_dim, self.ssm_heads
        in_proj = d * (2 * di + 2 * n + hds)  # z, x, B, C, dt
        conv = self.ssm_conv_width * (di + 2 * n)
        out = di * d
        extras = hds * 2 + di  # A_log, dt_bias, (D)
        return in_proj + conv + out + extras

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if self.num_experts == 0:
            return self.param_count()
        total = self.param_count()
        moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        dense = 3 * self.d_model * self.d_ff
        inactive = moe_layers * (
            self.num_experts - self.num_experts_per_token
        ) * dense
        return total - inactive
