"""Family-dispatched model API: one namespace for train/serve/dry-run.

Usage::

    api = model_api(cfg)
    params = api.init(key)                     # boxed Param tree
    loss, metrics = api.loss(unbox(params), batch)
    logits, cache = api.decode_step(params, cache, token, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    loss: Callable          # (params, batch) -> (loss, metrics)
    prefill: Callable       # (params, batch) -> (last_logits, cache)
    decode_step: Callable   # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable    # (batch_size, seq_len, ...) -> boxed cache


def model_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.is_encoder_decoder:
        return ModelAPI(
            cfg=cfg,
            init=lambda key: ED.init_encdec(key, cfg),
            loss=lambda p, b: ED.encdec_loss(p, cfg, b),
            prefill=lambda p, b: ED.encdec_prefill(p, cfg, b),
            decode_step=lambda p, c, t, pos: ED.encdec_decode_step(
                p, cfg, c, t, pos),
            init_cache=lambda bs, s, src_len=None: ED.init_encdec_cache(
                cfg, bs, s, src_len or max(1, s // cfg.encoder_seq_ratio)),
        )
    return ModelAPI(
        cfg=cfg,
        init=lambda key: T.init_lm(key, cfg),
        loss=lambda p, b: T.lm_loss(p, cfg, b),
        prefill=lambda p, b: T.lm_prefill(p, cfg, b),
        decode_step=lambda p, c, t, pos: T.lm_decode_step(p, cfg, c, t, pos),
        init_cache=lambda bs, s, **_: T.init_cache(cfg, bs, s),
    )
