"""Decoder-only LM substrate: composable blocks, scan-over-layers, caches.

Layer heterogeneity (hybrid attn/Mamba patterns, periodic MoE) is handled by
grouping layers into *super-blocks*: the model is a ``lax.scan`` over
``num_layers / period`` steps whose body applies the ``period`` distinct
sub-layers.  HLO size is proportional to one super-block regardless of depth,
which keeps 88-layer dry-run compiles tractable.

Params are boxed (:class:`repro.sharding.Param`) with logical axes; stacked
sub-layer params gain a leading "layers" axis.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.sharding import Param, is_param, with_logical_constraint as wlc


# ---------------------------------------------------------------------------
# Super-block structure
# ---------------------------------------------------------------------------

class BlockSpec(NamedTuple):
    kind: str      # "A" | "M"
    is_moe: bool
    has_ffn: bool


def superblock_period(cfg: ModelConfig) -> int:
    pat = 1 if cfg.layer_pattern is None else len(cfg.layer_pattern)
    moe = cfg.moe_layer_period if cfg.num_experts else 1
    period = _lcm(pat, moe)
    if cfg.num_layers % period:
        return cfg.num_layers  # no clean repeat: one unrolled super-block
    return period


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


def block_specs(cfg: ModelConfig) -> list[BlockSpec]:
    """Specs for the sub-layers of one super-block (length == period)."""
    period = superblock_period(cfg)
    pattern = cfg.pattern
    return [
        BlockSpec(
            kind=pattern[i],
            is_moe=cfg.is_moe_layer(i),
            has_ffn=cfg.d_ff > 0,
        )
        for i in range(period)
    ]


def stack_init(init_fn, key, n: int):
    """vmap an init over n keys and prepend the "layers" logical axis."""
    keys = jax.random.split(key, n)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, ("layers",) + p.axes),
        stacked, is_leaf=is_param)


def _slice_layer(tree, i):
    """Take layer i of a "layers"-stacked (unboxed) tree."""
    return jax.tree_util.tree_map(lambda x: x[i], tree)


# ---------------------------------------------------------------------------
# Sub-layer init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, spec: BlockSpec) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p: dict = {"norm1": L.init_rmsnorm(cfg.d_model, pdt)}
    if spec.kind == "A":
        p["attn"] = A.init_attention(k1, cfg, pdt)
    else:
        p["mamba"] = S.init_mamba(k1, cfg, pdt)
    if spec.has_ffn:
        p["norm2"] = L.init_rmsnorm(cfg.d_model, pdt)
        if spec.is_moe:
            p["moe"] = M.init_moe(k2, cfg, pdt)
        else:
            p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, pdt)
    return p


def _attn_window(cfg: ModelConfig) -> Optional[int]:
    if cfg.family == "hybrid":
        return cfg.hybrid_attn_window
    return cfg.sliding_window


def block_apply(p: dict, cfg: ModelConfig, spec: BlockSpec, x, positions,
                causal: bool = True):
    """One sub-layer (mixer + optional FFN). Returns (x, aux_loss)."""
    aux = jnp.zeros((), dtype=jnp.float32)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "A":
        if cfg.attention_kind == "mla":
            mix = A.mla_apply(p["attn"], cfg, h, positions, causal=causal)
        else:
            mix = A.gqa_apply(p["attn"], cfg, h, positions, causal=causal,
                              window=_attn_window(cfg))
    else:
        mix = S.mamba_apply(p["mamba"], cfg, h)
    x = x + mix
    if spec.has_ffn:
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if spec.is_moe:
            ffn, aux = M.moe_apply(p["moe"], cfg, h2)
        else:
            ffn = L.mlp_apply(p["mlp"], h2)
        x = x + ffn
    x = _residual_constraint(x)
    return x, aux


def _residual_constraint(x):
    # sequence-parallel residual stream: saved scan carries shard over "model"
    return wlc(x, ("batch", "seq", None))


def block_apply_cached(p: dict, cfg: ModelConfig, spec: BlockSpec, x, cache,
                       pos):
    """Decode step for one sub-layer against its cache entry."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "A":
        if cfg.attention_kind == "mla":
            mix, new_cache = A.mla_decode(p["attn"], cfg, h, cache, pos)
        else:
            mix, new_cache = A.gqa_decode(p["attn"], cfg, h, cache, pos,
                                          window=_attn_window(cfg))
    else:
        mix, new_cache = S.mamba_decode(p["mamba"], cfg, h, cache)
    x = x + mix
    if spec.has_ffn:
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if spec.is_moe:
            ffn, _ = M.moe_apply(p["moe"], cfg, h2)
        else:
            ffn = L.mlp_apply(p["mlp"], h2)
        x = x + ffn
    return x, new_cache


def block_apply_prefill(p: dict, cfg: ModelConfig, spec: BlockSpec, x,
                        positions):
    """Forward + cache construction (prefill). Returns (x, cache_entry)."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "A":
        if cfg.attention_kind == "mla":
            mix, entry = A.mla_apply(p["attn"], cfg, h, positions,
                                     causal=True, return_cache=True)
        else:
            mix, entry = A.gqa_apply(p["attn"], cfg, h, positions, causal=True,
                                     window=_attn_window(cfg),
                                     return_cache=True)
    else:
        mix, entry = S.mamba_apply(p["mamba"], cfg, h, return_state=True)
    x = x + mix
    if spec.has_ffn:
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if spec.is_moe:
            ffn, _ = M.moe_apply(p["moe"], cfg, h2)
        else:
            ffn = L.mlp_apply(p["mlp"], h2)
        x = x + ffn
    x = _residual_constraint(x)
    return x, entry


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    specs = block_specs(cfg)
    n_super = cfg.num_layers // len(specs)
    keys = jax.random.split(key, len(specs) + 3)
    params: dict = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, pdt),
        "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(
            keys[1], (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), pdt,
            scale=1.0 / (cfg.d_model ** 0.5))
    blocks = {}
    for i, spec in enumerate(specs):
        blocks[f"pos{i}"] = stack_init(
            lambda k, s=spec: init_block(k, cfg, s), keys[2 + i], n_super)
    params["blocks"] = blocks
    if cfg.frontend == "vision_stub" or cfg.frontend == "audio_stub":
        params["projector"] = L.init_mlp(
            keys[-1], cfg.d_model, cfg.d_model * 2, pdt)
    return params


def _scan_blocks(params, cfg: ModelConfig, x, positions, causal=True):
    """Apply all layers via scan over super-blocks. Returns (x, aux_total)."""
    specs = block_specs(cfg)
    n_super = cfg.num_layers // len(specs)

    def body(carry, layer_params):
        x, aux = carry
        for i, spec in enumerate(specs):
            x, a = block_apply(layer_params[f"pos{i}"], cfg, spec, x,
                               positions, causal=causal)
            aux = aux + a
        return (x, aux), None

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers and n_super > 1:
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for j in range(n_super):
            (x, aux), _ = body((x, aux), _slice_layer(params["blocks"], j))
    return x, aux


def lm_loss(params, cfg: ModelConfig, batch: dict):
    """batch: tokens [B,S] int32, labels [B,S] int32, loss_mask [B,S].

    VLM/audio stubs: batch additionally carries "frontend_embeds"
    [B, T_front, d_model*? ] which are projected and prepended; labels then
    cover only the token region (mask supplied by the pipeline).
    """
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"], tokens, dt)
    if cfg.frontend is not None:
        fe = batch["frontend_embeds"].astype(dt)
        fe = L.mlp_apply(params["projector"], fe)
        x = jnp.concatenate([fe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _residual_constraint(x)
    x, aux = _scan_blocks(params, cfg, x, positions)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.frontend is not None:
        x = x[:, -tokens.shape[1]:, :]
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed_logits(table, x, jnp.dtype(cfg.logits_dtype))
    loss = L.softmax_cross_entropy(
        logits, batch["labels"], batch.get("loss_mask"))
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux,
                   "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}


def lm_prefill(params, cfg: ModelConfig, batch: dict):
    """Forward pass building the KV cache. Returns (last_logits, cache)."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"], tokens, dt)
    if cfg.frontend is not None:
        fe = batch["frontend_embeds"].astype(dt)
        fe = L.mlp_apply(params["projector"], fe)
        x = jnp.concatenate([fe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    specs = block_specs(cfg)
    n_super = cfg.num_layers // len(specs)

    def body(x, layer_params):
        entries = {}
        for i, spec in enumerate(specs):
            x, entry = block_apply_prefill(
                layer_params[f"pos{i}"], cfg, spec, x, positions)
            entries[f"pos{i}"] = entry
        return x, entries

    if cfg.scan_layers and n_super > 1:
        x, cache = jax.lax.scan(body, x, params["blocks"])
    else:
        caches = []
        for j in range(n_super):
            x, entries = body(x, _slice_layer(params["blocks"], j))
            caches.append(entries)
        cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed_logits(table, x[:, -1:, :], jnp.dtype(cfg.logits_dtype))
    return logits, cache


def lm_decode_step(params, cfg: ModelConfig, cache, token, pos):
    """One decode step. token [B,1] int32; pos scalar int32.

    cache: {"pos{i}": stacked entry [n_super, ...]} as produced by
    lm_prefill / init_cache.  Returns (logits [B,1,V], new cache).
    """
    dt = jnp.dtype(cfg.dtype)
    x = L.embed_lookup(params["embed"], token, dt)
    specs = block_specs(cfg)
    n_super = cfg.num_layers // len(specs)

    def body(x, scanned):
        layer_params, cache_slice = scanned
        new_entries = {}
        for i, spec in enumerate(specs):
            x, entry = block_apply_cached(
                layer_params[f"pos{i}"], cfg, spec, x,
                cache_slice[f"pos{i}"], pos)
            new_entries[f"pos{i}"] = entry
        return x, new_entries

    if cfg.scan_layers and n_super > 1:
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    else:
        entries_list = []
        for j in range(n_super):
            x, entries = body(
                x, (_slice_layer(params["blocks"], j), _slice_layer(cache, j)))
            entries_list.append(entries)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *entries_list)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed_logits(table, x, jnp.dtype(cfg.logits_dtype))
    return logits, new_cache


# ---------------------------------------------------------------------------
# Cache construction (boxed, for dry-run specs and serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int):
    """Zero-initialized boxed cache tree for decode.

    Attention layers get [n_super, B, S_kv, K, D] KV entries (S_kv bounded
    by the sliding window for SWA archs); Mamba layers get SSM states.
    MLA caches the latent + rope-key instead.
    """
    dt = jnp.dtype(cfg.dtype)
    specs = block_specs(cfg)
    n_super = cfg.num_layers // len(specs)
    window = _attn_window(cfg)
    s_kv = seq_len if window is None else min(seq_len, window)
    cache = {}
    for i, spec in enumerate(specs):
        if spec.kind == "A":
            if cfg.attention_kind == "mla":
                entry = A.KVCacheEntry(
                    k=Param(jnp.zeros((n_super, batch_size, s_kv,
                                       cfg.kv_lora_rank), dt),
                            ("layers", "cache_batch", "kv_seq", "lora")),
                    v=Param(jnp.zeros((n_super, batch_size, s_kv,
                                       cfg.qk_rope_dim), dt),
                            ("layers", "cache_batch", "kv_seq", "lora")),
                )
            else:
                shape = (n_super, batch_size, s_kv, cfg.num_kv_heads,
                         cfg.head_dim)
                axes = ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")
                entry = A.KVCacheEntry(
                    k=Param(jnp.zeros(shape, dt), axes),
                    v=Param(jnp.zeros(shape, dt), axes),
                )
        else:
            entry = S.SSMState(
                conv=Param(
                    jnp.zeros((n_super, batch_size, cfg.ssm_conv_width - 1,
                               cfg.d_inner + 2 * cfg.ssm_state_dim), dt),
                    ("layers", "cache_batch", None, "mlp")),
                ssd=Param(
                    jnp.zeros((n_super, batch_size, cfg.ssm_heads,
                               cfg.ssm_head_dim, cfg.ssm_state_dim),
                              jnp.float32),
                    ("layers", "cache_batch", "ssm_heads", None, "ssm_state")),
            )
        cache[f"pos{i}"] = entry
    return cache
