"""Core building blocks: initializers, norms, embeddings, RoPE, MLPs.

All parameters are created as :class:`repro.sharding.Param` boxes carrying
logical axis names.  ``apply``-side functions consume *unboxed* value trees
and cast to the compute dtype at use sites.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.sharding import Param, with_logical_constraint


# ---------------------------------------------------------------------------
# Param creation
# ---------------------------------------------------------------------------

def dense_init(key, shape: Sequence[int], axes: Sequence[str], dtype,
               fan_in: int | None = None, scale: float = 1.0) -> Param:
    """Scaled-normal (LeCun-ish) init for a dense kernel."""
    if fan_in is None:
        fan_in = shape[0]
    std = scale / math.sqrt(max(fan_in, 1))
    val = (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)
    return Param(val, tuple(axes))


def embed_init(key, shape, axes, dtype, scale: float = 1.0) -> Param:
    val = (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)
    return Param(val, tuple(axes))


def zeros_init(shape, axes, dtype) -> Param:
    return Param(jnp.zeros(shape, dtype=dtype), tuple(axes))


def ones_init(shape, axes, dtype) -> Param:
    return Param(jnp.ones(shape, dtype=dtype), tuple(axes))


def const_init(value, axes) -> Param:
    return Param(value, tuple(axes))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, param_dtype) -> Param:
    return ones_init((d,), ("embed",), param_dtype)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, param_dtype) -> dict:
    return {
        "scale": ones_init((d,), ("embed",), param_dtype),
        "bias": zeros_init((d,), ("embed",), param_dtype),
    }


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, param_dtype) -> Param:
    return embed_init(key, (vocab, d), ("vocab", "embed"), param_dtype,
                      scale=1.0 / math.sqrt(d))


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    """[V, D] x [..., S] -> [..., S, D].

    One-hot matmul would shard better over "vocab", but for the assigned
    vocab sizes gather + all-reduce is what XLA picks anyway; take() keeps
    the HLO small.
    """
    out = jnp.take(table, tokens, axis=0).astype(dtype)
    return with_logical_constraint(out, ("batch", None, None))


def unembed_logits(table: jax.Array, x: jax.Array, dtype) -> jax.Array:
    """[..., S, D] x [V, D] -> [..., S, V]."""
    logits = jnp.einsum(
        "bsd,vd->bsv", x, table.astype(x.dtype), preferred_element_type=jnp.float32
    )
    logits = with_logical_constraint(logits, ("batch", None, "vocab"))
    return logits.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int). Pairs (even, odd halves)."""
    freqs = rope_frequencies(x.shape[-1], theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Feed-forward (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, param_dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d, f), ("embed", "mlp"), param_dtype, fan_in=d),
        "wi_up": dense_init(k2, (d, f), ("embed", "mlp"), param_dtype, fan_in=d),
        "wo": dense_init(k3, (f, d), ("mlp", "embed"), param_dtype, fan_in=f),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(dt))
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = with_logical_constraint(h, ("batch", None, "mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    return with_logical_constraint(out, ("batch", None, None))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None):
    """logits [B,S,V] (fp32), labels [B,S] int. Returns mean loss (masked)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(
        logits, labels[..., None], axis=-1
    )[..., 0]
    nll = logz - label_logits
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
