"""Generic CNN substrate for the paper's perception workloads.

Networks are declared as layer-spec lists so the same definition yields
(a) a runnable JAX model, (b) analytic MACs / parameter counts (Table 1),
and (c) per-layer workload descriptors consumed by the HMAI accelerator
performance model (`repro.core.hmai`).

Layer kinds:
    ("conv", c_out, k, stride)       conv + bias + leaky-relu
    ("maxpool", k, stride)
    ("residual", n_back)             add output of layer i-n_back
    ("globalpool",)                  spatial mean
    ("fc", n_out)                    dense + leaky-relu (flattens if needed)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import Param


@dataclasses.dataclass(frozen=True)
class ConvNetSpec:
    name: str
    layers: tuple  # tuple of layer-kind tuples
    in_channels: int = 3
    input_hw: int = 416  # nominal full-scale input resolution


def _leaky(x):
    return jax.nn.leaky_relu(x, 0.1)


def init_convnet(key, spec: ConvNetSpec, width_mult: float = 1.0,
                 param_dtype=jnp.float32) -> list:
    """Returns a list of per-layer param dicts (None for param-free)."""
    params = []
    c_in = spec.in_channels
    hw = spec.input_hw
    keys = jax.random.split(key, len(spec.layers))
    flat_dim = None
    for i, layer in enumerate(spec.layers):
        kind = layer[0]
        if kind == "conv":
            _, c_out, k, stride = layer
            c_out = max(4, int(c_out * width_mult))
            w = L.dense_init(keys[i], (k, k, c_in, c_out),
                             ("conv_kernel", "conv_kernel", "unsharded", "mlp"),
                             param_dtype, fan_in=k * k * c_in)
            b = L.zeros_init((c_out,), ("mlp",), param_dtype)
            params.append({"w": w, "b": b})
            c_in = c_out
            hw = -(-hw // stride)
        elif kind == "maxpool":
            _, k, stride = layer
            hw = -(-hw // stride)
            params.append(None)
        elif kind == "residual":
            params.append(None)
        elif kind == "globalpool":
            flat_dim = c_in
            hw = 1
            params.append(None)
        elif kind == "fc":
            _, n_out = layer
            n_out = max(4, int(n_out * width_mult))
            d_in = flat_dim if flat_dim is not None else c_in * hw * hw
            w = L.dense_init(keys[i], (d_in, n_out), ("embed", "mlp"),
                             param_dtype, fan_in=d_in)
            b = L.zeros_init((n_out,), ("mlp",), param_dtype)
            params.append({"w": w, "b": b})
            flat_dim = n_out
            c_in = n_out
        else:
            raise ValueError(kind)
    return params


def convnet_apply(params: list, spec: ConvNetSpec, x: jax.Array,
                  return_features: bool = False):
    """x: [B, H, W, C]. Returns final output (and per-layer features)."""
    feats = []
    flat = None
    for layer, p in zip(spec.layers, params):
        kind = layer[0]
        if kind == "conv":
            _, _, k, stride = layer
            w = p["w"].astype(x.dtype)
            x = jax.lax.conv_general_dilated(
                x, w, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = _leaky(x + p["b"].astype(x.dtype))
        elif kind == "maxpool":
            _, k, stride = layer
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1),
                "SAME")
        elif kind == "residual":
            x = x + feats[len(feats) - layer[1]]
        elif kind == "globalpool":
            x = jnp.mean(x, axis=(1, 2))
            flat = x
        elif kind == "fc":
            inp = flat if flat is not None else x.reshape(x.shape[0], -1)
            x = _leaky(inp @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype))
            flat = x
        feats.append(x)
    if return_features:
        return x, feats
    return x


def convnet_stats(spec: ConvNetSpec, width_mult: float = 1.0) -> dict:
    """Analytic MACs / params / per-layer workload (full-scale input)."""
    c_in = spec.in_channels
    hw = spec.input_hw
    macs = 0
    n_params = 0
    n_neurons = 0
    per_layer = []
    flat_dim = None
    for layer in spec.layers:
        kind = layer[0]
        if kind == "conv":
            _, c_out, k, stride = layer
            c_out = max(4, int(c_out * width_mult))
            hw_out = -(-hw // stride)
            m = hw_out * hw_out * k * k * c_in * c_out
            macs += m
            n_params += k * k * c_in * c_out + c_out
            n_neurons += hw_out * hw_out * c_out
            per_layer.append({
                "kind": "conv", "macs": m, "k": k,
                "c_in": c_in, "c_out": c_out, "hw": hw_out, "stride": stride,
            })
            c_in, hw = c_out, hw_out
        elif kind == "maxpool":
            _, k, stride = layer
            hw = -(-hw // stride)
            per_layer.append({"kind": "maxpool", "macs": 0})
        elif kind == "residual":
            per_layer.append({"kind": "residual", "macs": 0})
        elif kind == "globalpool":
            flat_dim = c_in
            hw = 1
            per_layer.append({"kind": "globalpool", "macs": 0})
        elif kind == "fc":
            _, n_out = layer
            n_out = max(4, int(n_out * width_mult))
            d_in = flat_dim if flat_dim is not None else c_in * hw * hw
            m = d_in * n_out
            macs += m
            n_params += d_in * n_out + n_out
            n_neurons += n_out
            per_layer.append({"kind": "fc", "macs": m,
                              "c_in": d_in, "c_out": n_out})
            flat_dim = n_out
            c_in = n_out
    n_layers = sum(1 for l in spec.layers if l[0] in ("conv", "fc", "residual"))
    return {
        "name": spec.name,
        "macs": macs,
        "params": n_params,
        "neurons": n_neurons,
        "weights_and_neurons": n_params + n_neurons,
        "layers": n_layers,
        "per_layer": per_layer,
    }
