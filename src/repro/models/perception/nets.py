"""The paper's three perception workloads: YOLO-class, SSD-class, GOTURN-class.

Full-scale specs are calibrated so the analytic MACs approximate Table 1
(YOLO 16 GMACs, SSD 26 GMACs, GOTURN 11 GMACs); the Table-1 benchmark prints
derived-vs-paper numbers.  Reduced configs (width_mult < 1) power CPU smoke
tests and the TPU virtual-platform serving example.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.perception.cnn import (
    ConvNetSpec, convnet_apply, convnet_stats, init_convnet)


def _darknet_stage(c: int, n_blocks: int):
    layers = [("conv", c, 3, 2)]
    for _ in range(n_blocks):
        layers += [("conv", c // 2, 1, 1), ("conv", c, 3, 1), ("residual", 3)]
    return layers


# YOLO-class detector: DarkNet-53-style backbone + detection head.
# width 0.72 -> ~16 GMACs at 416x416 (Table 1: 16G).
YOLO_WIDTH = 0.80
YOLO_SPEC = ConvNetSpec(
    name="yolo",
    in_channels=3,
    input_hw=416,
    layers=tuple(
        [("conv", 32, 3, 1)]
        + _darknet_stage(64, 1)
        + _darknet_stage(128, 2)
        + _darknet_stage(256, 8)
        + _darknet_stage(512, 8)
        + _darknet_stage(1024, 4)
        + [("conv", 512, 1, 1), ("conv", 1024, 3, 1), ("conv", 125, 1, 1)]
    ),
)


def _resnet_stage(c: int, n_blocks: int, stride: int):
    layers = [("conv", c, 3, stride)]  # stage entry (projection + downsample)
    for _ in range(n_blocks):
        layers += [("conv", c // 4, 1, 1), ("conv", c // 4, 3, 1),
                   ("conv", c, 1, 1), ("residual", 4)]
    return layers


# SSD-class detector: ResNet-50-style backbone at 512x512 + multiscale heads.
# width 0.78 -> ~26 GMACs (Table 1: 26G).
SSD_WIDTH = 0.85
SSD_SPEC = ConvNetSpec(
    name="ssd",
    in_channels=3,
    input_hw=512,
    layers=tuple(
        [("conv", 64, 7, 2), ("maxpool", 3, 2)]
        + _resnet_stage(256, 3, 1)
        + _resnet_stage(512, 4, 2)
        + _resnet_stage(1024, 6, 2)
        + _resnet_stage(2048, 3, 2)
        # extra SSD feature layers + class/box head convs
        + [("conv", 512, 1, 1), ("conv", 512, 3, 2),
           ("conv", 256, 1, 1), ("conv", 256, 3, 2),
           ("conv", 486, 3, 1)]
    ),
)


# GOTURN-class tracker: AlexNet-style twin towers + FC regression head.
# width 1.9 -> ~11 GMACs for the two towers + head (Table 1: 11G).
GOTURN_WIDTH = 2.1
GOTURN_TOWER = ConvNetSpec(
    name="goturn_tower",
    in_channels=3,
    input_hw=227,
    layers=(
        ("conv", 96, 11, 4), ("maxpool", 3, 2),
        ("conv", 256, 5, 1), ("maxpool", 3, 2),
        ("conv", 384, 3, 1),
        ("conv", 384, 3, 1),
        ("conv", 256, 3, 1), ("maxpool", 3, 2),
        ("globalpool",),
    ),
)
GOTURN_HEAD = ConvNetSpec(
    name="goturn_head",
    in_channels=512,  # concat of two tower outputs (pre width_mult)
    input_hw=1,
    layers=(("fc", 4096), ("fc", 4096), ("fc", 4)),
)
GOTURN_SPEC = GOTURN_TOWER  # stats helper below combines tower+head


def init_yolo(key, width_mult: float = YOLO_WIDTH, dtype=jnp.float32):
    return init_convnet(key, YOLO_SPEC, width_mult, dtype)


def yolo_apply(params, x, width_mult: float = YOLO_WIDTH):
    del width_mult
    return convnet_apply(params, YOLO_SPEC, x)


def init_ssd(key, width_mult: float = SSD_WIDTH, dtype=jnp.float32):
    return init_convnet(key, SSD_SPEC, width_mult, dtype)


def ssd_apply(params, x, width_mult: float = SSD_WIDTH):
    del width_mult
    return convnet_apply(params, SSD_SPEC, x)


def init_goturn(key, width_mult: float = GOTURN_WIDTH, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    tower = init_convnet(k1, GOTURN_TOWER, width_mult, dtype)
    # head input = 2 towers of (256 * width) channels
    c = 2 * max(4, int(256 * width_mult))
    head_spec = ConvNetSpec(name="goturn_head", in_channels=c, input_hw=1,
                            layers=GOTURN_HEAD.layers)
    head = init_convnet(k2, head_spec, 1.0, dtype)
    return {"tower": tower, "head": head, "head_spec": head_spec}


def goturn_apply(params, prev_crop, curr_crop):
    f1 = convnet_apply(params["tower"], GOTURN_TOWER, prev_crop)
    f2 = convnet_apply(params["tower"], GOTURN_TOWER, curr_crop)
    feats = jnp.concatenate([f1, f2], axis=-1)
    return convnet_apply(params["head"], params["head_spec"], feats)


def goturn_stats(width_mult: float = GOTURN_WIDTH) -> dict:
    tower = convnet_stats(GOTURN_TOWER, width_mult)
    c = 2 * max(4, int(256 * width_mult))
    head_spec = ConvNetSpec(name="goturn_head", in_channels=c, input_hw=1,
                            layers=GOTURN_HEAD.layers)
    head = convnet_stats(head_spec, 1.0)
    return {
        "name": "goturn",
        "macs": 2 * tower["macs"] + head["macs"],
        "params": tower["params"] + head["params"],
        "weights_and_neurons": (tower["weights_and_neurons"] * 2
                                + head["weights_and_neurons"]),
        "layers": tower["layers"] + head["layers"],
        "per_layer": tower["per_layer"] + head["per_layer"],
    }


PERCEPTION_SPECS = {
    "yolo": (YOLO_SPEC, YOLO_WIDTH),
    "ssd": (SSD_SPEC, SSD_WIDTH),
    "goturn": (GOTURN_TOWER, GOTURN_WIDTH),
}


def perception_stats() -> dict:
    return {
        "yolo": convnet_stats(YOLO_SPEC, YOLO_WIDTH),
        "ssd": convnet_stats(SSD_SPEC, SSD_WIDTH),
        "goturn": goturn_stats(),
    }
