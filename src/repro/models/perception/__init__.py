from repro.models.perception.cnn import (
    ConvNetSpec,
    init_convnet,
    convnet_apply,
    convnet_stats,
)
from repro.models.perception.nets import (
    YOLO_SPEC,
    SSD_SPEC,
    GOTURN_SPEC,
    PERCEPTION_SPECS,
    init_yolo,
    yolo_apply,
    init_ssd,
    ssd_apply,
    init_goturn,
    goturn_apply,
)
