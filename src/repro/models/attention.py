"""Attention: GQA/MHA, sliding-window (SWA), and MLA (latent) variants.

Three execution paths, all numerically equivalent where they overlap:

* ``naive``     — full-scores attention (small tests / oracles).
* ``chunked``   — lax.scan over KV chunks with online softmax (the XLA
                  fallback for TPU; bounded VMEM-sized working set).
* SWA prefill   — exact chunk+neighbour decomposition (each query chunk of
                  width W attends to its own and the previous KV chunk only),
                  giving true O(S·W) compute, not masked O(S²).

Decode paths read a KV cache whose sequence dim may be sharded over the
"model" mesh axis (flash-decoding style: partial softmax + all-reduce,
inserted by GSPMD from the sharding constraints).

GQA is computed by broadcasting KV heads to query heads *inside* the chunk
loop; XLA fuses the broadcast, so stored cache stays [B, S, K, D] while the
matmuls shard cleanly over flat query heads.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import with_logical_constraint as wlc

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, param_dtype) -> dict:
    if cfg.attention_kind == "mla":
        return init_mla_attention(key, cfg, param_dtype)
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(k1, (d, h, hd), ("embed", "heads", "head_dim"),
                           param_dtype, fan_in=d),
        "wk": L.dense_init(k2, (d, k, hd), ("embed", "kv_heads", "head_dim"),
                           param_dtype, fan_in=d),
        "wv": L.dense_init(k3, (d, k, hd), ("embed", "kv_heads", "head_dim"),
                           param_dtype, fan_in=d),
        "wo": L.dense_init(k4, (h, hd, d), ("heads", "head_dim", "embed"),
                           param_dtype, fan_in=h * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.ones_init((hd,), ("head_dim",), param_dtype)
        p["k_norm"] = L.ones_init((hd,), ("head_dim",), param_dtype)
    return p


def init_mla_attention(key, cfg: ModelConfig, param_dtype) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    keys = jax.random.split(key, 7)
    p = {
        "wkv_a": L.dense_init(keys[2], (d, kvr + rope), ("embed", "lora"),
                              param_dtype, fan_in=d),
        "kv_norm": L.ones_init((kvr,), ("lora",), param_dtype),
        "wk_b": L.dense_init(keys[3], (kvr, h, nope), ("lora", "heads", "head_dim"),
                             param_dtype, fan_in=kvr),
        "wv_b": L.dense_init(keys[4], (kvr, h, vd), ("lora", "heads", "head_dim"),
                             param_dtype, fan_in=kvr),
        "wo": L.dense_init(keys[5], (h, vd, d), ("heads", "head_dim", "embed"),
                           param_dtype, fan_in=h * vd),
    }
    if qr:
        p["wq_a"] = L.dense_init(keys[0], (d, qr), ("embed", "lora"),
                                 param_dtype, fan_in=d)
        p["q_norm"] = L.ones_init((qr,), ("lora",), param_dtype)
        p["wq_b"] = L.dense_init(keys[1], (qr, h, nope + rope),
                                 ("lora", "heads", "head_dim"),
                                 param_dtype, fan_in=qr)
    else:
        p["wq"] = L.dense_init(keys[0], (d, h, nope + rope),
                               ("embed", "heads", "head_dim"),
                               param_dtype, fan_in=d)
    return p


# ---------------------------------------------------------------------------
# Core softmax-attention primitives
# ---------------------------------------------------------------------------

def _broadcast_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """[B, T, K, D] -> [B, T, H, D] by repeating each KV head H//K times."""
    b, t, kh, d = k.shape
    if kh == num_heads:
        return k
    reps = num_heads // kh
    return jnp.repeat(k, reps, axis=2)


def naive_attention(q, k, v, *, causal: bool, scale: float,
                    window: Optional[int] = None,
                    q_offset: int | jax.Array = 0) -> jax.Array:
    """q [B,S,H,D], k/v [B,T,K,D]. Full-score reference path."""
    h = q.shape[2]
    k = _broadcast_kv(k, h)
    v = _broadcast_kv(v, h)
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    sq, tk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((sq, tk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, scale: float,
                      chunk_kv: int, window: Optional[int] = None,
                      q_offset: int | jax.Array = 0) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks.

    q [B,S,H,D]; k/v [B,T,K,D]. Working set per step is one KV chunk
    broadcast to H heads — this is the XLA analogue of flash attention.
    """
    b, s, h, d = q.shape
    dv = v.shape[-1]  # may differ from d (MLA: qk dim 96, v dim 64)
    t = k.shape[1]
    chunk_kv = min(chunk_kv, t)
    n_chunks = -(-t // chunk_kv)
    pad = n_chunks * chunk_kv - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk_kv, k.shape[2], d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk_kv, v.shape[2], dv).transpose(1, 0, 2, 3, 4)

    qf = q.astype(jnp.float32)
    qpos = jnp.arange(s)[:, None] + q_offset  # [S, 1]

    def step(carry, inp):
        m, l, acc = carry
        idx, k_blk, v_blk = inp
        k_blk = _broadcast_kv(k_blk, h)
        v_blk = _broadcast_kv(v_blk, h)
        scores = jnp.einsum("bshd,bthd->bhst", qf, k_blk.astype(jnp.float32)) * scale
        kpos = idx * chunk_kv + jnp.arange(chunk_kv)[None, :]
        mask = kpos < t  # padding
        if causal:
            mask = mask & (qpos >= kpos)
        if window is not None:
            mask = mask & ((qpos - kpos) < window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, s), dtype=jnp.float32)
    acc0 = jnp.zeros((b, h, s, dv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,S,H,D]


def sliding_window_attention(q, k, v, *, scale: float, window: int) -> jax.Array:
    """Exact causal SWA via chunk+neighbour decomposition: O(S·W) compute.

    Requires q and k aligned (self-attention, q_offset == 0). Sequence is
    padded to a multiple of W; each query chunk attends to [prev, self]
    KV chunks with an exact relative-position mask.
    """
    b, s, h, d = q.shape
    k = _broadcast_kv(k, h)
    v = _broadcast_kv(v, h)
    w = window
    n = -(-s // w)
    pad = n * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(b, n, w, h, d)
    kc = k.reshape(b, n, w, h, d)
    vc = v.reshape(b, n, w, h, d)
    # previous chunk (chunk -1 = zeros, fully masked)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kc], axis=2)  # [B, n, 2W, H, D]
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", qc.astype(jnp.float32),
                        k2.astype(jnp.float32)) * scale
    qpos = jnp.arange(w)[:, None]            # within-chunk query pos
    kpos = jnp.arange(2 * w)[None, :] - w    # relative chunk-local key pos
    rel = qpos - kpos                        # in [1-w, 2w-1]
    mask = (rel >= 0) & (rel < w)
    first = jnp.arange(n) == 0               # first chunk has no prev
    mask_first = mask & (kpos >= 0)
    full_mask = jnp.where(first[:, None, None], mask_first[None], mask[None])
    scores = jnp.where(full_mask[None, :, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs, v2.astype(jnp.float32))
    out = out.reshape(b, n * w, h, d)
    return out[:, :s].astype(q.dtype)


def attention_core(q, k, v, cfg: ModelConfig, *, causal=True,
                   window=None, q_offset=0) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    if window is not None and causal and cfg.attention_impl != "naive" \
            and q.shape[1] == k.shape[1] and q.shape[1] > window:
        return sliding_window_attention(q, k, v, scale=scale, window=window)
    if cfg.attention_impl == "naive" or q.shape[1] * k.shape[1] <= 512 * 512:
        return naive_attention(q, k, v, causal=causal, scale=scale,
                               window=window, q_offset=q_offset)
    return chunked_attention(q, k, v, causal=causal, scale=scale,
                             chunk_kv=cfg.attn_chunk_kv, window=window,
                             q_offset=q_offset)


# ---------------------------------------------------------------------------
# GQA self-attention (train / prefill)
# ---------------------------------------------------------------------------

class KVCacheEntry(NamedTuple):
    k: jax.Array  # [B, S, K, D]  (GQA)  /  latent [B, S, R] (MLA)
    v: jax.Array  # [B, S, K, D]         /  rope   [B, S, P] (MLA)


def gqa_apply(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              *, causal: bool = True, window: Optional[int] = None,
              return_cache: bool = False):
    """x [B,S,E] -> [B,S,E] (+ optional KV cache entries)."""
    dt = x.dtype
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(dt))
    k = jnp.einsum("bse,ekd->bskd", x, p["wk"].astype(dt))
    v = jnp.einsum("bse,ekd->bskd", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = wlc(q, ("batch", None, "heads", "head_dim"))
    k = wlc(k, ("batch", None, "kv_heads", "head_dim"))
    v = wlc(v, ("batch", None, "kv_heads", "head_dim"))
    out = attention_core(q, k, v, cfg, causal=causal, window=window)
    out = wlc(out, ("batch", None, "heads", "head_dim"))
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    y = wlc(y, ("batch", None, None))
    if return_cache:
        # cache leaves the step as output: shard seq over "model" so the
        # per-device slice is cache/(batch_shards*model) not cache/batch
        k = wlc(k, ("cache_batch", "kv_seq", "kv_heads", "head_dim"))
        v = wlc(v, ("cache_batch", "kv_seq", "kv_heads", "head_dim"))
        return y, KVCacheEntry(k=k, v=v)
    return y


def gqa_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: KVCacheEntry,
               pos: jax.Array, *, window: Optional[int] = None):
    """One-token decode. x [B,1,E]; cache k/v [B,S,K,D]; pos scalar int.

    The cache sequence dim may be sharded ("kv_seq" -> "model"); the partial
    softmax across shards is GSPMD-inserted (flash-decoding).  The new KV is
    written at ``pos`` via dynamic_update_slice.
    """
    dt = x.dtype
    b = x.shape[0]
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(dt))
    k_new = jnp.einsum("bse,ekd->bskd", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bse,ekd->bskd", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k_new = L.rmsnorm(p["k_norm"], k_new, cfg.norm_eps)
    posb = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k_new = L.apply_rope(k_new, posb, cfg.rope_theta)

    s_cache = cache.k.shape[1]
    if window is not None and s_cache >= window:
        # ring-buffer semantics: cache holds last `window` positions
        write_at = jax.lax.rem(pos, jnp.int32(s_cache))
    else:
        write_at = pos
    k_all = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, write_at, 0, 0))
    v_all = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, write_at, 0, 0))
    k_all = wlc(k_all, ("cache_batch", "kv_seq", "kv_heads", "head_dim"))
    v_all = wlc(v_all, ("cache_batch", "kv_seq", "kv_heads", "head_dim"))

    # Grouped-head attention (no KV broadcast materialization: repeating
    # K->H would write a 12x-inflated cache copy through HBM each step).
    h = q.shape[2]
    kh = k_all.shape[2]
    g = h // kh
    b_ = q.shape[0]
    qg = q.reshape(b_, 1, kh, g, q.shape[-1])
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k_all.astype(jnp.float32)) * scale  # [B,K,G,1,S]
    kpos = jnp.arange(s_cache)[None, None, None, None, :]
    # Full cache: slots > pos are future positions.  Ring buffer (SWA): every
    # written slot is in-window by construction, and `kpos <= pos` masks
    # exactly the not-yet-written slots during warmup (all-true once wrapped).
    valid = kpos <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    # flash-decoding: keep scores sharded along the cache sequence dim
    # (matching the cache layout); the softmax max/sum and the PV partial
    # sums become small all-reduces over "model".
    scores = wlc(scores, ("cache_batch", None, None, None, "kv_seq"))
    # stable softmax with f32 stats, bf16 probs for the PV read (halves the
    # biggest HBM stream at decode; max-subtracted exps are bf16-safe)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = (e / denom).astype(dt)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_all.astype(dt))
    out = out.reshape(b_, 1, h, q.shape[-1])
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    y = wlc(y, ("batch", None, None))
    return y, KVCacheEntry(k=k_all, v=v_all)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def _mla_q(p, cfg: ModelConfig, x, positions):
    dt = x.dtype
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bse,er->bsr", x, p["wq_a"].astype(dt))
        cq = L.rmsnorm(p["q_norm"], cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rhd->bshd", cq, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              *, causal: bool = True, return_cache: bool = False):
    """MLA prefill/train: latent is expanded to per-head K/V (standard path)."""
    dt = x.dtype
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)

    ckv = jnp.einsum("bse,er->bsr", x, p["wkv_a"].astype(dt))
    c_kv, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = L.rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhd->bshd", c_kv, p["wv_b"].astype(dt))
    h = k_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_rope.shape[:2], h, rope))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = wlc(q, ("batch", None, "heads", "head_dim"))
    k = wlc(k, ("batch", None, "heads", "head_dim"))
    v = wlc(v, ("batch", None, "heads", "head_dim"))
    out = attention_core(q, k, v, cfg, causal=causal)
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    y = wlc(y, ("batch", None, None))
    if return_cache:
        c_kv = wlc(c_kv, ("cache_batch", "kv_seq", "lora"))
        k_r = wlc(k_rope[:, :, 0, :], ("cache_batch", "kv_seq", "lora"))
        return y, KVCacheEntry(k=c_kv, v=k_r)
    return y


def mla_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: KVCacheEntry,
               pos: jax.Array):
    """Weight-absorbed MLA decode (DeepSeek-V2 style).

    Cache stores the compressed latent [B,S,R] + rope key [B,S,P]: per-token
    cache bytes are (R+P), independent of head count.  Queries are absorbed
    into latent space, so decode attends MQA-style over the latent.
    """
    dt = x.dtype
    b = x.shape[0]
    q_nope, q_rope = _mla_q(p, cfg, x, jnp.full((b, 1), pos, dtype=jnp.int32))

    ckv = jnp.einsum("bse,er->bsr", x, p["wkv_a"].astype(dt))
    c_new, kr_new = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_new = L.rmsnorm(p["kv_norm"], c_new, cfg.norm_eps)
    kr_new = L.apply_rope(kr_new[:, :, None, :],
                          jnp.full((b, 1), pos, dtype=jnp.int32),
                          cfg.rope_theta)[:, :, 0, :]

    c_all = jax.lax.dynamic_update_slice(
        cache.k, c_new.astype(cache.k.dtype), (0, pos, 0))
    kr_all = jax.lax.dynamic_update_slice(
        cache.v, kr_new.astype(cache.v.dtype), (0, pos, 0))
    c_all = wlc(c_all, ("cache_batch", "kv_seq", "lora"))
    kr_all = wlc(kr_all, ("cache_batch", "kv_seq", "lora"))

    # absorb: q_nope' = q_nope @ wk_b^T  -> latent-space queries [B,1,H,R]
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, p["wk_b"].astype(dt))
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s_nope = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        c_all.astype(jnp.float32))
    s_rope = jnp.einsum("bshp,btp->bhst", q_rope.astype(jnp.float32),
                        kr_all.astype(jnp.float32))
    scores = (s_nope + s_rope) * scale
    s_cache = c_all.shape[1]
    valid = jnp.arange(s_cache)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    scores = wlc(scores, ("cache_batch", None, None, "kv_seq"))
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", probs, c_all.astype(jnp.float32))
    out = jnp.einsum("bshr,rhd->bshd", o_lat.astype(dt), p["wv_b"].astype(dt))
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    y = wlc(y, ("batch", None, None))
    return y, KVCacheEntry(k=c_all, v=kr_all)


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig, param_dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, (d, h, hd), ("embed", "heads", "head_dim"),
                           param_dtype, fan_in=d),
        "wk": L.dense_init(k2, (d, h, hd), ("embed", "heads", "head_dim"),
                           param_dtype, fan_in=d),
        "wv": L.dense_init(k3, (d, h, hd), ("embed", "heads", "head_dim"),
                           param_dtype, fan_in=d),
        "wo": L.dense_init(k4, (h, hd, d), ("heads", "head_dim", "embed"),
                           param_dtype, fan_in=h * hd),
    }


def cross_attention_kv(p: dict, enc_out: jax.Array) -> KVCacheEntry:
    dt = enc_out.dtype
    k = jnp.einsum("bte,ehd->bthd", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bte,ehd->bthd", enc_out, p["wv"].astype(dt))
    return KVCacheEntry(k=k, v=v)


def cross_attention_apply(p: dict, cfg: ModelConfig, x: jax.Array,
                          kv: KVCacheEntry) -> jax.Array:
    dt = x.dtype
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(dt))
    q = wlc(q, ("batch", None, "heads", "head_dim"))
    out = attention_core(q, kv.k, kv.v, cfg, causal=False)
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(dt))
    return wlc(y, ("batch", None, None))
