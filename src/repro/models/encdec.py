"""Encoder–decoder transformer (seamless-m4t backbone).

The speech frontend is a stub: ``frontend_embeds`` [B, T_src, d_model] arrive
precomputed (fbank-frame embeddings) per the assignment brief; a learned
projector maps them into the encoder.  Decoder layers are
self-attn -> cross-attn -> FFN; decode carries a self-attention KV cache plus
per-layer cross KV computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import stack_init, _slice_layer
from repro.sharding import Param, with_logical_constraint as wlc


def _init_enc_block(key, cfg: ModelConfig, pdt):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.init_rmsnorm(cfg.d_model, pdt),
        "attn": A.init_attention(k1, cfg, pdt),
        "norm2": L.init_rmsnorm(cfg.d_model, pdt),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, pdt),
    }


def _init_dec_block(key, cfg: ModelConfig, pdt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.init_rmsnorm(cfg.d_model, pdt),
        "self_attn": A.init_attention(k1, cfg, pdt),
        "norm_x": L.init_rmsnorm(cfg.d_model, pdt),
        "cross_attn": A.init_cross_attention(k2, cfg, pdt),
        "norm2": L.init_rmsnorm(cfg.d_model, pdt),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, pdt),
    }


def init_encdec(key, cfg: ModelConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    return {
        "projector": L.init_mlp(keys[0], cfg.d_model, cfg.d_model * 2, pdt),
        "embed": L.init_embedding(keys[1], cfg.vocab_size, cfg.d_model, pdt),
        "enc_blocks": stack_init(lambda k: _init_enc_block(k, cfg, pdt),
                                 keys[2], cfg.num_encoder_layers),
        "enc_norm": L.init_rmsnorm(cfg.d_model, pdt),
        "dec_blocks": stack_init(lambda k: _init_dec_block(k, cfg, pdt),
                                 keys[3], cfg.num_layers),
        "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
        "unembed": L.embed_init(keys[4], (cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), pdt,
                                scale=1.0 / (cfg.d_model ** 0.5)),
    }


def encode(params, cfg: ModelConfig, frontend_embeds: jax.Array) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    x = L.mlp_apply(params["projector"], frontend_embeds.astype(dt))
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, p):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + A.gqa_apply(p["attn"], cfg, h, positions, causal=False)
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h2)
        return wlc(x, ("batch", "seq", None)), None

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(p, cfg, spec_unused, x, positions, enc_out):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + A.gqa_apply(p["self_attn"], cfg, h, positions, causal=True)
    hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
    kv = A.cross_attention_kv(p["cross_attn"], enc_out)
    x = x + A.cross_attention_apply(p["cross_attn"], cfg, hx, kv)
    h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h2)
    return wlc(x, ("batch", "seq", None))


def encdec_loss(params, cfg: ModelConfig, batch: dict):
    """batch: frontend_embeds [B,T_src,D], tokens [B,S], labels, loss_mask."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, batch["frontend_embeds"])
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"], tokens, dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        return _dec_block(p, cfg, None, x, positions, enc_out), None

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_logits(params["unembed"], x, jnp.dtype(cfg.logits_dtype))
    loss = L.softmax_cross_entropy(logits, batch["labels"],
                                   batch.get("loss_mask"))
    return loss, {"loss": loss,
                  "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}


def encdec_prefill(params, cfg: ModelConfig, batch: dict):
    """Encode + run decoder prompt; build self-cache and cross-KV."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, batch["frontend_embeds"])
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"], tokens, dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        mix, entry = A.gqa_apply(p["self_attn"], cfg, h, positions,
                                 causal=True, return_cache=True)
        x = x + mix
        hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        kv = A.cross_attention_kv(p["cross_attn"], enc_out)
        x = x + A.cross_attention_apply(p["cross_attn"], cfg, hx, kv)
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h2)
        return x, {"self": entry, "cross": kv}

    x, cache = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_logits(params["unembed"], x[:, -1:, :],
                              jnp.dtype(cfg.logits_dtype))
    return logits, cache


def encdec_decode_step(params, cfg: ModelConfig, cache, token, pos):
    dt = jnp.dtype(cfg.dtype)
    x = L.embed_lookup(params["embed"], token, dt)

    def body(x, scanned):
        p, cache_slice = scanned
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        mix, new_self = A.gqa_decode(p["self_attn"], cfg, h,
                                     cache_slice["self"], pos)
        x = x + mix
        hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        x = x + A.cross_attention_apply(p["cross_attn"], cfg, hx,
                                        cache_slice["cross"])
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h2)
        return x, {"self": new_self, "cross": cache_slice["cross"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_logits(params["unembed"], x, jnp.dtype(cfg.logits_dtype))
    return logits, new_cache


def init_encdec_cache(cfg: ModelConfig, batch_size: int, seq_len: int,
                      src_len: int):
    """Boxed zero cache for decode dry-run: self KV + cross KV per layer."""
    dt = jnp.dtype(cfg.dtype)
    n = cfg.num_layers
    kv_shape = (n, batch_size, seq_len, cfg.num_kv_heads, cfg.head_dim)
    kv_axes = ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")
    cross_shape = (n, batch_size, src_len, cfg.num_heads, cfg.head_dim)
    cross_axes = ("layers", "cache_batch", None, "heads", "head_dim")
    return {
        "self": A.KVCacheEntry(
            k=Param(jnp.zeros(kv_shape, dt), kv_axes),
            v=Param(jnp.zeros(kv_shape, dt), kv_axes)),
        "cross": A.KVCacheEntry(
            k=Param(jnp.zeros(cross_shape, dt), cross_axes),
            v=Param(jnp.zeros(cross_shape, dt), cross_axes)),
    }
