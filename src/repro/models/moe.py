"""Top-k routed MoE with capacity-bounded scatter dispatch.

Design notes (TPU adaptation):

* Expert weights are sharded over the "model" mesh axis (expert parallelism);
  token activations are sharded over ("pod", "data").  The token->expert
  re-layout is expressed as a scatter into an [E, C, D] buffer with sharding
  constraints; GSPMD lowers the cross-shard movement to all-to-all /
  collective-permute (inspected in the dry-run HLO).
* We deliberately do NOT use GShard einsum dispatch: with E=128 experts the
  [N, E, C] dispatch einsum costs E*C/k (~600x) more FLOPs than the useful
  work.  Scatter/gather keeps HLO FLOPs equal to routed-token matmul FLOPs,
  which is what the §Roofline "useful ratio" is measured against.
* Capacity factor bounds the per-expert buffer; overflowing tokens are
  dropped (standard Switch/GShard semantics) and their residual passes
  through unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import with_logical_constraint as wlc


def init_moe(key, cfg: ModelConfig, param_dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(k1, (d, e), ("embed", "unsharded"), param_dtype,
                               fan_in=d),
        "wi_gate": L.dense_init(k2, (e, d, f), ("expert", "embed", "expert_mlp"),
                                param_dtype, fan_in=d),
        "wi_up": L.dense_init(k3, (e, d, f), ("expert", "embed", "expert_mlp"),
                              param_dtype, fan_in=d),
        "wo": L.dense_init(k4, (e, f, d), ("expert", "expert_mlp", "embed"),
                           param_dtype, fan_in=f),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared"] = L.init_mlp(k5, d, fs, param_dtype)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.num_experts_per_token * cfg.moe_capacity_factor
            / cfg.num_experts)
    # round up to a lane-friendly multiple
    return max(8, -(-c // 8) * 8)


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array):
    """x [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Dispatches to the explicit all-to-all implementation when
    ``cfg.moe_impl == "shard_map"`` and a mesh with a "model" axis is
    active; otherwise the GSPMD scatter path below.
    """
    if getattr(cfg, "moe_impl", "gspmd") == "shard_map":
        from repro.sharding.partition import current_mesh_and_rules
        ctx = current_mesh_and_rules()
        if ctx is not None and "model" in ctx[0].axis_names \
                and cfg.num_experts % ctx[0].shape["model"] == 0:
            return moe_apply_shard_map(p, cfg, x, ctx[0])
    return moe_apply_gspmd(p, cfg, x)


def moe_apply_gspmd(p: dict, cfg: ModelConfig, x: jax.Array):
    """x [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    dt = x.dtype
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_token
    cap = _capacity(cfg, n)

    xf = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch eq. 4) ----
    me = jnp.mean(probs, axis=0)  # [E]
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(one_hot_top1, axis=0)
    aux_loss = cfg.router_aux_loss_coef * e * jnp.sum(me * fe)

    # ---- slot assignment: position of each (token, choice) in its expert ----
    # Sort-based ranking (MegaBlocks-style) instead of a [N*k, E] one-hot
    # cumsum: XLA lowers big cumsums to reduce-window with O(len^2) counted
    # cost, which poisons both the roofline FLOPs and the partitioner.  A
    # stable argsort keeps Switch "first tokens win" capacity semantics.
    flat_e = expert_idx.reshape(n * k)  # row-major: all k choices of token 0
    order = jnp.argsort(flat_e, stable=True)  # [A]
    sorted_e = jnp.take(flat_e, order)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(n * k, dtype=jnp.int32) - jnp.take(starts, sorted_e)
    pos = jnp.zeros((n * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # overflow -> dump row

    # ---- dispatch: scatter token embeddings into [E*C(+1 dump), D] ----
    x_rep = jnp.repeat(xf, k, axis=0)  # [N*k, D]
    buf = jnp.zeros((e * cap + 1, d), dtype=dt).at[slot].set(x_rep)
    buf = buf[: e * cap].reshape(e, cap, d)
    # 2D expert sharding: experts over "model" (EP) AND capacity over
    # "data" — without the capacity split, the [E_loc, cap_global, D]
    # buffer replicates across the data axis and every data shard
    # duplicates the expert matmuls (16x waste observed in the dry-run HLO).
    buf = wlc(buf, ("expert", "expert_cap", None))

    # ---- expert FFN (SwiGLU), E sharded over "model" ----
    gate = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = wlc(h, ("expert", "expert_cap", "expert_mlp"))
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
    y = wlc(y, ("expert", "expert_cap", None))

    # ---- combine: gather back, weight, sum over k choices ----
    y_flat = jnp.concatenate(
        [y.reshape(e * cap, d), jnp.zeros((1, d), dtype=dt)], axis=0)
    gathered = y_flat[slot]  # [N*k, D]
    w = (gate_vals.reshape(n * k, 1) * keep[:, None]).astype(dt)
    out = jnp.sum((gathered * w).reshape(n, k, d), axis=1)

    if cfg.num_shared_experts:
        out = out + L.mlp_apply(p["shared"], x).reshape(n, d)

    out = out.reshape(b, s, d)
    out = wlc(out, ("batch", None, None))
    return out, aux_loss


# ---------------------------------------------------------------------------
# Explicit expert parallelism (shard_map + all_to_all) — §Perf iteration 2
# ---------------------------------------------------------------------------

def _pack_by_bucket(bucket: jax.Array, n_buckets: int, cap: int,
                    rows: jax.Array, extra: jax.Array):
    """Pack ``rows`` [A, D] into [n_buckets*cap, D] by bucket id (stable,
    first-come capacity).  ``extra`` [A, m] int32 rides along (dropped rows
    get sentinel -1).  Returns (packed_rows, packed_extra, slot_of_row,
    keep_mask)."""
    a = bucket.shape[0]
    order = jnp.argsort(bucket, stable=True)
    sorted_b = jnp.take(bucket, order)
    counts = jnp.zeros((n_buckets,), jnp.int32).at[bucket].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(a, dtype=jnp.int32) - jnp.take(starts, sorted_b)
    pos = jnp.zeros((a,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, bucket * cap + pos, n_buckets * cap)
    packed = jnp.zeros((n_buckets * cap + 1, rows.shape[1]),
                       rows.dtype).at[slot].set(rows)[:-1]
    pext = jnp.full((n_buckets * cap + 1, extra.shape[1]), -1,
                    jnp.int32).at[slot].set(
        jnp.where(keep[:, None], extra, -1))[:-1]
    return packed, pext, slot, keep


def moe_apply_shard_map(p: dict, cfg: ModelConfig, x: jax.Array, mesh):
    """Production EP: tokens resharded over "model", routed assignments
    exchanged with two all-to-alls (dispatch + combine), experts computed
    on their owning shard only.

    Wire volume per direction ~= routed-token bytes / devices — the
    GSPMD-scatter baseline instead all-gathers the routed activations.
    """
    from jax.sharding import PartitionSpec as P

    dt = x.dtype
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_token
    m_size = mesh.shape["model"]
    e_loc = e // m_size
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_shards = m_size
    for a_ in batch_axes:
        n_shards *= mesh.shape[a_]
    if n % n_shards:
        return moe_apply_gspmd(p, cfg, x)

    xf = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), 0)
    aux_loss = cfg.router_aux_loss_coef * e * jnp.sum(me * fe)

    n_loc = n // n_shards
    a_loc = n_loc * k
    send_cf = getattr(cfg, "moe_send_capacity_factor", 1.5)
    cap_send = max(8, -(- int(a_loc / m_size * send_cf) // 8) * 8)
    cap_loc = max(8, -(- int(cap_send * m_size / e_loc
                             * cfg.moe_capacity_factor) // 8) * 8)

    tok_spec = P(batch_axes + ("model",), None)

    def local_moe(x_loc, idx_loc, gates_loc, wg, wu, wo):
        # x_loc [n_loc, D]; idx/gates [n_loc, k]; w* [E_loc, ...]
        flat_e = idx_loc.reshape(a_loc)
        dest = flat_e // e_loc
        le = (flat_e % e_loc).astype(jnp.int32)
        x_rep = jnp.repeat(x_loc, k, axis=0)
        meta = jnp.stack([le, jnp.arange(a_loc, dtype=jnp.int32)], axis=1)
        send, send_meta, slot, keep = _pack_by_bucket(
            dest.astype(jnp.int32), m_size, cap_send, x_rep, meta)

        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        recv_meta = jax.lax.all_to_all(send_meta, "model", split_axis=0,
                                       concat_axis=0, tiled=True)

        r = recv.shape[0]
        le_r = jnp.where(recv_meta[:, 0] >= 0, recv_meta[:, 0], e_loc)
        buf, _, slot_r, keep_r = _pack_by_bucket(
            le_r.astype(jnp.int32), e_loc + 1, cap_loc, recv,
            jnp.zeros((r, 1), jnp.int32))
        buf = buf.reshape(e_loc + 1, cap_loc, d)[:e_loc]

        gate = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
        up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
        h = jax.nn.silu(gate) * up
        y = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))

        y_flat = jnp.concatenate(
            [y.reshape(e_loc * cap_loc, d),
             jnp.zeros((cap_loc + 1, d), dt)], axis=0)
        back = y_flat[jnp.minimum(slot_r, e_loc * cap_loc + cap_loc)]
        back = jnp.where(keep_r[:, None], back, 0.0)

        ret = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=True)
        ret_all = jnp.concatenate([ret, jnp.zeros((1, d), dt)], axis=0)
        out_rep = ret_all[jnp.minimum(slot, m_size * cap_send)]
        out_rep = jnp.where(keep[:, None], out_rep, 0.0)
        w = gates_loc.reshape(a_loc, 1).astype(dt)
        return jnp.sum((out_rep * w).reshape(n_loc, k, d), axis=1)

    out_flat = shard_map(
        local_moe, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec,
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=tok_spec,
    )(xf, expert_idx, gate_vals.astype(dt),
      # cast before the boundary: the FSDP weight all-gather implied by the
      # in_spec then moves bf16, not fp32 (halves that wire volume)
      p["wi_gate"].astype(dt), p["wi_up"].astype(dt), p["wo"].astype(dt))

    out = out_flat.reshape(b, s, d)
    if cfg.num_shared_experts:
        out = out + L.mlp_apply(p["shared"], x)
    out = wlc(out, ("batch", None, None))
    return out, aux_loss
