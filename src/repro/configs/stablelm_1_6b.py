"""stablelm-1.6b: 24L d_model=2048 32H (kv=32, full MHA) d_ff=5632 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-1.6b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=8,
    d_ff=320,
    vocab_size=512,
    attention_impl="naive",
)
