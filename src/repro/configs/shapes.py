"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four cells per architecture (40 total):

    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill_step
    decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524,288 global_batch 1     -> serve_step

``long_500k`` requires sub-quadratic attention / bounded cache: it runs for
SSM (mamba2), hybrid (jamba), and SWA (h2o-danube) archs, and is marked
skipped for pure full-attention archs (see DESIGN.md §shape-cell skips).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import Param


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def long_context_capable(cfg: ModelConfig) -> bool:
    """True when the arch has sub-quadratic attention / bounded decode state."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    return cfg.sliding_window is not None


def cell_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not long_context_capable(cfg):
        return False, "pure full-attention arch: unbounded 500k decode cache"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Boxed ShapeDtypeStruct stand-ins for a training batch (weak-type
    correct, shardable, no allocation)."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.is_encoder_decoder:
        src = s // cfg.encoder_seq_ratio
        return {
            "tokens": Param(_sds((b, s), jnp.int32), ("batch", None)),
            "labels": Param(_sds((b, s), jnp.int32), ("batch", None)),
            "loss_mask": Param(_sds((b, s), jnp.float32), ("batch", None)),
            "frontend_embeds": Param(_sds((b, src, cfg.d_model), jnp.float32),
                                     ("batch", "seq", None)),
        }
    if cfg.frontend is not None:
        t = cfg.num_frontend_tokens
        s_text = s - t
        return {
            "tokens": Param(_sds((b, s_text), jnp.int32), ("batch", None)),
            "labels": Param(_sds((b, s_text), jnp.int32), ("batch", None)),
            "loss_mask": Param(_sds((b, s_text), jnp.float32), ("batch", None)),
            "frontend_embeds": Param(_sds((b, t, cfg.d_model), jnp.float32),
                                     ("batch", None, None)),
        }
    return {
        "tokens": Param(_sds((b, s), jnp.int32), ("batch", None)),
        "labels": Param(_sds((b, s), jnp.int32), ("batch", None)),
        "loss_mask": Param(_sds((b, s), jnp.float32), ("batch", None)),
    }


def decode_token_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    return {
        "token": Param(_sds((cell.global_batch, 1), jnp.int32),
                       ("batch", None)),
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """All model inputs for a cell as boxed ShapeDtypeStructs."""
    cell = SHAPES[shape_name]
    if cell.step in ("train", "prefill"):
        return train_batch_specs(cfg, cell)
    return decode_token_specs(cfg, cell)
