"""minicpm3-4b: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA.

[hf:openbmb/MiniCPM3-4B]. Multi-head latent attention: KV cache stores the
compressed latent (R=256) + rope key (P=32) per token; decode uses the
weight-absorbed path.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    head_dim=96,  # nope + rope
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm3-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=8,
    d_ff=256,
    vocab_size=512,
    attention_kind="mla",
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    head_dim=24,
    attention_impl="naive",
)
