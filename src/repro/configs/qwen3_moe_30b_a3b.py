"""qwen3-moe-30b-a3b: 48L d_model=2048 32H (GQA kv=4) d_ff=768 (expert)
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].  QK-norm per
the Qwen3 family signature.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_token=8,
    qk_norm=True,
    use_grad_accum_microbatches=2,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=48,
    vocab_size=512,
    num_experts=8,
    num_experts_per_token=2,
    qk_norm=True,
    attention_impl="naive",
)
