"""mamba2-130m: 24L d_model=768, attention-free, vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060].  d_inner = 1536, 24 SSD heads
of dim 64.  O(1) decode state -> runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern="M",
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-130m-smoke",
    family="ssm",
    num_layers=3,
    d_model=96,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    layer_pattern="M",
    ssm_state_dim=16,
    ssm_head_dim=24,
    ssm_chunk=8,
    tie_embeddings=True,
)
