"""mistral-large-123b: 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

[hf:mistralai/Mistral-Large-Instruct-2407]. The FSDP+TP stress case:
grad-accumulation microbatches keep the remat carries inside v5e HBM.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    use_grad_accum_microbatches=4,
)

SMOKE_CONFIG = ModelConfig(
    name="mistral-large-123b-smoke",
    family="dense",
    num_layers=3,
    d_model=192,
    num_heads=12,
    num_kv_heads=2,
    d_ff=448,
    vocab_size=512,
    attention_impl="naive",
)
