"""jamba-v0.1-52b: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Mamba+attention 1:7 interleave, MoE 16 experts top-2 every other layer
[arXiv:2403.19887].  Period-8 super-block "MMMMAMMM" with MoE at odd
layer indices.  Hybrid -> runs the long_500k cell (SSM state + 4 full-attn
layer caches).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern="MMMMAMMM",
    num_experts=16,
    num_experts_per_token=2,
    moe_layer_period=2,
    ssm_state_dim=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    use_grad_accum_microbatches=2,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    layer_pattern="MMAM",
    num_experts=4,
    num_experts_per_token=2,
    moe_layer_period=2,
    ssm_state_dim=16,
    ssm_head_dim=32,
    ssm_chunk=8,
    attention_impl="naive",
)
