"""moonshot-v1-16b-a3b: 48L d_model=2048 16H (GQA kv=16) d_ff=1408 (expert)
vocab=163840, MoE 64 experts top-6 [hf:moonshotai/Moonlight-16B-A3B].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    num_experts_per_token=6,
    use_grad_accum_microbatches=2,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=8,
    d_ff=64,
    vocab_size=512,
    num_experts=8,
    num_experts_per_token=2,
    attention_impl="naive",
)
