"""seamless-m4t-medium: enc-dec 12L+12L d_model=1024 16H d_ff=4096
vocab=256206 [arXiv:2308.11596].  The speech frontend is a STUB — inputs
are precomputed fbank-frame embeddings [B, T_src, d_model] with
T_src = tgt_len / 4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    num_encoder_layers=12,
    encoder_seq_ratio=4,
    frontend="audio_stub",
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=8,
    d_ff=256,
    vocab_size=512,
    is_encoder_decoder=True,
    num_encoder_layers=2,
    encoder_seq_ratio=4,
    frontend="audio_stub",
    attention_impl="naive",
)
