"""h2o-danube-3-4b: 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.

llama+mistral mix with sliding-window attention [arXiv:2401.16818].
SWA window 4096 -> bounded KV cache; runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="h2o-danube-3-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    sliding_window=16,
    attention_impl="naive",
)
