"""internvl2-76b: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT + InternLM2 [arXiv:2404.16821].  VLM: the vision frontend is a
STUB per the assignment brief — input_specs provide precomputed patch
embeddings [B, 256, d_model]; a learned projector maps them into the LM.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision_stub",
    num_frontend_tokens=256,
    use_grad_accum_microbatches=2,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    frontend="vision_stub",
    num_frontend_tokens=4,
    attention_impl="naive",
)
