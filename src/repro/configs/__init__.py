"""Architecture registry: ``--arch <id>`` ids map to ModelConfigs."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "h2o-danube-3-4b",
    "mistral-large-123b",
    "minicpm3-4b",
    "stablelm-1.6b",
    "jamba-v0.1-52b",
    "mamba2-130m",
    "internvl2-76b",
    "moonshot-v1-16b-a3b",
    "qwen3-moe-30b-a3b",
    "seamless-m4t-medium",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return importlib.import_module(_MODULES[arch_id]).SMOKE_CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
