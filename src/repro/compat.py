"""Version-adaptive JAX compatibility seam.

The repo targets the newest public JAX API surface (``jax.shard_map``,
``jax.make_mesh(axis_types=...)``, ``pltpu.CompilerParams``); CI and the
baked container run jax 0.4.37, where those names live elsewhere or do not
exist yet.  Every version-sensitive symbol is resolved HERE, once, at import
time — call sites import from ``repro.compat`` and never probe ``jax``
themselves.

Shimmed surface (see DESIGN.md "Compat-shim policy" for the drop rules):

=====================  ====================================================
export                 resolves to
=====================  ====================================================
``shard_map``          ``jax.shard_map`` (>= 0.6) else
                       ``jax.experimental.shard_map.shard_map``
``make_mesh``          ``jax.make_mesh`` with ``axis_types`` forwarded when
                       supported, silently dropped otherwise
``abstract_mesh``      ``jax.sharding.AbstractMesh`` under both calling
                       conventions: ``(shape, names)`` (new) vs the 0.4.x
                       ``((name, size), ...)`` shape-tuple
``default_axis_types`` ``(jax.sharding.AxisType.Auto,) * n`` when
                       ``AxisType`` exists, else ``None``
``CompilerParams``     ``pltpu.CompilerParams`` (>= 0.6) else
                       ``pltpu.TPUCompilerParams``
``pallas_interpret_default``  True off-accelerator (Pallas kernels fall
                       back to interpret mode so CPU CI executes the
                       kernel bodies); ``REPRO_KERNEL_COMPILED=1`` also
                       compiles on GPU, ``=0`` forces interpret (debug)
=====================  ====================================================
"""
from __future__ import annotations

import inspect

import jax

# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):                     # jax >= 0.6
    shard_map = jax.shard_map
else:                                             # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def default_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n`` on new JAX, ``None`` where the enum does not
    exist (0.4.x meshes are implicitly Auto)."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n_axes
    return None


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates pre-``axis_types`` JAX.

    ``axis_types=None`` asks for the default (Auto on every axis); on old
    JAX the kwarg is dropped entirely, which means the same thing.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _MAKE_MESH_TAKES_AXIS_TYPES:
        if axis_types is None:
            axis_types = default_axis_types(len(tuple(axis_names)))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` under either calling convention.

    New JAX: ``AbstractMesh(axis_shapes, axis_names)``.  0.4.x:
    ``AbstractMesh(shape_tuple)`` with ``((name, size), ...)`` pairs.
    """
    AbstractMesh = jax.sharding.AbstractMesh
    params = list(inspect.signature(AbstractMesh.__init__).parameters)
    if "axis_names" in params or "axis_name" in params:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


# ---------------------------------------------------------------------------
# Pallas TPU
# ---------------------------------------------------------------------------

def __getattr__(name):
    # ``CompilerParams`` resolves lazily so non-kernel consumers of this
    # module (sharding, serving, launch) never pay the Pallas/Mosaic
    # import at startup.  jax >= 0.6 renamed TPUCompilerParams ->
    # CompilerParams; accept either.
    if name == "CompilerParams":
        from jax.experimental.pallas import tpu as _pltpu
        return getattr(_pltpu, "CompilerParams", None) \
            or _pltpu.TPUCompilerParams
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _interpret_for(platform: str, compiled_env: str | None) -> bool:
    """Pure decision core of :func:`pallas_interpret_default` (split out so
    the protocol tests can exercise every platform/env combination on a
    CPU-only host).

    * ``REPRO_KERNEL_COMPILED=0`` forces interpret everywhere (debug).
    * TPU compiles by default (Mosaic is the native path).
    * ``REPRO_KERNEL_COMPILED=1`` additionally compiles on GPU (Triton
      lowering) — the hardware-run protocol of ``repro.kernels.protocol``.
    * CPU has no Pallas compiler: always interpret, even when compiled
      mode is requested — the benchmark/CI layer reports that skip
      explicitly rather than silently greening.
    """
    if compiled_env == "0":
        return True
    if platform == "tpu":
        return False
    if compiled_env == "1" and platform == "gpu":
        return False
    return True


def pallas_interpret_default() -> bool:
    """Pallas kernels compile (Mosaic/Triton) only on TPU — or on GPU when
    ``REPRO_KERNEL_COMPILED=1`` requests the compiled hardware run;
    everywhere else default to interpret mode so the same call sites run
    under CPU CI."""
    import os
    return _interpret_for(jax.devices()[0].platform,
                          os.environ.get("REPRO_KERNEL_COMPILED"))
