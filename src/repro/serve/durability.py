"""Durability & failure recovery for the QoS serving layer (ISSUE 6).

The paper's "basically 100% of tasks within their required period" claim is
a safety claim, and safety claims have to survive failures: a killed
serving process, a re-meshed device count, a dead or degraded accelerator
mid-route (the per-chiplet fault model of arXiv:2411.16007).  This module
composes the existing pieces — the PR-5 ``PlatformState`` preemption seam,
the atomic ``AsyncCheckpointer``, ``StragglerDetector``/``PreemptionGuard``
— into a crash-recoverable serving story:

* **Snapshots** (``DurableQoSEngine.snapshot``): on a segment cadence the
  full serving state — batched ``PlatformState``, QoS queues, the running
  wave (including its partial records), wave log, dead-letter log, virtual
  clock, fault/detector state, and the policy weights — is packed into a
  flat array list plus a JSON meta blob and handed to ``AsyncCheckpointer``
  (host copy synchronous, disk write on the background thread).

* **Crash recovery** (``DurableQoSEngine.restore``): the latest snapshot is
  self-describing (``load_checkpoint_arrays`` needs no live template), so a
  fresh process rebuilds the engine mid-wave and replays deterministically.
  Every admission/preemption/shed decision is a pure function of the
  virtual clock and the queues — both in the snapshot — so the recovered
  trajectory is **bit-exact** vs an uninterrupted run (the kill-mid-wave
  subprocess test in tests/test_durability.py proves it on the served set,
  placements, and final per-request ``PlatformState``).

* **Elastic resume**: restoring with a ``("routes",)`` mesh re-pads the
  wave's lane axis to the mesh size (``pad_route_batch`` + extra
  ``platform_init`` lanes) and dispatches through a shard_mapped vmapped
  scan — snapshots are mesh-independent, so a 1-device snapshot restores
  onto N devices with placement parity.

* **Fault injection + graceful degradation** (``FaultInjection``): at a
  virtual-clock instant an accelerator degrades by ``factor`` (a large
  factor is a dead core).  Execution truth switches to the degraded spec
  for *everyone*; a ``handled`` fault additionally stops the core's
  heartbeats, the ``StragglerDetector`` (driven by the serving virtual
  clock) flags it, and mitigation masks it out of the Q argmax
  (``_schedule_run_masked``), rescales the lockstep service cost to the
  surviving capacity, and lets the QoS layer shed what no longer fits.
  The unhandled arm keeps placing onto the faulty core and pays for it
  through the segment charge ratio — the no-mitigation baseline
  ``benchmarks/recovery.py`` compares against.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flexai.dqn import DQNParams
from repro.core.flexai.engine import _schedule_run_masked
from repro.core.platform_jax import (PlatformSpec, PlatformState,
                                     StepRecord, platform_init, stack_states)
from repro.core.tasks import TaskArrays, pad_route_batch
from repro.serve.qos import (COMPLETED, PREEMPTED, QoSConfig,
                             QoSPlacementEngine, RouteRequest, Wave)
from repro.train import checkpoint as ckpt_lib
from repro.train.fault_tolerance import (HeartbeatRecord, PreemptionGuard,
                                         StragglerDetector)

SNAPSHOT_VERSION = 2

# exec-time multiplier at/above which an injected fault counts as a dead
# core: its heartbeats stop and the detector's dead-host arm fires.  Below
# it the core is a *straggler* — it keeps heartbeating with an inflated
# step time and the detector's threshold arm flags it instead.
DEAD_CORE_FACTOR = 8.0


@dataclasses.dataclass(frozen=True)
class FaultInjection:
    """One accelerator failing (or degrading) at a virtual-clock instant.

    ``factor`` multiplies the core's exec-time/energy rows from
    ``at_time`` on (per-chiplet degradation; a large factor is a dead
    core).  ``handled=True`` lets the serving layer react — heartbeat
    silence, detector flag, alive-mask reroute, capacity-scaled shedding;
    ``handled=False`` degrades execution truth but the scheduler keeps
    placing onto the faulty core (the no-mitigation baseline).
    """
    at_time: float
    core: int
    factor: float = 50.0
    handled: bool = True


def injections_from_fault_events(events, svc_per_task: float, *,
                                 handled: bool = True
                                 ) -> list[FaultInjection]:
    """Bridge the in-scan fault schedule (``core.faults.FaultEvent``) to
    serving-time injections, so one seeded trace drives both the scan
    engines and the serving layer.

    A task-step index maps onto the virtual clock at which serving has
    charged that many lockstep task slots (``step * svc_per_task``).
    Trace factors are *capacity* (0.0 dead, (0, 1] fraction) while
    injection factors are cumulative exec-time *multipliers*, so each
    event emits the relative multiplier that moves the core from its
    previous capacity to the new one — a recovery event divides the
    earlier slowdown back out.  A dead-core event lands at the
    ``HEALTH_FLOOR`` multiplier (1000x), well past ``DEAD_CORE_FACTOR``,
    so it takes the heartbeat-silence arm exactly like a hand-written
    ``FaultInjection(factor=50)``."""
    from repro.core.platform_jax import HEALTH_FLOOR
    cur: dict[int, float] = {}
    out = []
    for ev in sorted(events, key=lambda e: (e.step, e.core)):
        prev = cur.get(ev.core, 1.0)
        new = max(float(ev.factor), HEALTH_FLOOR)
        cur[ev.core] = new
        out.append(FaultInjection(at_time=ev.step * svc_per_task,
                                  core=ev.core, factor=prev / new,
                                  handled=handled))
    return out


def degrade_spec(healthy: PlatformSpec,
                 core_factor: np.ndarray) -> PlatformSpec:
    """Execution-truth spec: per-core exec/energy rows scaled by the
    cumulative degradation factors (energy scales with busy time at fixed
    power).  The G-value scales stay at their healthy values — the metric
    normalization must not move when the platform degrades."""
    f = np.asarray(core_factor, np.float32)[:, None]
    return PlatformSpec(
        exec_time=jnp.asarray(np.asarray(healthy.exec_time) * f),
        energy=jnp.asarray(np.asarray(healthy.energy) * f),
        gvalue_e_scale=healthy.gvalue_e_scale,
        gvalue_t_scale=healthy.gvalue_t_scale)


_MASKED_FN_CACHE: dict = {}


def _masked_segment_fn(spec: PlatformSpec, backlog_scale: float, mesh=None):
    """Jitted vmapped alive-masked resume-able scan segment, optionally
    shard_mapped over ``mesh``'s route axis.  ``alive`` is a runtime
    argument, so one compiled closure serves every fault pattern; only a
    spec change (fault firing) recompiles."""
    key = (np.asarray(spec.exec_time).tobytes(),
           np.asarray(spec.energy).tobytes(), float(backlog_scale),
           None if mesh is None else (mesh.devices.shape, mesh.axis_names))
    if key not in _MASKED_FN_CACHE:
        run = _schedule_run_masked(spec, backlog_scale)

        def seg(params, tasks, state, alive):
            return run(params, tasks, state0=state, alive=alive)

        vm = jax.vmap(seg, in_axes=(None, 0, 0, None))
        if mesh is None:
            _MASKED_FN_CACHE[key] = jax.jit(vm)
        else:
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map
            ax = mesh.axis_names[0]
            _MASKED_FN_CACHE[key] = jax.jit(shard_map(
                vm, mesh=mesh, in_specs=(P(), P(ax), P(ax), P()),
                out_specs=(P(ax), P(ax))))
    return _MASKED_FN_CACHE[key]


def _py(v):
    return v.item() if isinstance(v, (np.floating, np.integer,
                                      np.bool_)) else v


def _sanitize(d: dict) -> dict:
    return {k: _py(v) for k, v in d.items()}


# ---------------------------------------------------------------------------
# snapshot pack / unpack
# ---------------------------------------------------------------------------

def pack_engine(eng: "DurableQoSEngine", inflight: Optional[Wave] = None,
                *, host: bool = True) -> tuple[list, dict]:
    """Flatten the full serving state into ``(arrays, meta)``: a list of
    host arrays (a valid pytree for ``AsyncCheckpointer``) plus a
    JSON-serializable meta dict whose ``[start, count]`` refs index into
    the array list.  ``inflight`` is the wave currently inside
    ``_run_wave`` (it lives in no queue).

    ``host=False`` keeps device leaves as raw references instead of
    transferring them — jax arrays are immutable, so a snapshot can
    capture them synchronously and let :func:`encode_snapshot` pay the
    device_get on the checkpoint writer thread, off the serving path."""
    arrays: list = []

    def ref(tree):
        leaves = jax.tree_util.tree_leaves(tree)
        start = len(arrays)
        arrays.extend(leaves)
        return [start, len(leaves)]

    def req_meta(r: RouteRequest) -> dict:
        m = {"uid": r.uid, "n_tasks": r.n_tasks, "arrival": _py(r.arrival),
             "deadline": _py(r.deadline), "bucket": r.bucket,
             "submit_order": r.submit_order, "waves_waited": r.waves_waited,
             "status": r.status, "finish": _py(r.finish),
             "slack": _py(r.slack), "tasks": ref(r.tasks)}
        if r.summary is not None:
            m["summary"] = {
                "scalars": _sanitize({k: v for k, v in r.summary.items()
                                      if not isinstance(v, np.ndarray)}),
                "arrays": {k: ref(v) for k, v in r.summary.items()
                           if isinstance(v, np.ndarray)}}
        return m

    def wave_meta(w: Wave) -> dict:
        recs = None
        if w.recs:
            # one ref per segment record, exactly as ``_run_wave`` holds
            # them — concatenating here would block the serving thread on
            # recent segments' device buffers
            recs = [ref(p) for p in w.recs]
        return {"requests": [req_meta(r) for r in w.requests],
                "batch": ref(w.batch), "state": ref(w.state),
                "bucket": w.bucket, "progress": w.progress,
                "preemptions": w.preemptions,
                "waves_waited": w.waves_waited, "recs": recs}

    meta = {
        "version": SNAPSHOT_VERSION,
        "now": eng.now,
        "order": eng._order,
        "dispatches": eng.dispatches,
        "preemption_count": eng.preemption_count,
        "segments_done": eng.segments_done,
        "svc": eng.svc, "base_svc": eng.base_svc,
        "svc_scale": eng.svc_scale,
        "snapshot_every": eng.snapshot_every,
        "snapshots_written": eng.snapshots_written,
        "cfg": dataclasses.asdict(eng.cfg),
        "wave_log": eng.wave_log,
        "dead_letter": [_sanitize(d) for d in eng.dead_letter],
        "pending": [req_meta(r) for r in eng.pending],
        "backlog": [req_meta(r) for r in eng.backlog],
        "preempted": [wave_meta(w) for w in eng.preempted],
        "completed": [req_meta(r) for r in eng.completed],
        "inflight": wave_meta(inflight) if inflight is not None else None,
        "alive": [bool(a) for a in eng.alive],
        "health": [float(h) for h in eng.health],
        "core_factor": [float(f) for f in eng.core_factor],
        "fired": [_sanitize(ev) for ev in eng.fired],
        "pending_faults": [dataclasses.asdict(f)
                           for f in eng.pending_faults],
        "detector_last_seen": {str(h): float(t) for h, t
                               in eng.detector._last_seen.items()},
        "detector_times": {str(h): [float(x) for x in ts] for h, ts
                           in eng.detector._times.items()},
        "final_states": {str(uid): ref(st)
                         for uid, st in eng.final_states.items()},
        "params": ref(eng.params),
        "exec_time": ref(np.asarray(eng.healthy_spec.exec_time)),
    }
    if host:
        # one batched transfer for every device leaf (np leaves pass
        # through untouched) — far cheaper than a device_get per leaf,
        # and this is serving-thread time, the snapshot-overhead budget
        arrays = [x if type(x) is np.ndarray else np.asarray(x)
                  for x in jax.device_get(arrays)]
    return arrays, meta


def _slice(arrays: list, ref_: list) -> list:
    start, n = ref_
    return arrays[start: start + n]


def encode_snapshot(arrays: list, meta: dict) -> list:
    """On-disk form of a packed snapshot: one byte blob holding every
    array back-to-back plus the JSON meta (dtype/shape per array rides in
    ``meta["leaves"]``).  Two files per snapshot instead of one per array
    — the write cost is what the <10% snapshot-overhead budget pays.
    Accepts raw device leaves from ``pack_engine(..., host=False)`` and
    materializes them here (i.e. on whichever thread runs the encode)."""
    return [_snapshot_blob(arrays), _snapshot_meta(arrays, meta)]


def _snapshot_meta(arrays: list, meta: dict) -> np.ndarray:
    """JSON half of the blob encoding.  Runs synchronously at snapshot
    time: serializing freezes any live engine containers the meta still
    references (``wave_log`` etc.) before serving mutates them further —
    dtype/shape reads never touch device buffers."""
    meta = dict(meta)
    dtype_names: dict = {}
    meta["leaves"] = [
        [dtype_names.setdefault(a.dtype, str(a.dtype)), list(a.shape)]
        for a in arrays]
    return np.frombuffer(json.dumps(meta).encode(), np.uint8)


def _snapshot_blob(arrays: list) -> np.ndarray:
    """Byte half of the blob encoding: every array back-to-back.  Safe to
    defer to the checkpoint writer thread — jax leaves are immutable and
    the engine never mutates packed host arrays in place."""
    return np.frombuffer(
        b"".join((x if type(x) is np.ndarray
                  else np.asarray(jax.device_get(x))).tobytes()
                 for x in arrays), np.uint8)


def decode_snapshot(leaves: list) -> tuple[list, dict]:
    """Inverse of :func:`encode_snapshot` -> ``(arrays, meta)``."""
    blob, meta_arr = leaves
    meta = json.loads(bytes(meta_arr).decode())
    buf, off, arrays = blob.tobytes(), 0, []
    for dt, shape in meta.pop("leaves"):
        n = int(np.prod(shape)) * np.dtype(dt).itemsize
        arrays.append(np.frombuffer(
            buf, np.dtype(dt), count=int(np.prod(shape)), offset=off
        ).reshape(shape).copy())
        off += n
    return arrays, meta


def unpack_into(eng: "DurableQoSEngine", arrays: list, meta: dict) -> None:
    """Inverse of :func:`pack_engine`: fill a freshly constructed engine
    with the snapshot's serving state."""
    def tree_from(cls, ref_, device=False):
        leaves = _slice(arrays, ref_)
        if device:
            leaves = [jnp.asarray(x) for x in leaves]
        return cls(*leaves)

    def req_from(m: dict) -> RouteRequest:
        r = RouteRequest(
            uid=m["uid"], tasks=tree_from(TaskArrays, m["tasks"]),
            n_tasks=m["n_tasks"], arrival=m["arrival"],
            deadline=m["deadline"], bucket=m["bucket"],
            submit_order=m["submit_order"],
            waves_waited=m["waves_waited"], status=m["status"],
            finish=m["finish"], slack=m["slack"])
        if m.get("summary") is not None:
            s = dict(m["summary"]["scalars"])
            for k, rr in m["summary"]["arrays"].items():
                s[k] = _slice(arrays, rr)[0]
            r.summary = s
        return r

    def wave_from(m: dict) -> Wave:
        w = Wave(requests=[req_from(x) for x in m["requests"]],
                 batch=tree_from(TaskArrays, m["batch"]),
                 state=tree_from(PlatformState, m["state"], device=True),
                 bucket=m["bucket"], progress=m["progress"],
                 preemptions=m["preemptions"],
                 waves_waited=m["waves_waited"])
        if m["recs"] is not None:
            w.recs = [tree_from(StepRecord, r) for r in m["recs"]]
        return w

    eng.now = meta["now"]
    eng._order = meta["order"]
    eng.dispatches = meta["dispatches"]
    eng.preemption_count = meta["preemption_count"]
    eng.segments_done = meta["segments_done"]
    eng.svc = meta["svc"]
    eng.base_svc = meta["base_svc"]
    eng.svc_scale = meta["svc_scale"]
    eng.svc_step = eng.svc / eng.cfg.stages
    eng.snapshots_written = meta["snapshots_written"]
    eng.wave_log = [list(w) for w in meta["wave_log"]]
    eng.dead_letter = [dict(d) for d in meta["dead_letter"]]
    eng.pending = [req_from(m) for m in meta["pending"]]
    eng.backlog = [req_from(m) for m in meta["backlog"]]
    eng.preempted = [wave_from(m) for m in meta["preempted"]]
    eng.completed = [req_from(m) for m in meta["completed"]]
    eng._inflight = (wave_from(meta["inflight"])
                     if meta["inflight"] is not None else None)
    eng.alive = np.asarray(meta["alive"], bool)
    eng.health = np.asarray(meta["health"], np.float64)
    eng.core_factor = np.asarray(meta["core_factor"], np.float64)
    eng.fired = [dict(ev) for ev in meta["fired"]]
    eng.pending_faults = [FaultInjection(**f)
                          for f in meta["pending_faults"]]
    eng.detector._last_seen = {int(h): t for h, t
                               in meta["detector_last_seen"].items()}
    eng.detector._times = {int(h): list(ts) for h, ts
                           in meta["detector_times"].items()}
    eng.final_states = {
        int(uid): tuple(_slice(arrays, rr))
        for uid, rr in meta["final_states"].items()}
    if (eng.core_factor != 1.0).any():
        eng.cur_spec = degrade_spec(eng.healthy_spec, eng.core_factor)
    eng._use_masked = (eng._use_masked or bool(eng.fired)
                       or bool(eng.pending_faults))


def serving_digest(eng: QoSPlacementEngine) -> dict:
    """Order-canonical arrays capturing the serving outcome — the
    bit-exactness contract of crash recovery.  Two engines that served
    the same submissions must agree on every entry: completed uids with
    finish/slack, per-request placements and final ``PlatformState``
    (durable engines), shed uids, the wave log, and the virtual clock."""
    comp = sorted(eng.completed, key=lambda r: r.uid)
    flat_log = []
    for w in eng.wave_log:
        flat_log.extend(w)
        flat_log.append(-1)
    out = {
        "completed_uids": np.asarray([r.uid for r in comp], np.int64),
        "finish": np.asarray([r.finish for r in comp], np.float64),
        "slack": np.asarray([r.slack for r in comp], np.float64),
        "shed_uids": np.sort(np.asarray(
            [d["uid"] for d in eng.dead_letter], np.int64)),
        "wave_log": np.asarray(flat_log, np.int64),
        "virtual_time": np.asarray(eng.now, np.float64),
    }
    for r in comp:
        out[f"placements_{r.uid}"] = np.asarray(
            r.summary["placements"], np.int32)
    for uid, st in sorted(getattr(eng, "final_states", {}).items()):
        for fname, a in zip(PlatformState._fields, st):
            out[f"state_{uid}_{fname}"] = np.asarray(a)
    return out


def digests_equal(a: dict, b: dict) -> bool:
    if set(a) != set(b):
        return False
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


# ---------------------------------------------------------------------------
# the durable engine
# ---------------------------------------------------------------------------

class DurableQoSEngine(QoSPlacementEngine):
    """``QoSPlacementEngine`` with snapshots, crash recovery, elastic
    mesh resume, and fault injection with graceful degradation.

    The base wave loop is untouched; durability rides on the four seams
    (``_dispatch_segment`` / ``_charge_segment`` / ``_after_segment`` /
    ``_on_complete``).  With no snapshot dir, no faults and no mesh the
    engine behaves exactly like the base class.
    """

    def __init__(self, platform, params, cfg: QoSConfig = QoSConfig(), *,
                 backlog_scale: float = 1.0,
                 executor: "Callable | str | None" = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0,       # segments; 0 = off
                 faults: Optional[list] = None,
                 mesh=None,
                 guard: Optional[PreemptionGuard] = None,
                 dead_after_segments: int = 4,
                 trace: bool = False,
                 segment_sleep: float = 0.0,
                 keep: int = 3):
        if cfg.stages > 1:
            raise ValueError(
                "durability does not support pipeline waves (stages > 1): "
                "snapshots and fault-masked executors cover the lockstep "
                "(state)-only checkpoint, not (state, ring)")
        if cfg.continuous:
            raise ValueError(
                "durability does not support continuous batching yet: the "
                "snapshot format packs whole-wave checkpoints, not per-lane "
                "cursors (ROADMAP follow-up)")
        if cfg.measured_svc:
            raise ValueError(
                "durability requires the virtual clock: measured service "
                "times would break bit-exact crash replay")
        super().__init__(platform, params, cfg,
                         backlog_scale=backlog_scale, executor=executor)
        self._stub = executor is not None
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.saver = (ckpt_lib.AsyncCheckpointer(snapshot_dir, keep=keep)
                      if snapshot_dir else None)
        self.mesh = mesh
        self.guard = guard
        self.trace = trace
        self.segment_sleep = segment_sleep
        self.interrupted = False
        self.healthy_spec = self.spec
        self.cur_spec = self.spec
        n = self.spec.n
        self.alive = np.ones(n, bool)          # scheduler's belief
        self.core_factor = np.ones(n, np.float64)  # execution truth
        self.pending_faults = sorted(faults or [], key=lambda f: f.at_time)
        self.fired: list[dict] = []
        # base_svc / svc_scale / health live on the base engine now
        # (the set_health admission seam); nothing extra to init here
        self.segments_done = 0
        self.snapshots_written = 0
        self.snapshot_time_s = 0.0  # sync time serving loses to pack/save
        self._inflight: Optional[Wave] = None
        self._use_masked = bool(self.pending_faults) or mesh is not None
        # heartbeat detection runs on the serving virtual clock, so the
        # whole fault story is deterministic and replayable
        self.detector = StragglerDetector(
            n, dead_after_s=dead_after_segments * cfg.chunk * self.svc,
            clock=lambda: self.now)
        self.final_states: dict[int, tuple] = {}

    # ---- fault machinery ------------------------------------------------

    def _fire_due_faults(self) -> None:
        while (self.pending_faults
               and self.pending_faults[0].at_time <= self.now):
            f = self.pending_faults.pop(0)
            self.core_factor[f.core] *= f.factor
            self.cur_spec = degrade_spec(self.healthy_spec,
                                         self.core_factor)
            self.fired.append({
                "at_time": f.at_time, "core": f.core, "factor": f.factor,
                "handled": f.handled, "fired_at": self.now,
                "detected_at": None})
            if self.trace:
                print(f"FAULT core={f.core} factor={f.factor} "
                      f"at={self.now:.4f} handled={f.handled}", flush=True)

    def _heartbeat_and_detect(self) -> None:
        seg_cost = self.cfg.chunk * self.svc
        for core in range(self.spec.n):
            f = self.core_factor[core]
            if f == 1.0:
                self.detector.record(HeartbeatRecord(
                    core, self.segments_done, seg_cost, self.now))
            elif f < DEAD_CORE_FACTOR:
                # a throttled core still makes progress: it heartbeats,
                # but its step time is inflated by the degradation — the
                # detector's threshold (straggler) arm fires instead of
                # waiting out the dead-host timeout
                self.detector.record(HeartbeatRecord(
                    core, self.segments_done, seg_cost * f, self.now))
            # else: a dead core goes silent -> dead_hosts() after timeout
        dead = set(self.detector.dead_hosts())
        slow = set(self.detector.stragglers())
        for ev in self.fired:
            if ev["detected_at"] is not None:
                continue
            core = ev["core"]
            if core in dead:
                ev["detected_at"] = self.now
                if self.trace:
                    print(f"DETECTED core={core} at={self.now:.4f}",
                          flush=True)
                if ev["handled"]:
                    self._mitigate(core)
            elif core in slow and 1.0 < self.core_factor[core]:
                ev["detected_at"] = self.now
                if self.trace:
                    print(f"STRAGGLER core={core} at={self.now:.4f}",
                          flush=True)
                if ev["handled"]:
                    self._mitigate_degraded(core, self.core_factor[core])

    def _mitigate(self, core: int) -> None:
        """Dead-core mitigation: drop the core from the placement argmax
        and shrink admission capacity through the shared ``set_health``
        seam — shedding then naturally drops what no longer fits."""
        self.alive[core] = False
        h = np.array(self.health, np.float64)
        h[core] = 0.0
        self.set_health(h)
        if self.trace:
            print(f"MITIGATE core={core} svc_scale={self.svc_scale:.4f}",
                  flush=True)

    def _mitigate_degraded(self, core: int, factor: float) -> None:
        """Straggler mitigation: the core stays in the placement argmax
        (it still makes progress) but admission sees its shrunken
        capacity, so the stretched service cost sheds marginal routes
        instead of letting the slow core turn them into deadline misses."""
        h = np.array(self.health, np.float64)
        h[core] = min(h[core], 1.0 / max(float(factor), 1.0))
        self.set_health(h)
        if self.trace:
            print(f"MITIGATE-DEGRADED core={core} health={h[core]:.3f} "
                  f"svc_scale={self.svc_scale:.4f}", flush=True)

    # ---- durability seams ----------------------------------------------

    def _dispatch_segment(self, wave: Wave, seg: TaskArrays):
        self._fire_due_faults()
        if self._stub or not self._use_masked:
            return super()._dispatch_segment(wave, seg)
        alive = jnp.asarray(self.alive)
        fn = _masked_segment_fn(self.cur_spec, self.backlog_scale,
                                mesh=self.mesh)
        if self.mesh is not None:
            pad = (-self.cfg.slots) % self.mesh.size
            if pad:
                seg = pad_route_batch(seg, self.mesh.size)
                state = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate(
                        [jnp.asarray(a), jnp.asarray(b)]),
                    wave.state,
                    stack_states([platform_init(self.spec.n)] * pad))
                st, recs = fn(self.params, seg, state, alive)
                trim = lambda a: a[: self.cfg.slots]  # noqa: E731
                return (jax.tree_util.tree_map(trim, st),
                        jax.tree_util.tree_map(trim, recs))
        return fn(self.params, seg, wave.state, alive)

    def _charge_segment(self, wave: Wave, recs) -> None:
        cost = self.cfg.chunk * self.svc
        if self.saver is not None and not self._stub and wave.recs:
            # normalize this segment's transitions to host eagerly: wave
            # completion pays this transfer anyway, and paying it here —
            # one segment at a time — means a snapshot packs plain numpy
            # instead of blocking on a backlog of device recs
            recs = jax.device_get(recs)
            wave.recs[-1] = recs
        if self.fired and not self._stub:
            # honest lockstep cost: accelerator-seconds actually consumed
            # over what the healthy platform would have spent on the same
            # placements — work landing on a degraded core slows its
            # whole lockstep wave by the degradation factor
            r = jax.device_get(recs)
            v = np.asarray(r.valid, bool)
            if v.any():
                act = np.asarray(r.action)[v]
                ex = np.asarray(r.exec_time, np.float64)[v]
                healthy = (ex / self.core_factor[act]).sum()
                if healthy > 0.0:
                    cost *= max(float(ex.sum() / healthy), 1.0)
        self.now += cost

    def _after_segment(self, wave: Wave) -> None:
        self.segments_done += 1
        self._heartbeat_and_detect()
        if self.segment_sleep:
            time.sleep(self.segment_sleep)
        if self.trace:
            print(f"SEG {self.segments_done} now={self.now:.4f} "
                  f"progress={wave.progress}/{wave.bucket}", flush=True)
        due = (self.saver is not None and self.snapshot_every > 0
               and self.segments_done % self.snapshot_every == 0)
        stop = self.guard is not None and self.guard.preempted
        if due or stop:
            self.snapshot(inflight=wave)
        if stop:
            if self.saver is not None:
                self.saver.wait()
            self.interrupted = True
            self._halt = True

    def _on_complete(self, req: RouteRequest, lane_final,
                     lane_recs) -> None:
        self.final_states[req.uid] = tuple(
            np.asarray(x) for x in lane_final)

    # ---- snapshot / restore --------------------------------------------

    def snapshot(self, inflight: Optional[Wave] = None) -> None:
        if self.saver is None:
            return
        # the step is a dedicated monotonic counter (not segments_done):
        # it is packed into the snapshot, so a restored engine keeps
        # counting where the crashed one stopped and its snapshots never
        # collide with — or sort below — the survivors on disk
        t0 = time.perf_counter()
        self.snapshots_written += 1
        # pack + encode synchronously: a consistent cut of the serving
        # state (the meta freezes live containers like wave_log, the
        # blob copies every array) — only the disk write is async.
        # Deferring the device transfers to the writer thread measures
        # worse, not better: hundreds of background device_gets contend
        # with serving's own dispatches on the GIL and the jax runtime.
        arrays, meta = pack_engine(self, inflight=inflight)
        self.saver.save(self.snapshots_written,
                        encode_snapshot(arrays, meta))
        self.snapshot_time_s += time.perf_counter() - t0
        if self.trace:
            print(f"SNAPSHOT step={self.segments_done} "
                  f"now={self.now:.4f}", flush=True)

    @classmethod
    def from_packed(cls, arrays: list, meta: dict, platform, *,
                    backlog_scale: float = 1.0, executor=None, mesh=None,
                    guard=None, snapshot_dir=None, snapshot_every=None,
                    trace=False, segment_sleep=0.0) -> "DurableQoSEngine":
        params = DQNParams(*[jnp.asarray(x)
                             for x in _slice(arrays, meta["params"])])
        eng = cls(platform, params, QoSConfig(**meta["cfg"]),
                  backlog_scale=backlog_scale, executor=executor,
                  snapshot_dir=snapshot_dir,
                  snapshot_every=(meta["snapshot_every"]
                                  if snapshot_every is None
                                  else snapshot_every),
                  mesh=mesh, guard=guard, trace=trace,
                  segment_sleep=segment_sleep)
        snap_et = _slice(arrays, meta["exec_time"])[0]
        if not np.array_equal(np.asarray(eng.healthy_spec.exec_time),
                              snap_et):
            raise ValueError(
                "snapshot was taken on a different platform "
                "(exec-time tables disagree)")
        unpack_into(eng, arrays, meta)
        return eng

    @classmethod
    def restore(cls, snapshot_dir: str, platform,
                **kwargs) -> "DurableQoSEngine":
        """Rebuild the engine from the latest snapshot in
        ``snapshot_dir`` (or an explicit ``path=``).  The snapshot is
        self-describing; ``platform`` only provides the spec tables,
        which are integrity-checked against the snapshot."""
        path = kwargs.pop("path", None) \
            or ckpt_lib.latest_checkpoint(snapshot_dir)
        if path is None:
            raise FileNotFoundError(
                f"no snapshot under {snapshot_dir!r}")
        _, leaves, _ = ckpt_lib.load_checkpoint_arrays(path)
        arrays, meta = decode_snapshot(leaves)
        if meta["version"] != SNAPSHOT_VERSION:
            raise ValueError(f"snapshot version {meta['version']} != "
                             f"{SNAPSHOT_VERSION}")
        kwargs.setdefault("snapshot_dir", snapshot_dir)
        return cls.from_packed(arrays, meta, platform, **kwargs)

    # ---- serving loop --------------------------------------------------

    def _resume_inflight(self) -> None:
        """Continue the wave that was mid-``_run_wave`` at snapshot time.
        The snapshot is taken inside ``_after_segment``, i.e. *before*
        the loop's preemption check — so replay re-applies that check on
        the restored state (a pure function of clock + queues, hence the
        same verdict the uninterrupted run reached) before serving on."""
        w, self._inflight = self._inflight, None
        if w.progress < w.bucket and self._should_preempt(w):
            w.preemptions += 1
            self.preemption_count += 1
            for r in w.requests:
                r.status = PREEMPTED
            self.preempted.append(w)
            return
        self._run_wave(w)

    def run_until_done(self, max_waves: int = 100_000) -> None:
        if self._inflight is not None:
            self._resume_inflight()
        super().run_until_done(max_waves)

    def serve_waves(self, k: int) -> int:
        """Serve up to ``k`` admission rounds — the crash-point control
        of the recovery tests and benchmark.  Returns rounds served."""
        served = 0
        if self._inflight is not None and k > 0:
            self._resume_inflight()
            served += 1
        while served < k and not self._halt:
            wave = self._next_wave()
            if wave is None:
                break
            self._run_wave(wave)
            served += 1
        return served

    def stats(self) -> dict:
        s = super().stats()
        s.update({
            "snapshots_written": self.snapshots_written,
            "snapshot_time_s": self.snapshot_time_s,
            "segments_done": self.segments_done,
            "faults_fired": len(self.fired),
            "cores_masked": int((~self.alive).sum()),
            "svc_scale": self.svc_scale,
            "interrupted": self.interrupted,
        })
        return s
