"""Open-loop load generation for the serving layer (ISSUE 10).

The paper's "basically 100% within period" claim is a *sustained-load*
guarantee, and production serving is provisioned against tail latency
under continuous arrival streams — not against the makespan of draining
a short trace.  This module generates those streams: seeded arrival
processes (Poisson for memoryless traffic, Gamma-renewal for bursty
traffic with a tunable squared coefficient of variation) over request
bodies drawn from the scenario families of ``core.scenarios``, so the
load the QoS engine faces is the same variability mix the fleet
benchmarks train and evaluate on.

Open-loop means arrivals do not wait for completions: the generator
fixes the full arrival schedule up front from ``offered_load`` (arrival
rate as a multiple of the service rate), and the engine falls behind,
sheds, or keeps up on its own.  Everything is deterministic in
``cfg.seed`` — the serving benchmark gates on these traces.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import numpy as np

from repro.core.scenarios import FAMILIES, scenario_batch
from repro.core.tasks import TaskArrays

# the serving families: "fault" rows are identical task-wise to "clean"
# (their payload is the health trace, which serving injects separately)
SERVE_FAMILIES = ("clean", "sensor_dropout", "weather", "burst")


@dataclasses.dataclass(frozen=True)
class LoadGenConfig:
    """Knobs of one open-loop trace."""
    process: str = "poisson"       # "poisson" | "gamma"
    n_requests: int = 32
    offered_load: float = 1.0      # mean arrival rate / service rate
    burstiness: float = 4.0        # gamma: squared CV of arrival gaps
                                   # (1.0 degenerates to poisson)
    families: tuple = SERVE_FAMILIES
    seed: int = 0

    def __post_init__(self):
        if self.process not in ("poisson", "gamma"):
            raise ValueError(f"unknown arrival process {self.process!r}")
        if self.offered_load <= 0.0:
            raise ValueError("offered_load must be > 0")
        if self.burstiness <= 0.0:
            raise ValueError("burstiness must be > 0")
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        unknown = set(self.families) - set(FAMILIES)
        if unknown:
            raise ValueError(f"unknown scenario families {sorted(unknown)}")


class LoadRequest(NamedTuple):
    """One generated request: the route body, its absolute arrival time,
    and the scenario family it was drawn from."""
    tasks: TaskArrays
    arrival: float
    family: str


def arrival_times(cfg: LoadGenConfig, mean_gap: float) -> np.ndarray:
    """[n_requests] absolute arrival instants, strictly deterministic in
    ``cfg.seed``.  Mean inter-arrival gap is ``mean_gap`` for both
    processes; the gamma process has gap CV^2 = ``burstiness`` (shape
    k = 1/burstiness), i.e. long quiet stretches broken by clumps."""
    rng = np.random.default_rng(cfg.seed)
    if cfg.process == "poisson":
        gaps = rng.exponential(mean_gap, cfg.n_requests)
    else:
        k = 1.0 / cfg.burstiness
        gaps = rng.gamma(k, mean_gap * cfg.burstiness, cfg.n_requests)
    return np.cumsum(gaps)


def generate(base: TaskArrays, n_cores: int, cfg: LoadGenConfig,
             mean_service: float) -> list[LoadRequest]:
    """Build the open-loop trace: ``n_requests`` scenario-family routes
    with arrival instants at ``offered_load`` times the service rate.

    ``mean_service`` is the engine's mean per-request service time (the
    caller knows its clock — virtual or measured); the mean arrival gap
    is ``mean_service / offered_load``, so load 2.0 offers twice what
    the pool can serve and load 0.5 half of it.
    """
    per_family = -(-cfg.n_requests // len(cfg.families))  # ceil
    batch = scenario_batch(base, n_cores, cfg.seed,
                           n_per_family=per_family,
                           families=tuple(cfg.families))
    rows = jax.tree_util.tree_map(np.asarray, batch.tasks)
    order = np.random.default_rng(cfg.seed + 1).permutation(
        int(batch.family.shape[0]))[: cfg.n_requests]
    arrivals = arrival_times(cfg, mean_service / cfg.offered_load)
    out = []
    for t, row_idx in zip(arrivals, order):
        tasks = jax.tree_util.tree_map(lambda a: a[row_idx], rows)
        out.append(LoadRequest(tasks=tasks, arrival=float(t),
                               family=FAMILIES[int(batch.family[row_idx])]))
    return out


def submit_trace(engine, trace: "list[LoadRequest]") -> list:
    """Feed a generated trace into a ``QoSPlacementEngine``; returns the
    engine's ``RouteRequest`` handles aligned with the trace."""
    return [engine.submit(r.tasks, arrival=r.arrival) for r in trace]
