"""Serving: jit-able decode/prefill steps + a batched continuous-batching
engine.

``make_serve_step`` is what the decode-shape dry-run cells lower: one new
token against a KV cache of the cell's sequence length, cache donated so the
update is in-place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI


def make_serve_step(api: ModelAPI, greedy: bool = True,
                    temperature: float = 1.0, top_k: int = 0):
    """(params, cache, token [B,1], pos scalar) -> (next_token, logits, cache).

    With ``greedy=False`` the step takes a trailing PRNG ``key`` argument
    and samples through :func:`sample_token` (temperature / top-k).
    """

    def serve_step(params, cache, token, pos):
        logits, new_cache = api.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, new_cache

    def sampled_step(params, cache, token, pos, key):
        logits, new_cache = api.decode_step(params, cache, token, pos)
        nxt = sample_token(logits[:, -1, :], key, temperature=temperature,
                           top_k=top_k)
        return nxt[:, None], logits, new_cache

    return serve_step if greedy else sampled_step


def make_prefill_step(api: ModelAPI):
    def prefill_step(params, batch):
        return api.prefill(params, batch)

    return prefill_step


def sample_token(logits: jax.Array, key, temperature: float = 1.0,
                 top_k: int = 0):
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # deadline-aware QoS (engine step units; see serve.qos for the
    # placement-side analogue).  ``deadline`` is absolute; None at submit
    # means "derive from the token budget" (tasks.token_deadline_budget).
    deadline: "float | None" = None
    submit_time: float = 0.0
    finish_time: "float | None" = None
    waves_waited: int = 0
    # decode tokens the admission pricing promised (wave-padding-aware
    # cap applied); delivery below this is a pricing bug, not truncation
    priced_tokens: "int | None" = None

    @property
    def slack(self) -> "float | None":
        if self.deadline is None or self.finish_time is None:
            return None
        return self.deadline - self.finish_time

    @property
    def submit_order(self) -> int:
        # QoSPolicy sort-key protocol (ties inside one wave break on uid)
        return self.uid


class FlexAIPlacementService:
    """Multi-vehicle placement serving on the device-resident scheduler.

    Each request is one vehicle's task queue (a route, or a camera-burst
    window of it).  Queues are precompiled to ``TaskArrays``, right-padded
    to power-of-two length buckets, stacked per bucket, and dispatched
    through the vmapped greedy ``schedule_scan`` — one device call per
    (bucket, batch-size) shape, compiled executables cached across calls.
    This is the serving analogue of the engine's training batcher: the
    per-frame Python loop never runs on the request path.
    """

    def __init__(self, platform, params, *, backlog_scale: float = 1.0,
                 min_bucket: int = 64, mesh=None,
                 tight_slack_s: "float | None" = None):
        from repro.core.flexai.engine import (make_schedule_fn,
                                              make_sharded_schedule_fn)
        from repro.core.platform_jax import spec_from_platform
        self.spec = spec_from_platform(platform)
        self.params = params
        self.backlog_scale = backlog_scale
        self.min_bucket = min_bucket
        self.tight_slack_s = tight_slack_s
        self.shards = 1 if mesh is None else int(mesh.size)
        if mesh is None:
            self._batched_fn = make_schedule_fn(self.spec, backlog_scale,
                                                batched=True)
        else:
            # multi-device serving: each bucket's lane batch is padded to
            # a multiple of the mesh size and split across devices
            self._batched_fn = make_sharded_schedule_fn(
                self.spec, mesh, backlog_scale, axis=mesh.axis_names[0])
        # tight-deadline lane: the single-route fused scan, dispatched
        # immediately instead of waiting to co-batch with bucket peers
        self._fused_fn = make_schedule_fn(self.spec, backlog_scale)
        self.dispatches = 0
        self.fused_dispatches = 0

    def _bucket(self, n: int) -> int:
        from repro.serve.qos import power_of_two_bucket
        return power_of_two_bucket(n, self.min_bucket)

    def place(self, queues: list, deadlines: "list | None" = None,
              now: float = 0.0) -> list[dict]:
        """Schedule every queue; returns one summary dict per queue with
        ``placements`` trimmed to the queue's real length.

        ``deadlines`` (absolute, same clock as ``now``) is the QoS seam:
        when ``tight_slack_s`` is set, any request whose remaining slack
        ``deadline - now`` is below it skips bucket co-batching and goes
        straight through the single-route fused scan path — it pays the
        solo dispatch instead of waiting for peers to amortize one.
        Summaries carry ``path`` ("fused" or "batched") either way.
        """
        from repro.core.platform_jax import summarize
        from repro.core.tasks import (TaskArrays, pad_route_batch,
                                      pad_task_arrays, stack_task_arrays,
                                      tasks_to_arrays)
        arrays = [q if isinstance(q, TaskArrays) else tasks_to_arrays(q)
                  for q in queues]
        results: list = [None] * len(arrays)
        tight: set = set()
        if deadlines is not None and self.tight_slack_s is not None:
            tight = {i for i, d in enumerate(deadlines)
                     if d is not None and d - now < self.tight_slack_s}
        for i in sorted(tight):
            ta = pad_task_arrays(arrays[i], self._bucket(arrays[i].num_tasks))
            final, recs = self._fused_fn(self.params, ta)
            final, recs = jax.device_get((final, recs))
            self.dispatches += 1
            self.fused_dispatches += 1
            summ = summarize(self.spec, final, recs)
            summ["placements"] = recs.action[: arrays[i].num_tasks]
            summ["bucket"] = ta.num_tasks
            summ["path"] = "fused"
            results[i] = summ
        by_bucket: dict = {}
        for i, ta in enumerate(arrays):
            if i in tight:
                continue
            by_bucket.setdefault(self._bucket(ta.num_tasks), []).append(i)
        for bucket, idxs in sorted(by_bucket.items()):
            batch = stack_task_arrays(
                [pad_task_arrays(arrays[i], bucket) for i in idxs])
            if self.shards > 1:
                batch = pad_route_batch(batch, self.shards)
            out = self._batched_fn(self.params, batch)
            # one device->host transfer per bucket, then NumPy slicing —
            # per-lane device gathers would issue hundreds of tiny
            # blocking transfers on the serving hot path
            finals, recs = jax.device_get(out)
            self.dispatches += 1
            for lane, i in enumerate(idxs):
                take = jax.tree_util.tree_map(lambda a, l=lane: a[l],
                                              (finals, recs))
                summ = summarize(self.spec, take[0], take[1])
                summ["placements"] = take[1].action[: arrays[i].num_tasks]
                summ["bucket"] = bucket
                summ["path"] = "batched"
                results[i] = summ
        return results


class ServeEngine:
    """Wave-based batched serving with a static decode shape.

    Requests are admitted in waves of ``slots``: a wave's prompts are padded
    to a common length, batch-prefilled once, then decoded in lockstep until
    every request in the wave finishes (per-request EOS/max handled with a
    done mask).  The decode step keeps a single static (batch, cache) shape —
    the property the compiled/sharded step needs on real hardware.  When a
    wave drains, the next wave is admitted (continuous batching at wave
    granularity).

    Admission is length-aware rather than strict FIFO: a wave's cost is its
    *longest* member (lockstep decode + common prompt padding), so queued
    requests are bucketed by total length (prompt + budget, power-of-two)
    and each wave greedily packs the bucket of the oldest queued request —
    FIFO across waves at head granularity (no starvation: the oldest
    request is always admitted) and FIFO within a bucket, but a short
    request queued behind a long one rides a short wave instead of paying
    the long wave's decode steps.  ``wave_log`` records the admitted uid
    groups for observability/tests.

    ``qos="edf"`` makes admission deadline-aware: the head is the earliest
    *effective* deadline (deadline minus ``aging_credit`` per passed-over
    wave), buckets drain in effective-deadline order, and requests whose
    decode budget can no longer fit before their deadline are shed to
    ``dead_letter`` instead of served late.  Deadlines default to the
    per-token budget of ``tasks.token_deadline_budget`` on the engine's
    virtual step clock (1.0 per decode step, so QoS decisions are
    deterministic).  ``serve.qos`` holds the placement-side analogue with
    preemption; ``qos_stats()`` reports miss rate and slack percentiles.
    """

    def __init__(self, api: ModelAPI, params, *, slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0,
                 pad_token: int = 0, qos: str = "fifo",
                 deadline_scale: float = 1.0, aging_credit: float = 4.0,
                 shed: bool = True):
        from repro.serve.policy import QoSPolicy
        if qos not in ("fifo", "edf"):
            raise ValueError(f"unknown qos policy {qos!r}")
        self._qpolicy: "QoSPolicy | None" = None
        self.api = api
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.pad_token = pad_token
        self.qos = qos
        self.deadline_scale = deadline_scale
        self.aging_credit = aging_credit
        self.shed = shed
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.dead_letter: list[Request] = []
        self._decode = jax.jit(api.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(api.prefill)
        self.steps_executed = 0
        self.clock = 0.0          # virtual step clock (1.0 per decode step)
        self.wave_log: list[list[int]] = []

    @property
    def qpolicy(self):
        """The shared EDF/aging/shed formula object (serve.policy) —
        rebuilt lazily so the ``qos`` / ``aging_credit`` / ``shed``
        attributes stay live knobs (tests flip them post-construction)."""
        from repro.serve.policy import QoSPolicy
        p = self._qpolicy
        if (p is None or p.policy != self.qos
                or p.aging_credit != self.aging_credit
                or p.shed != self.shed):
            p = QoSPolicy(policy=self.qos, aging_credit=self.aging_credit,
                          shed=self.shed)
            self._qpolicy = p
        return p

    def _token_cap(self, req: Request) -> int:
        """Decode tokens ``max_seq`` can guarantee this request *in a
        wave*: co-batched peers share the request's power-of-two length
        bucket, so the wave's common prompt padding can push ``pos`` up
        to ``bucket - 1`` before the first decode step.  Capping by the
        request's own prompt length (the old formula) over-promised a
        short prompt co-batched with a long one — it was priced and
        shed-tested for tokens the lockstep decode loop could never
        reach (ISSUE 10 bugfix).  Any bucket peer keeps >= 1 token of
        budget, so the wave's prompt length is at most ``bucket - 1``
        and this bound is tight."""
        return 1 + max(0, self.max_seq - self._length_bucket(req))

    def submit(self, req: Request) -> None:
        from repro.core.tasks import token_deadline_budget
        req.submit_time = self.clock
        # price the deadline for the tokens a wave can actually deliver,
        # so a truncated request cannot buy easy slack from a budget it
        # will never consume
        req.priced_tokens = min(req.max_new_tokens, self._token_cap(req))
        if req.deadline is None:
            req.deadline = self.clock + token_deadline_budget(
                len(req.prompt), req.priced_tokens, self.deadline_scale)
        self.queue.append(req)

    def _merge_cache(self, prefill_cache):
        """Embed the prefill-length cache into a max_seq-length zero cache.

        KV entries get written at sequence offset 0 (positions 0..plen-1);
        SSM states match shape exactly and pass through.
        """
        from repro.sharding import unbox
        zero = unbox(self.api.init_cache(self.slots, self.max_seq))

        def merge(z, p):
            if z.shape == p.shape:
                return p.astype(z.dtype)
            # KV entries: [..., S, ...] differ only in the seq dim (axis 2)
            if (z.ndim == p.ndim and z.shape[:2] == p.shape[:2]
                    and z.shape[3:] == p.shape[3:]
                    and p.shape[2] <= z.shape[2]):
                return jax.lax.dynamic_update_slice(
                    z, p.astype(z.dtype), (0,) * z.ndim)
            raise ValueError(f"cache merge mismatch: {z.shape} vs {p.shape}")

        return jax.tree_util.tree_map(merge, zero, prefill_cache)

    @staticmethod
    def _length_bucket(req: Request) -> int:
        """Power-of-two bucket of the request's total token budget — the
        quantity that sets its wave's lockstep cost."""
        from repro.serve.qos import power_of_two_bucket
        return power_of_two_bucket(
            max(len(req.prompt) + req.max_new_tokens, 1), 1)

    def _eff_deadline(self, req: Request) -> float:
        """EDF comparison key (shared object: serve.policy.QoSPolicy —
        the placement engine and this token engine must never drift)."""
        return self.qpolicy.eff_deadline(req.deadline, req.waves_waited)

    def _shed_overdue(self) -> None:
        """Timeout shedding: a queued request that cannot finish its decode
        budget before its deadline moves to the dead-letter log."""
        keep = []
        for req in self.queue:
            # finish lands at clock + priced ticks (the prefill+first-token
            # tick covers token 1, then priced - 1 decode ticks) — the
            # wave-bucket-aware cap applied at submit
            need = float(max(min(req.max_new_tokens, self._token_cap(req)),
                             1))
            if self.qpolicy.should_shed(self.clock, need, req.deadline):
                req.finish_time = self.clock
                self.dead_letter.append(req)
            else:
                keep.append(req)
        self.queue = keep

    def _next_wave(self) -> list[Request]:
        # greedy bin-pack: the head request picks the wave's length bucket,
        # then the wave fills from that bucket (slots not fillable from the
        # bucket stay padded — mixing buckets would stretch every short
        # member to the longest).  Under "fifo" the head is the oldest
        # request and the bucket drains in submit order (the pre-QoS
        # engine); under "edf" the head is the earliest effective deadline
        # and the bucket drains in effective-deadline order, with every
        # passed-over request earning one wave of aging credit.
        if self.qos == "edf":
            if self.shed:
                self._shed_overdue()
            if not self.queue:
                return []
            head = min(self.queue, key=self.qpolicy.request_key)
            bucket = self._length_bucket(head)
            peers = sorted(
                (r for r in self.queue if self._length_bucket(r) == bucket),
                key=self.qpolicy.request_key)
            wave = peers[: self.slots]
            taken = {id(r) for r in wave}
            self.queue = [r for r in self.queue if id(r) not in taken]
            self.qpolicy.age(self.queue)
        else:
            bucket = self._length_bucket(self.queue[0])
            wave, rest = [], []
            for req in self.queue:
                if (len(wave) < self.slots
                        and self._length_bucket(req) == bucket):
                    wave.append(req)
                else:
                    rest.append(req)
            self.queue = rest
        self.wave_log.append([r.uid for r in wave])
        while len(wave) < self.slots:  # pad the wave with dummy requests
            wave.append(Request(uid=-1, prompt=np.array([self.pad_token],
                                                        np.int32),
                                max_new_tokens=0, done=True))
        return wave

    def _run_wave(self, wave: list[Request]) -> None:
        plen = max(len(r.prompt) for r in wave)
        prompts = np.full((self.slots, plen), self.pad_token, np.int32)
        for i, r in enumerate(wave):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        if self.api.cfg.frontend is not None:
            t = max(1, self.api.cfg.num_frontend_tokens)
            batch["frontend_embeds"] = jnp.zeros(
                (self.slots, t, self.api.cfg.d_model), jnp.float32)
        logits, prefill_cache = self._prefill(self.params, batch)
        cache = self._merge_cache(prefill_cache)
        self.key, sub = jax.random.split(self.key)
        tok = np.asarray(sample_token(logits[:, -1, :], sub,
                                      self.temperature))[:, None]
        pos = plen
        self.clock += 1.0  # prefill + first sampled token
        max_new = max((r.max_new_tokens for r in wave), default=0)
        for i, r in enumerate(wave):
            if not r.done and r.max_new_tokens > 0:
                r.generated.append(int(tok[i, 0]))
            if not r.done and len(r.generated) >= r.max_new_tokens:
                r.done = True
                r.finish_time = self.clock
        for _ in range(max_new - 1):
            if pos >= self.max_seq - 1:
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tok), jnp.int32(pos))
            self.steps_executed += 1
            self.clock += 1.0
            self.key, sub = jax.random.split(self.key)
            tok = np.asarray(sample_token(logits[:, -1, :], sub,
                                          self.temperature))[:, None]
            pos += 1
            for i, r in enumerate(wave):
                if not r.done and len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(tok[i, 0]))
                if not r.done and len(r.generated) >= r.max_new_tokens:
                    r.done = True
                    r.finish_time = self.clock
        for r in wave:
            r.done = True
            if r.finish_time is None:
                r.finish_time = self.clock
            if r.uid >= 0:
                self.finished.append(r)

    def run_until_done(self, max_waves: int = 1000) -> None:
        for _ in range(max_waves):
            if not self.queue:
                return
            wave = self._next_wave()
            if not wave:      # queue fully shed at admission
                return
            self._run_wave(wave)

    def qos_stats(self) -> dict:
        """Deadline bookkeeping over everything served so far (resolved
        requests only — the shared ``QoSPolicy.miss_stats`` contract)."""
        ms = self.qpolicy.miss_stats([r.slack for r in self.finished],
                                     len(self.dead_letter))
        return {
            "policy": self.qos,
            "finished": len(self.finished),
            "queued": len(self.queue),
            "shed": ms["shed"],
            # requests cut short by max_seq got partial service; they are
            # reported separately rather than silently counted as met
            "truncated": sum(1 for r in self.finished
                             if len(r.generated) < r.max_new_tokens),
            # delivery below the priced budget would mean admission and
            # the lockstep decode loop disagree again — pinned at 0 by
            # the mixed-prompt regression test
            "short_changed": sum(
                1 for r in self.finished
                if r.priced_tokens is not None
                and len(r.generated) < min(r.priced_tokens,
                                           r.max_new_tokens)),
            "missed_deadline": ms["missed_deadline"],
            "miss_rate": ms["miss_rate"],
            "p50_slack": ms["p50_slack"],
            "p99_slack": ms["p99_slack"],
            "mean_turnaround": float(np.mean(
                [r.finish_time - r.submit_time for r in self.finished]))
            if self.finished else 0.0,
        }
