"""Serving: jit-able decode/prefill steps + a batched continuous-batching
engine.

``make_serve_step`` is what the decode-shape dry-run cells lower: one new
token against a KV cache of the cell's sequence length, cache donated so the
update is in-place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI


def make_serve_step(api: ModelAPI, greedy: bool = True,
                    temperature: float = 1.0, top_k: int = 0):
    """(params, cache, token [B,1], pos scalar) -> (next_token, logits, cache).

    With ``greedy=False`` the step takes a trailing PRNG ``key`` argument
    and samples through :func:`sample_token` (temperature / top-k).
    """

    def serve_step(params, cache, token, pos):
        logits, new_cache = api.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, new_cache

    def sampled_step(params, cache, token, pos, key):
        logits, new_cache = api.decode_step(params, cache, token, pos)
        nxt = sample_token(logits[:, -1, :], key, temperature=temperature,
                           top_k=top_k)
        return nxt[:, None], logits, new_cache

    return serve_step if greedy else sampled_step


def make_prefill_step(api: ModelAPI):
    def prefill_step(params, batch):
        return api.prefill(params, batch)

    return prefill_step


def sample_token(logits: jax.Array, key, temperature: float = 1.0,
                 top_k: int = 0):
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class FlexAIPlacementService:
    """Multi-vehicle placement serving on the device-resident scheduler.

    Each request is one vehicle's task queue (a route, or a camera-burst
    window of it).  Queues are precompiled to ``TaskArrays``, right-padded
    to power-of-two length buckets, stacked per bucket, and dispatched
    through the vmapped greedy ``schedule_scan`` — one device call per
    (bucket, batch-size) shape, compiled executables cached across calls.
    This is the serving analogue of the engine's training batcher: the
    per-frame Python loop never runs on the request path.
    """

    def __init__(self, platform, params, *, backlog_scale: float = 1.0,
                 min_bucket: int = 64, mesh=None):
        from repro.core.flexai.engine import (make_schedule_fn,
                                              make_sharded_schedule_fn)
        from repro.core.platform_jax import spec_from_platform
        self.spec = spec_from_platform(platform)
        self.params = params
        self.backlog_scale = backlog_scale
        self.min_bucket = min_bucket
        self.shards = 1 if mesh is None else int(mesh.size)
        if mesh is None:
            self._batched_fn = make_schedule_fn(self.spec, backlog_scale,
                                                batched=True)
        else:
            # multi-device serving: each bucket's lane batch is padded to
            # a multiple of the mesh size and split across devices
            self._batched_fn = make_sharded_schedule_fn(
                self.spec, mesh, backlog_scale, axis=mesh.axis_names[0])
        self.dispatches = 0

    def _bucket(self, n: int) -> int:
        b = self.min_bucket
        while b < n:
            b *= 2
        return b

    def place(self, queues: list) -> list[dict]:
        """Schedule every queue; returns one summary dict per queue with
        ``placements`` trimmed to the queue's real length."""
        from repro.core.platform_jax import summarize
        from repro.core.tasks import (TaskArrays, pad_route_batch,
                                      pad_task_arrays, stack_task_arrays,
                                      tasks_to_arrays)
        arrays = [q if isinstance(q, TaskArrays) else tasks_to_arrays(q)
                  for q in queues]
        by_bucket: dict = {}
        for i, ta in enumerate(arrays):
            by_bucket.setdefault(self._bucket(ta.num_tasks), []).append(i)
        results: list = [None] * len(arrays)
        for bucket, idxs in sorted(by_bucket.items()):
            batch = stack_task_arrays(
                [pad_task_arrays(arrays[i], bucket) for i in idxs])
            if self.shards > 1:
                batch = pad_route_batch(batch, self.shards)
            out = self._batched_fn(self.params, batch)
            # one device->host transfer per bucket, then NumPy slicing —
            # per-lane device gathers would issue hundreds of tiny
            # blocking transfers on the serving hot path
            finals, recs = jax.device_get(out)
            self.dispatches += 1
            for lane, i in enumerate(idxs):
                take = jax.tree_util.tree_map(lambda a, l=lane: a[l],
                                              (finals, recs))
                summ = summarize(self.spec, take[0], take[1])
                summ["placements"] = take[1].action[: arrays[i].num_tasks]
                summ["bucket"] = bucket
                results[i] = summ
        return results


class ServeEngine:
    """Wave-based batched serving with a static decode shape.

    Requests are admitted in waves of ``slots``: a wave's prompts are padded
    to a common length, batch-prefilled once, then decoded in lockstep until
    every request in the wave finishes (per-request EOS/max handled with a
    done mask).  The decode step keeps a single static (batch, cache) shape —
    the property the compiled/sharded step needs on real hardware.  When a
    wave drains, the next wave is admitted (continuous batching at wave
    granularity).

    Admission is length-aware rather than strict FIFO: a wave's cost is its
    *longest* member (lockstep decode + common prompt padding), so queued
    requests are bucketed by total length (prompt + budget, power-of-two)
    and each wave greedily packs the bucket of the oldest queued request —
    FIFO across waves at head granularity (no starvation: the oldest
    request is always admitted) and FIFO within a bucket, but a short
    request queued behind a long one rides a short wave instead of paying
    the long wave's decode steps.  ``wave_log`` records the admitted uid
    groups for observability/tests.
    """

    def __init__(self, api: ModelAPI, params, *, slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0,
                 pad_token: int = 0):
        self.api = api
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.pad_token = pad_token
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._decode = jax.jit(api.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(api.prefill)
        self.steps_executed = 0
        self.wave_log: list[list[int]] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _merge_cache(self, prefill_cache):
        """Embed the prefill-length cache into a max_seq-length zero cache.

        KV entries get written at sequence offset 0 (positions 0..plen-1);
        SSM states match shape exactly and pass through.
        """
        from repro.sharding import unbox
        zero = unbox(self.api.init_cache(self.slots, self.max_seq))

        def merge(z, p):
            if z.shape == p.shape:
                return p.astype(z.dtype)
            # KV entries: [..., S, ...] differ only in the seq dim (axis 2)
            if (z.ndim == p.ndim and z.shape[:2] == p.shape[:2]
                    and z.shape[3:] == p.shape[3:]
                    and p.shape[2] <= z.shape[2]):
                return jax.lax.dynamic_update_slice(
                    z, p.astype(z.dtype), (0,) * z.ndim)
            raise ValueError(f"cache merge mismatch: {z.shape} vs {p.shape}")

        return jax.tree_util.tree_map(merge, zero, prefill_cache)

    @staticmethod
    def _length_bucket(req: Request) -> int:
        """Power-of-two bucket of the request's total token budget — the
        quantity that sets its wave's lockstep cost."""
        total = max(len(req.prompt) + req.max_new_tokens, 1)
        return 1 << (total - 1).bit_length()

    def _next_wave(self) -> list[Request]:
        # greedy bin-pack: the oldest request picks the wave's length
        # bucket, then the wave fills with that bucket's requests in FIFO
        # order (slots not fillable from the bucket stay padded — mixing
        # buckets would stretch every short member to the longest)
        bucket = self._length_bucket(self.queue[0])
        wave, rest = [], []
        for req in self.queue:
            if (len(wave) < self.slots
                    and self._length_bucket(req) == bucket):
                wave.append(req)
            else:
                rest.append(req)
        self.queue = rest
        self.wave_log.append([r.uid for r in wave])
        while len(wave) < self.slots:  # pad the wave with dummy requests
            wave.append(Request(uid=-1, prompt=np.array([self.pad_token],
                                                        np.int32),
                                max_new_tokens=0, done=True))
        return wave

    def _run_wave(self, wave: list[Request]) -> None:
        plen = max(len(r.prompt) for r in wave)
        prompts = np.full((self.slots, plen), self.pad_token, np.int32)
        for i, r in enumerate(wave):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        if self.api.cfg.frontend is not None:
            t = max(1, self.api.cfg.num_frontend_tokens)
            batch["frontend_embeds"] = jnp.zeros(
                (self.slots, t, self.api.cfg.d_model), jnp.float32)
        logits, prefill_cache = self._prefill(self.params, batch)
        cache = self._merge_cache(prefill_cache)
        self.key, sub = jax.random.split(self.key)
        tok = np.asarray(sample_token(logits[:, -1, :], sub,
                                      self.temperature))[:, None]
        pos = plen
        max_new = max((r.max_new_tokens for r in wave), default=0)
        for i, r in enumerate(wave):
            if not r.done and r.max_new_tokens > 0:
                r.generated.append(int(tok[i, 0]))
        for _ in range(max_new - 1):
            if pos >= self.max_seq - 1:
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tok), jnp.int32(pos))
            self.steps_executed += 1
            self.key, sub = jax.random.split(self.key)
            tok = np.asarray(sample_token(logits[:, -1, :], sub,
                                          self.temperature))[:, None]
            pos += 1
            for i, r in enumerate(wave):
                if not r.done and len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(tok[i, 0]))
                if len(r.generated) >= r.max_new_tokens:
                    r.done = True
        for r in wave:
            r.done = True
            if r.uid >= 0:
                self.finished.append(r)

    def run_until_done(self, max_waves: int = 1000) -> None:
        for _ in range(max_waves):
            if not self.queue:
                return
            self._run_wave(self._next_wave())
