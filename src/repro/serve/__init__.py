from repro.serve.durability import (DurableQoSEngine, FaultInjection,
                                    pack_engine, serving_digest, unpack_into)
from repro.serve.engine import (FlexAIPlacementService, Request, ServeEngine,
                                make_prefill_step, make_serve_step)
from repro.serve.qos import QoSConfig, QoSPlacementEngine, RouteRequest
