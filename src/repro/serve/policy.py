"""Shared QoS policy object (ISSUE 10).

``ServeEngine`` (token serving) and ``QoSPlacementEngine`` (placement
serving) grew the same deadline discipline twice: EDF sort keys over an
aging-credited effective deadline, per-wave aging bookkeeping, the
timeout-shed predicate, and resolved-request miss/slack stats.  This
module is the single home for all of it — both engines construct a
:class:`QoSPolicy` and route every formula through it, so the two
serving layers cannot drift apart again.

``power_of_two_bucket`` and ``effective_deadline`` live here too (they
were already shared); ``serve.qos`` re-exports them for compatibility.
"""
from __future__ import annotations

import dataclasses

import numpy as np

POLICIES = ("edf", "fifo")


def power_of_two_bucket(n: int, minimum: int) -> int:
    """Power-of-two length bucket >= max(n, minimum) — the shared shape
    quantization of every wave engine (lockstep cost is set by the
    longest member, so co-batching only makes sense within a bucket).

    ``minimum`` must be >= 1: doubling from 0 (or a negative) never
    reaches ``n``, which used to hang the caller forever.
    """
    if minimum < 1:
        raise ValueError(
            f"power_of_two_bucket minimum must be >= 1, got {minimum}")
    b = minimum
    while b < n:
        b *= 2
    return b


def effective_deadline(deadline: float, waves_waited: int,
                       aging_credit: float) -> float:
    """EDF comparison key shared by the token and placement engines: the
    absolute deadline minus the aging credit earned per passed-over wave.
    Co-submitted cohorts age together (the credit cancels within them);
    it is earned against *later* arrivals, which is what bounds
    cross-bucket starvation (tests/test_serve_properties.py)."""
    return deadline - aging_credit * waves_waited


@dataclasses.dataclass(frozen=True)
class QoSPolicy:
    """The deadline discipline both serving engines share.

    Holds exactly the knobs the shared formulas need — admission policy,
    aging credit, and whether timeout shedding is armed.  Engine-specific
    knobs (slots, chunking, preemption laxity, service model) stay with
    the engines.
    """
    policy: str = "edf"
    aging_credit: float = 0.0
    shed: bool = True

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")

    @property
    def is_edf(self) -> bool:
        return self.policy == "edf"

    # ---- EDF ordering --------------------------------------------------

    def eff_deadline(self, deadline: float, waves_waited: int) -> float:
        return effective_deadline(deadline, waves_waited, self.aging_credit)

    def request_key(self, req):
        """Admission sort key for anything with ``deadline`` /
        ``waves_waited`` / ``submit_order`` attributes: EDF on the
        effective deadline (submit order breaks ties) under "edf",
        plain submit order under "fifo"."""
        if self.is_edf:
            return (self.eff_deadline(req.deadline, req.waves_waited),
                    req.submit_order)
        return (req.submit_order,)

    # ---- shedding ------------------------------------------------------

    def should_shed(self, now: float, service_need: float,
                    deadline: float) -> bool:
        """Timeout-shed predicate: the request's remaining service no
        longer fits before its deadline (it would only burn capacity a
        feasible request could use)."""
        return self.shed and now + service_need > deadline

    # ---- aging ---------------------------------------------------------

    @staticmethod
    def age(waiters) -> None:
        """One admission round passed a set of waiters over: each earns
        one wave of aging credit.  Works on requests and on checkpointed
        waves alike (anything with ``waves_waited``)."""
        for w in waiters:
            w.waves_waited += 1

    # ---- stats ---------------------------------------------------------

    @staticmethod
    def miss_stats(slacks, n_shed: int) -> dict:
        """Resolved-request miss/slack summary.

        The denominator is *resolved* requests only (completed + shed) —
        never pending/backlog/in-flight work that has no verdict yet, so
        a mid-drain read is not silently optimistic (ISSUE 10 bugfix).
        """
        slacks = np.asarray([s for s in slacks if s is not None], np.float64)
        missed = int((slacks < 0.0).sum()) if slacks.size else 0
        resolved = int(slacks.size) + int(n_shed)
        return {
            "resolved": resolved,
            "completed": int(slacks.size),
            "shed": int(n_shed),
            "missed_deadline": missed,
            "miss_rate": ((missed + n_shed) / resolved) if resolved else 0.0,
            "p50_slack": float(np.percentile(slacks, 50)) if slacks.size
            else 0.0,
            "p99_slack": float(np.percentile(slacks, 99)) if slacks.size
            else 0.0,
        }
