"""Deadline-aware QoS serving for FlexAI placement requests (ISSUE 5).

The paper's headline serving claim — "basically 100% of tasks in each
driving route are processed within their required period" — is a *deadline*
guarantee, not a throughput one.  This module adds the deadline story the
wave-based serving layer was missing:

* every request carries an absolute deadline derived from the Table-5
  period requirements (``tasks.route_deadline_budget``);
* admission is EDF-within-bucket with a cross-bucket **aging credit**, so
  a long-route bucket cannot be starved by a stream of tight short routes
  (each wave a queued request is passed over lowers its effective deadline
  by ``aging_credit``; after ``spread/credit + n_queued`` waves it beats
  any newcomer — the bound ``tests/test_serve_properties.py`` checks);
* a running wave is **preemptible**: between service segments it
  checkpoints its batched ``PlatformState`` (the same pytree
  ``state_from_platform`` snapshots) and yields when a sufficiently
  tighter-deadline request is waiting (laxity rule below); the checkpoint
  resumes through the scan engine's ``state0=`` seam, bit-exactly;
* queued requests whose deadline can no longer be met are **shed** to a
  dead-letter log instead of burning wave slots on doomed work.

Time is a *virtual clock*: serving work is charged at ``svc_per_task``
seconds per lockstep task slot (padding included — the static-shape wave
pays for its padding, exactly like the real engine).  That keeps every
admission decision, preemption point and miss/shed verdict deterministic,
which is what the property suite and the CI gate need; wall-clock serving
latency rides on top without changing any decision.  With
``cfg.measured_svc`` the clock is instead advanced by *measured* segment
wall time and a per-(bucket, stages) EMA of it replaces the constant in
shedding/preemption decisions, so admission tracks the hardware the pool
actually has (virtual stays the deterministic fallback — see DESIGN.md).

Placements are real: each wave dispatches through the vmapped greedy scan
engine (``flexai.engine._schedule_run`` with ``state0`` resume), so
``stm_rate`` at the serving boundary is measured on actual schedules, not
a queueing abstraction.  A ``stub`` executor swaps the device dispatch for
a state pass-through when only the queueing discipline is under test.

With ``cfg.stages > 1`` a wave serves *pipeline* placements
(``core.pipeline``): each lane's route is flattened into the wavefront
stream at admission, service segments are micro-batches of flat
(task, stage) steps, and the preemption checkpoint widens to ``(state,
ring)`` — the ring of per-stage upstream finish times is exactly what a
resumed wave needs to keep charging cross-stage handoffs.  The virtual
clock charges ``svc/stages`` per flat slot, so a pipelined wave costs
the same service time as its unpipelined twin up to the (S-1)-column
drain bubble.  Params must come from a stage-level agent
(``PipelineFlexAI``); the durability layer does not support pipeline
waves (gated off in ``launch/serve.py`` and ``DurableQoSEngine``).

Two production paths land on top (ISSUE 10):

* **Sharded waves** (``mesh=``): the wave's lane axis is shard_mapped
  over the ``("routes",)`` mesh, lanes padded to the mesh size with
  invalid rows + fresh states and trimmed back — per-lane scans are
  independent, so placements are bit-exact vs the single-device path
  (the parity trace in ``benchmarks/serve_load.py`` pins it).

* **Continuous batching** (``cfg.continuous``): instead of draining a
  wave before re-admitting, a freed lane (completed — or shed mid-flight
  once its remaining service can no longer meet its deadline) is
  refilled at the next segment boundary from the backlog, JetStream
  prefill-insert style.  Refill only admits the request global admission
  would pick next (and only if its bucket matches the in-flight wave),
  so EDF ordering and the aging starvation bound survive; the refilled
  lane's ``PlatformState`` row is reinitialized, and the wave remains a
  preemptible checkpointed unit with per-lane cursors.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.platform_jax import (PlatformState, platform_init,
                                     spec_from_platform, stack_states,
                                     summarize)
from repro.core.tasks import (TaskArrays, invalid_task_arrays,
                              kind_period_table, pad_route_batch,
                              pad_task_arrays, route_deadline_budget,
                              stack_task_arrays, tasks_to_arrays)
from repro.serve.policy import (QoSPolicy, effective_deadline,
                                power_of_two_bucket)

__all__ = [
    "QoSConfig", "QoSPlacementEngine", "RouteRequest", "Wave", "QoSPolicy",
    "power_of_two_bucket", "effective_deadline",
    "QUEUED", "RUNNING", "PREEMPTED", "COMPLETED", "SHED",
]

QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
COMPLETED = "completed"
SHED = "shed"

# A long-lived serving process churns platforms/meshes; the compiled
# segment closures it no longer uses must not accumulate forever.
_SEG_FN_CACHE_CAP = 8
_SEG_FN_CACHE: "OrderedDict" = OrderedDict()


def _seg_cache_get(key, build):
    """LRU-bounded lookup into the shared compiled-closure cache."""
    if key in _SEG_FN_CACHE:
        _SEG_FN_CACHE.move_to_end(key)
        return _SEG_FN_CACHE[key]
    fn = build()
    _SEG_FN_CACHE[key] = fn
    while len(_SEG_FN_CACHE) > _SEG_FN_CACHE_CAP:
        _SEG_FN_CACHE.popitem(last=False)
    return fn


def _segment_fn(spec, backlog_scale: float, mesh=None):
    """Jitted vmapped resume-able scan segment, cached on the table
    contents (two engines over the same platform share one compiled
    closure — the benchmark builds six engines per run).  With ``mesh``
    the lane axis is shard_mapped over the mesh's route axis; callers
    pad lanes to the mesh size."""
    key = (np.asarray(spec.exec_time).tobytes(),
           np.asarray(spec.energy).tobytes(), float(backlog_scale),
           None if mesh is None else (mesh.devices.shape, mesh.axis_names))

    def build():
        from repro.core.flexai.engine import _schedule_run
        run = _schedule_run(spec, backlog_scale)
        vm = jax.vmap(run, in_axes=(None, 0, 0))
        if mesh is None:
            return jax.jit(vm)
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        ax = mesh.axis_names[0]
        return jax.jit(shard_map(vm, mesh=mesh,
                                 in_specs=(P(), P(ax), P(ax)),
                                 out_specs=(P(ax), P(ax))))

    return _seg_cache_get(key, build)


def _pipeline_segment_fn(spec, plan, backlog_scale: float):
    """Jitted vmapped pipeline segment (``core.pipeline``): lanes share
    the flat stage sequence, each carries its own (state, ring)
    checkpoint.  Cached like :func:`_segment_fn`, with the stage plan in
    the key."""
    key = (np.asarray(spec.exec_time).tobytes(),
           np.asarray(plan.stage_exec).tobytes(),
           np.asarray(plan.groups).tobytes(), float(backlog_scale))

    def build():
        from repro.core.pipeline import _pipeline_segment_run
        run = _pipeline_segment_run(spec, plan, backlog_scale,
                                    policy="flexai")
        return jax.jit(jax.vmap(run, in_axes=(None, 0, None, 0, 0)))

    return _seg_cache_get(key, build)


@dataclasses.dataclass(frozen=True)
class QoSConfig:
    """Knobs of the deadline-aware serving layer.

    ``policy="fifo"`` reproduces the pre-QoS engine exactly (oldest-head
    bucket admission, no aging / shedding / preemption) — the baseline the
    benchmark and the dominance property compare EDF against.
    """
    policy: str = "edf"              # "edf" | "fifo"
    deadline_scale: float = 1.0      # scales the Table-5 budget
    aging_credit: float = 0.002      # s of effective-deadline credit/wave
    laxity_s: float = 0.005          # preempt when a waiter is tighter by >
    preempt: bool = True
    shed: bool = True
    slots: int = 4                   # requests per wave
    chunk: int = 16                  # tasks per service segment (preemption
                                     # granularity; must divide the bucket)
    svc_per_task: Optional[float] = None  # virtual s per lockstep task slot
                                     # (None: half the mean Table-5 period)
    min_bucket: int = 16             # power of two, >= chunk
    max_preemptions: int = 4         # per wave (livelock guard)
    stages: int = 1                  # >1: pipeline waves (core.pipeline)
    continuous: bool = False         # refill freed lanes at segment
                                     # boundaries instead of draining
    measured_svc: bool = False       # EMA-calibrated measured service
                                     # times (virtual clock = fallback)
    svc_ema: float = 0.25            # EMA weight of a new measurement

    def __post_init__(self):
        if self.policy not in ("edf", "fifo"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.min_bucket < 1:
            raise ValueError(
                f"min_bucket must be >= 1, got {self.min_bucket}")
        if self.min_bucket & (self.min_bucket - 1):
            raise ValueError(
                f"min_bucket must be a power of two, got {self.min_bucket}")
        if self.min_bucket % self.chunk:
            raise ValueError("min_bucket must be a multiple of chunk")
        if self.stages < 1:
            raise ValueError("stages must be >= 1")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if not (0.0 < self.svc_ema <= 1.0):
            raise ValueError(f"svc_ema must be in (0, 1], got {self.svc_ema}")
        if self.continuous and self.stages > 1:
            raise ValueError(
                "continuous batching refills lockstep lanes; pipeline "
                "waves (stages > 1) drain — pick one")


@dataclasses.dataclass
class RouteRequest:
    """One vehicle's placement request plus its QoS bookkeeping."""
    uid: int
    tasks: TaskArrays        # padded to ``bucket``
    n_tasks: int             # real (pre-padding) length
    arrival: float           # virtual submit time
    deadline: float          # absolute virtual deadline
    bucket: int
    submit_order: int = 0
    waves_waited: int = 0    # admission rounds passed over (aging input)
    status: str = QUEUED
    finish: Optional[float] = None
    slack: Optional[float] = None
    summary: Optional[dict] = None

    @property
    def missed(self) -> bool:
        return self.status == SHED or (self.slack is not None
                                       and self.slack < 0.0)


@dataclasses.dataclass
class Wave:
    """An admitted (and possibly checkpointed) lockstep wave.

    Pipeline waves (``cfg.stages > 1``) carry the flat wavefront stream
    in ``batch`` ([slots, flat_len]) plus the shared stage sequence and
    the per-lane ring of upstream finish times — ``(state, ring)`` is the
    preemption checkpoint there."""
    requests: list           # lane-aligned RouteRequests (may be < slots)
    batch: TaskArrays        # [slots, bucket] (or [slots, flat_len])
    state: PlatformState     # [slots, ...] — THE preemption checkpoint
    bucket: int
    progress: int = 0        # lockstep task slots already served
    preemptions: int = 0
    waves_waited: int = 0
    recs: list = dataclasses.field(default_factory=list)
    s_seq: Optional[np.ndarray] = None   # [flat_len] stage per flat slot
    ring: Optional[jax.Array] = None     # [slots, S] checkpoint half 2
    flat_len: Optional[int] = None       # padded wavefront length
    # continuous batching (cfg.continuous): per-lane occupancy — the
    # checkpoint widens to (state, lane cursors) but stays on the Wave,
    # so preempt/resume is unchanged
    lane_requests: Optional[list] = None  # [slots] RouteRequest | None
    lane_progress: Optional[list] = None  # [slots] slots served per lane
    lane_recs: Optional[list] = None      # [slots] per-lane record chunks

    def min_deadline(self, aging_credit: float) -> float:
        return min(effective_deadline(r.deadline, self.waves_waited,
                                      aging_credit)
                   for r in self.requests)


def _stub_executor(spec):
    """State pass-through executor: same shapes as the scan dispatch, zero
    device work.  Lets the property suite exercise the queueing discipline
    (conservation / aging / dominance) at hypothesis speed."""
    from repro.core.platform_jax import StepRecord

    def seg(params, tasks, state):
        v = np.asarray(tasks.valid)
        z = np.zeros(v.shape, np.float32)
        rec = StepRecord(action=z.astype(np.int32), start=z, finish=z,
                         wait=z, exec_time=z, response=z, ms=z, energy=z,
                         met=np.zeros(v.shape, bool),
                         valid=np.zeros(v.shape, bool))
        # lax.scan stacks records time-major then the engine transposes;
        # the stub is already [lanes, chunk], so hand it over as-is
        return state, rec

    return seg


class QoSPlacementEngine:
    """Deadline-aware wave serving of FlexAI placement requests.

    One wave runs at a time (the serving pipe is the shared accelerator
    pool); a wave is up to ``slots`` same-bucket requests scheduled in
    lockstep segments of ``chunk`` tasks through the vmapped greedy scan
    engine.  Between segments the engine may preempt: the batched
    ``PlatformState`` is the checkpoint, and the wave re-enters admission
    as a resumable unit.
    """

    def __init__(self, platform, params, cfg: QoSConfig = QoSConfig(), *,
                 backlog_scale: float = 1.0,
                 executor: "Callable | str | None" = None,
                 mesh=None):
        self.spec = spec_from_platform(platform)
        self.params = params
        self.cfg = cfg
        self.backlog_scale = backlog_scale
        self.qpolicy = QoSPolicy(policy=cfg.policy,
                                 aging_credit=cfg.aging_credit,
                                 shed=cfg.shed)
        self.mesh = mesh
        if mesh is not None and cfg.stages > 1:
            raise ValueError("sharded waves are single-stage; pipeline "
                             "waves have their own 2-D mesh path")
        if mesh is not None and executor is not None:
            raise ValueError("mesh sharding requires the device scan "
                             "executor; stub/custom executors are host "
                             "functions")
        self.svc = (cfg.svc_per_task if cfg.svc_per_task is not None
                    else 0.5 * float(kind_period_table().mean()))
        # a flat pipeline slot is one (task, stage) micro-step: charge
        # svc/stages so a wave's total service matches its unpipelined
        # twin up to the (S-1)-column drain bubble
        self.svc_step = self.svc / cfg.stages
        self.base_svc = self.svc
        self.svc_scale = 1.0
        self.health = np.ones(self.spec.n, np.float64)
        self.plan = None
        if cfg.stages > 1:
            if executor is not None:
                raise ValueError(
                    "pipeline waves (stages > 1) require the device scan "
                    "executor; stub/custom executors are single-stage")
            from repro.core.pipeline import build_stage_plan
            self.plan = build_stage_plan(platform, cfg.stages)
            self._seg_fn = _pipeline_segment_fn(self.spec, self.plan,
                                                backlog_scale)
        elif executor == "stub":
            self._seg_fn = _stub_executor(self.spec)
        elif executor is not None:
            self._seg_fn = executor
        else:
            self._seg_fn = _segment_fn(self.spec, backlog_scale, mesh=mesh)
        # measured service times: per-(bucket, stages) EMA of wall-clock
        # per-slot segment cost (cfg.measured_svc); None entries fall
        # back to the virtual constant until the first dispatch lands
        self._svc_measured: dict = {}
        self._seg_elapsed: Optional[float] = None
        self.now = 0.0
        self._halt = False  # set by a durability hook to stop serving
        self._order = 0
        self.pending: list[RouteRequest] = []    # arrival > now
        self.backlog: list[RouteRequest] = []    # eligible, never started
        self.preempted: list[Wave] = []
        self.completed: list[RouteRequest] = []
        self.dead_letter: list[dict] = []
        self.wave_log: list[list[int]] = []
        self.dispatches = 0
        self.preemption_count = 0
        self.refills = 0

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        return power_of_two_bucket(n, max(self.cfg.min_bucket,
                                          self.cfg.chunk))

    def _flat_len(self, bucket: int) -> int:
        """Wavefront stream length for a bucket, padded to a chunk
        multiple (segment cuts stay aligned)."""
        L = (bucket + self.cfg.stages - 1) * self.cfg.stages
        return L + (-L) % self.cfg.chunk

    def _service_need(self, bucket: int) -> float:
        """Service time a bucket will be charged end to end — what
        shedding and preemption decisions compare against deadlines
        (identical to ``bucket * svc`` when stages == 1).  ``set_health``
        stretches ``svc``, so a degraded pool's need grows and admission
        sheds what no longer fits *before* dispatch.  Under
        ``cfg.measured_svc`` the per-(bucket, stages) EMA of measured
        per-slot cost replaces the virtual constant once calibrated
        (still scaled by the health stretch)."""
        length = (self._flat_len(bucket) if self.cfg.stages > 1
                  else bucket)
        if self.cfg.measured_svc:
            m = self._svc_measured.get((bucket, self.cfg.stages))
            if m is not None:
                return length * m * self.svc_scale
        if self.cfg.stages > 1:
            return length * self.svc_step
        return bucket * self.svc

    def set_health(self, health) -> None:
        """Degradation-aware admission: install a per-core health row
        (``core.faults`` semantics — 0.0 dead, (0, 1] capacity fraction)
        and stretch the virtual service cost by the lost throughput.
        The lockstep wave only moves as fast as the pool's surviving
        capacity, so effective service time scales by
        total-capacity / health-weighted-capacity; ``_service_need``
        then reflects what the degraded pool can actually deliver and
        timeout shedding fires ahead of doomed dispatches.  An all-ones
        row restores the healthy cost exactly."""
        self.health = np.asarray(health, np.float64)
        et = np.asarray(self.spec.exec_time, np.float64)
        cap = 1.0 / et.mean(axis=1)          # per-core healthy throughput
        eff = float((cap * self.health).sum())
        self.svc_scale = float(cap.sum()) / max(eff, 1e-12)
        self.svc = self.base_svc * self.svc_scale
        self.svc_step = self.svc / self.cfg.stages

    def submit(self, tasks, arrival: float = 0.0,
               deadline: Optional[float] = None) -> RouteRequest:
        """Queue one route.  ``deadline`` defaults to arrival + the
        Table-5 period budget of the route (``route_deadline_budget``
        scaled by ``cfg.deadline_scale``)."""
        ta = tasks if isinstance(tasks, TaskArrays) else tasks_to_arrays(tasks)
        n = ta.num_tasks
        bucket = self._bucket(n)
        if deadline is None:
            deadline = arrival + route_deadline_budget(
                ta, self.cfg.deadline_scale)
        req = RouteRequest(uid=self._order, tasks=pad_task_arrays(ta, bucket),
                           n_tasks=n, arrival=float(arrival),
                           deadline=float(deadline), bucket=bucket,
                           submit_order=self._order)
        self._order += 1
        if req.arrival <= self.now:
            self.backlog.append(req)
        else:
            self.pending.append(req)
            self.pending.sort(key=lambda r: (r.arrival, r.submit_order))
        return req

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _promote_arrivals(self) -> None:
        while self.pending and self.pending[0].arrival <= self.now:
            self.backlog.append(self.pending.pop(0))

    def _eff_deadline(self, req: RouteRequest) -> float:
        return self.qpolicy.eff_deadline(req.deadline, req.waves_waited)

    def _shed_request(self, r: RouteRequest, reason: str,
                      needed_s: float) -> None:
        """Move one request to the dead-letter log (shared by queued-shed
        and the continuous-mode mid-flight overrun shed)."""
        r.status = SHED
        r.finish = self.now
        r.slack = r.deadline - self.now
        self.dead_letter.append({
            "uid": r.uid, "n_tasks": r.n_tasks,
            "deadline": r.deadline, "shed_at": self.now,
            "reason": reason, "needed_s": needed_s,
            "had_s": r.deadline - self.now})

    def _shed_infeasible(self) -> None:
        """Timeout shedding: a queued request whose full service no longer
        fits before its deadline goes to the dead-letter log (it would
        only burn a wave that a feasible request could use)."""
        keep = []
        for r in self.backlog:
            need = self._service_need(r.bucket)
            if self.qpolicy.should_shed(self.now, need, r.deadline):
                self._shed_request(r, "infeasible", need)
            else:
                keep.append(r)
        self.backlog = keep

    def _pack_wave(self, head: RouteRequest) -> Wave:
        """The head picks the bucket; the wave fills with that bucket's
        eligible requests — EDF order under "edf", submit order under
        "fifo".  Everyone left behind ages one wave."""
        peers = [r for r in self.backlog if r.bucket == head.bucket]
        peers.sort(key=self.qpolicy.request_key)
        wave_reqs = peers[: self.cfg.slots]
        taken = {r.uid for r in wave_reqs}
        self.backlog = [r for r in self.backlog if r.uid not in taken]
        self.qpolicy.age(self.backlog)
        self.qpolicy.age(self.preempted)
        for r in wave_reqs:
            r.status = RUNNING
        rows = [r.tasks for r in wave_reqs]
        rows += [invalid_task_arrays(head.bucket)
                 for _ in range(self.cfg.slots - len(rows))]
        batch = stack_task_arrays(rows)
        state = stack_states(
            [platform_init(self.spec.n) for _ in range(self.cfg.slots)])
        self.wave_log.append([r.uid for r in wave_reqs])
        s_seq = ring = flat_len = None
        if self.plan is not None:
            batch, s_seq, flat_len = self._flatten_batch(batch, head.bucket)
            import jax.numpy as jnp
            ring = jnp.zeros((self.cfg.slots, self.cfg.stages), jnp.float32)
        # the wave inherits its members' earned aging credit, so a
        # long-aged request that gets preempted right after admission does
        # not restart its anti-starvation clock from zero
        return Wave(requests=wave_reqs, batch=batch, state=state,
                    bucket=head.bucket,
                    waves_waited=max(r.waves_waited for r in wave_reqs),
                    s_seq=s_seq, ring=ring, flat_len=flat_len)

    def _flatten_batch(self, batch: TaskArrays, bucket: int):
        """[slots, bucket] lockstep batch -> [slots, flat_len] wavefront
        stream (``core.pipeline._wavefront_stream`` per lane; the stage
        sequence depends only on (bucket, stages), so lanes share it),
        right-padded with invalid rows to a chunk multiple."""
        from repro.core.pipeline import _wavefront_stream
        S = self.cfg.stages
        flat_len = self._flat_len(bucket)
        lanes, s_seq = [], None
        for lane in range(batch.arrival.shape[0]):
            rows, ss = _wavefront_stream(
                jax.tree_util.tree_map(lambda a: a[lane], batch), S)
            rows = jax.tree_util.tree_map(np.asarray, rows)
            lanes.append(pad_task_arrays(rows, flat_len))
            s_seq = ss
        s_seq = np.concatenate(
            [np.asarray(s_seq),
             np.zeros(flat_len - s_seq.shape[0], s_seq.dtype)])
        return stack_task_arrays(lanes), s_seq, flat_len

    def _next_wave(self) -> Optional[Wave]:
        while True:
            self._promote_arrivals()
            if not self.backlog and not self.preempted:
                if not self.pending:
                    return None
                self.now = max(self.now, self.pending[0].arrival)
                self._promote_arrivals()
            if self.cfg.policy == "edf" and self.cfg.shed:
                self._shed_infeasible()
            if self.backlog or self.preempted:
                break
            if not self.pending:  # everything left was shed
                return None
            # an all-infeasible arrival group was shed; advance to the next
        if self.cfg.policy == "fifo":
            if self.preempted:      # only reachable via external injection:
                # _should_preempt gates on "edf", but resume consistently
                return self._resume(self.preempted[0])
            head = min(self.backlog, key=lambda r: r.submit_order)
            return self._pack_wave(head)
        # EDF: fresh requests and preempted waves compete on effective
        # deadline; a resumed wave re-enters at its checkpoint
        best_req = min(self.backlog, default=None,
                       key=self.qpolicy.request_key)
        best_wave = min(self.preempted, default=None,
                        key=lambda w: w.min_deadline(self.cfg.aging_credit))
        if best_wave is not None and (
                best_req is None
                or best_wave.min_deadline(self.cfg.aging_credit)
                <= self._eff_deadline(best_req)):
            return self._resume(best_wave)
        return self._pack_wave(best_req)

    def _resume(self, wave: Wave) -> Wave:
        """Re-admit a preempted wave at its checkpoint: same aging and
        wave_log bookkeeping as a fresh admission."""
        self.preempted.remove(wave)
        self.qpolicy.age(self.backlog)
        self.qpolicy.age(self.preempted)
        for r in wave.requests:
            r.status = RUNNING
        self.wave_log.append([r.uid for r in wave.requests])
        return wave

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def _should_preempt(self, wave: Wave) -> bool:
        if (self.cfg.policy != "edf" or not self.cfg.preempt
                or wave.preemptions >= self.cfg.max_preemptions):
            return False
        # a waiter that can no longer make its deadline anyway (it will be
        # shed at the next admission) is not worth a checkpoint
        waiters = [self._eff_deadline(r) for r in self.backlog
                   if not self.qpolicy.should_shed(
                       self.now, self._service_need(r.bucket), r.deadline)]
        waiters += [w.min_deadline(self.cfg.aging_credit)
                    for w in self.preempted]
        if not waiters:
            return False
        return min(waiters) < (wave.min_deadline(self.cfg.aging_credit)
                               - self.cfg.laxity_s)

    # ---- durability seams (overridden by serve/durability.py) ----------

    def _dispatch_segment(self, wave: Wave, seg: TaskArrays):
        """Serve one chunk: returns ``(new_state, records)``.  The
        durability layer swaps in fault-masked / mesh-sharded executors
        here without touching the wave loop.  With a mesh the lane axis
        is padded to the mesh size (invalid rows + fresh states) and
        trimmed back — per-lane scans are independent, so sharding is
        placement-neutral."""
        if self.mesh is not None:
            pad = (-self.cfg.slots) % self.mesh.size
            if pad:
                import jax.numpy as jnp
                seg = pad_route_batch(seg, self.mesh.size)
                state = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate(
                        [jnp.asarray(a), jnp.asarray(b)]),
                    wave.state,
                    stack_states([platform_init(self.spec.n)] * pad))
                st, recs = self._seg_fn(self.params, seg, state)
                trim = lambda a: a[: self.cfg.slots]  # noqa: E731
                return (jax.tree_util.tree_map(trim, st),
                        jax.tree_util.tree_map(trim, recs))
        return self._seg_fn(self.params, seg, wave.state)

    def _timed_dispatch(self, wave: Wave, seg: TaskArrays):
        """Dispatch one segment, measuring wall time when the measured
        service clock is armed: the blocking ``perf_counter`` window
        feeds the per-(bucket, stages) EMA and is what ``_charge_segment``
        advances the clock by for this segment."""
        if not self.cfg.measured_svc:
            return self._dispatch_segment(wave, seg)
        t0 = time.perf_counter()
        out = self._dispatch_segment(wave, seg)
        jax.block_until_ready(out[0])
        self._seg_elapsed = time.perf_counter() - t0
        self._observe_service(wave.bucket, self._seg_elapsed)
        return out

    def _observe_service(self, bucket: int, elapsed: float) -> None:
        per_slot = elapsed / self.cfg.chunk
        key = (bucket, self.cfg.stages)
        prev = self._svc_measured.get(key)
        a = self.cfg.svc_ema
        self._svc_measured[key] = (per_slot if prev is None
                                   else (1.0 - a) * prev + a * per_slot)

    def _charge_segment(self, wave: Wave, recs) -> None:
        """Advance the clock for one served segment (the durability layer
        charges degraded-core overruns here).  Pipeline segments are
        chunks of flat (task, stage) micro-steps charged at
        ``svc/stages`` each — identical to ``chunk * svc`` at one stage.
        A measured segment charges its own blocking wall time instead of
        the virtual constant."""
        if self._seg_elapsed is not None:
            self.now += self._seg_elapsed
            self._seg_elapsed = None
        else:
            self.now += self.cfg.chunk * self.svc_step

    def _after_segment(self, wave: Wave) -> None:
        """Segment-boundary hook: fault firing, heartbeats, snapshot
        cadence, preemption-guard checks (no-op in the base engine)."""

    def _on_complete(self, req: RouteRequest, lane_final, lane_recs) -> None:
        """Per-request completion hook (durability: final-state capture
        for the recovery parity digest)."""

    # --------------------------------------------------------------------

    def _run_wave(self, wave: Wave) -> None:
        if self.cfg.continuous and self.plan is None:
            return self._run_wave_continuous(wave)
        chunk = self.cfg.chunk
        total = wave.flat_len if wave.flat_len is not None else wave.bucket
        while wave.progress < total:
            p = wave.progress
            seg = jax.tree_util.tree_map(
                lambda a: a[:, p: p + chunk], wave.batch)
            if self.plan is not None:
                t0 = (time.perf_counter() if self.cfg.measured_svc
                      else None)
                state, ring, recs = self._seg_fn(
                    self.params, seg, wave.s_seq[p: p + chunk],
                    wave.state, wave.ring)
                if t0 is not None:
                    jax.block_until_ready(state)
                    self._seg_elapsed = time.perf_counter() - t0
                    self._observe_service(wave.bucket, self._seg_elapsed)
                wave.ring = ring
            else:
                state, recs = self._timed_dispatch(wave, seg)
            self.dispatches += 1
            wave.state = state
            wave.recs.append(recs)
            wave.progress += chunk
            self._charge_segment(wave, recs)
            self._promote_arrivals()
            self._after_segment(wave)
            if self._halt:
                return  # durability stop: the wave was snapshotted in-flight
            if wave.progress < total and self._should_preempt(wave):
                wave.preemptions += 1
                self.preemption_count += 1
                for r in wave.requests:
                    r.status = PREEMPTED
                self.preempted.append(wave)
                return
        # wave drained: every live lane completes at the current clock
        recs = jax.tree_util.tree_map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=1),
            *wave.recs)
        final = jax.device_get(wave.state)
        order = None
        if self.plan is not None:
            from repro.core.pipeline import _record_order
            order = np.asarray(_record_order(wave.bucket, self.cfg.stages))
        for lane, req in enumerate(wave.requests):
            lane_final = jax.tree_util.tree_map(lambda a: a[lane], final)
            lane_recs = jax.tree_util.tree_map(lambda a: a[lane], recs)
            if order is not None:
                # flat wavefront records -> task-major [bucket, S];
                # end-to-end verdicts come from the final stage
                from repro.core.pipeline import pipeline_summarize
                lane_recs = jax.tree_util.tree_map(
                    lambda a: a[order], lane_recs)
                summ = pipeline_summarize(self.spec, lane_final, lane_recs)
                summ["placements"] = np.asarray(
                    lane_recs.action)[: req.n_tasks]       # [n_tasks, S]
            else:
                summ = summarize(self.spec, lane_final, lane_recs)
                summ["placements"] = np.asarray(
                    lane_recs.action)[: req.n_tasks]
            summ["bucket"] = wave.bucket
            req.summary = summ
            req.status = COMPLETED
            req.finish = self.now
            req.slack = req.deadline - self.now
            self._on_complete(req, lane_final, lane_recs)
            self.completed.append(req)

    # ---- continuous batching (cfg.continuous) --------------------------

    def _run_wave_continuous(self, wave: Wave) -> None:
        """Continuous-batching wave loop (JetStream prefill-insert
        style): lanes carry independent cursors, and at every segment
        boundary a freed lane — completed, or shed mid-flight once its
        remaining service cannot meet its deadline — is refilled from
        the backlog with a reinitialized ``PlatformState`` row.  The
        wave stays a preemptible checkpointed unit: ``(state, lane
        cursors)`` lives on the Wave, so preempt/resume re-enters here
        unchanged."""
        chunk, slots = self.cfg.chunk, self.cfg.slots
        if wave.lane_requests is None:
            wave.lane_requests = (list(wave.requests)
                                  + [None] * (slots - len(wave.requests)))
            wave.lane_progress = [0] * slots
            wave.lane_recs = [[] for _ in range(slots)]
        idle_row = invalid_task_arrays(chunk)
        while True:
            rows = []
            for lane in range(slots):
                r = wave.lane_requests[lane]
                if r is None:
                    rows.append(idle_row)
                else:
                    p = wave.lane_progress[lane]
                    rows.append(jax.tree_util.tree_map(
                        lambda a: a[p: p + chunk], r.tasks))
            seg = stack_task_arrays(rows)
            state, recs = self._timed_dispatch(wave, seg)
            self.dispatches += 1
            wave.state = state
            for lane in range(slots):
                if wave.lane_requests[lane] is not None:
                    wave.lane_recs[lane].append(jax.tree_util.tree_map(
                        lambda a: a[lane], recs))
                    wave.lane_progress[lane] += chunk
            wave.progress += chunk
            self._charge_segment(wave, recs)
            self._promote_arrivals()
            self._after_segment(wave)
            if self._halt:
                wave.requests = [r for r in wave.lane_requests
                                 if r is not None]
                return
            for lane in range(slots):
                r = wave.lane_requests[lane]
                if (r is not None
                        and wave.lane_progress[lane] >= wave.bucket):
                    self._complete_lane(wave, lane)
            self._shed_overrun_lanes(wave)
            self._refill(wave)
            wave.requests = [r for r in wave.lane_requests if r is not None]
            if not wave.requests:
                return
            if self._should_preempt(wave):
                wave.preemptions += 1
                self.preemption_count += 1
                for r in wave.requests:
                    r.status = PREEMPTED
                self.preempted.append(wave)
                return

    def _complete_lane(self, wave: Wave, lane: int) -> None:
        """One lane reached its bucket: summarize exactly like a drained
        wave's lane and free the slot for refill."""
        r = wave.lane_requests[lane]
        lane_recs = jax.tree_util.tree_map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs]),
            *wave.lane_recs[lane])
        lane_final = jax.tree_util.tree_map(
            lambda a: a[lane], jax.device_get(wave.state))
        summ = summarize(self.spec, lane_final, lane_recs)
        summ["placements"] = np.asarray(lane_recs.action)[: r.n_tasks]
        summ["bucket"] = wave.bucket
        r.summary = summ
        r.status = COMPLETED
        r.finish = self.now
        r.slack = r.deadline - self.now
        self._on_complete(r, lane_final, lane_recs)
        self.completed.append(r)
        wave.lane_requests[lane] = None
        wave.lane_progress[lane] = 0
        wave.lane_recs[lane] = []

    def _shed_overrun_lanes(self, wave: Wave) -> None:
        """Mid-flight shed: a lane whose *remaining* service can no
        longer meet its deadline is cut loose (the work already done is
        sunk either way) so the lane can serve a feasible request — the
        "shed member" source of freed lanes."""
        if not self.qpolicy.is_edf or not self.cfg.shed:
            return
        per_slot = self._service_need(wave.bucket) / wave.bucket
        for lane, r in enumerate(wave.lane_requests):
            if r is None:
                continue
            need = (wave.bucket - wave.lane_progress[lane]) * per_slot
            if self.qpolicy.should_shed(self.now, need, r.deadline):
                self._shed_request(r, "overrun", need)
                wave.lane_requests[lane] = None
                wave.lane_progress[lane] = 0
                wave.lane_recs[lane] = []

    def _refill_head(self, wave: Wave) -> Optional[RouteRequest]:
        """The request global admission would run next, or None if a
        checkpointed wave (or nothing) should go first — refill must not
        overtake the cross-bucket EDF/FIFO order, or aging's starvation
        bound dies."""
        if not self.backlog:
            return None
        if not self.qpolicy.is_edf:
            if self.preempted:
                return None
            return min(self.backlog, key=lambda r: r.submit_order)
        best_req = min(self.backlog, key=self.qpolicy.request_key)
        best_wave = min(self.preempted, default=None,
                        key=lambda w: w.min_deadline(self.cfg.aging_credit))
        if best_wave is not None and (
                best_wave.min_deadline(self.cfg.aging_credit)
                <= self._eff_deadline(best_req)):
            return None
        return best_req

    def _refill(self, wave: Wave) -> None:
        """Admit backlog into freed lanes at a segment boundary.  Only
        the global admission head is eligible, and only while it shares
        the wave's bucket; a refill round that admits anyone counts as
        an admission round for aging (everyone passed over earns a
        wave of credit, same as ``_pack_wave``)."""
        free = [lane for lane in range(self.cfg.slots)
                if wave.lane_requests[lane] is None]
        if not free:
            return
        if self.qpolicy.is_edf and self.cfg.shed:
            self._shed_infeasible()
        import jax.numpy as jnp
        admitted = []
        for lane in free:
            head = self._refill_head(wave)
            if head is None or head.bucket != wave.bucket:
                break
            self.backlog.remove(head)
            head.status = RUNNING
            wave.lane_requests[lane] = head
            wave.lane_progress[lane] = 0
            wave.lane_recs[lane] = []
            wave.state = jax.tree_util.tree_map(
                lambda a, b: jnp.asarray(a).at[lane].set(b),
                wave.state, platform_init(self.spec.n))
            admitted.append(head)
        if admitted:
            self.refills += len(admitted)
            self.wave_log.append([r.uid for r in admitted])
            self.qpolicy.age(self.backlog)
            self.qpolicy.age(self.preempted)
            wave.waves_waited = max(
                [wave.waves_waited] + [r.waves_waited for r in admitted])

    def run_until_done(self, max_waves: int = 100_000) -> None:
        for _ in range(max_waves):
            if self._halt:
                return
            wave = self._next_wave()
            if wave is None:
                return
            self._run_wave(wave)
        raise RuntimeError(f"serving did not drain in {max_waves} waves")

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Serving-boundary QoS summary (what BENCH_serving.json reports).

        Safe to read mid-drain: miss/slack rates denominate over
        *resolved* requests only (completed + shed); work still pending,
        queued, or in flight is reported separately instead of silently
        deflating the miss rate (ISSUE 10 bugfix)."""
        submitted = self._order
        shed = len(self.dead_letter)
        ms = self.qpolicy.miss_stats(
            [r.slack for r in self.completed], shed)
        queued = len(self.backlog) + len(self.pending)
        in_flight = submitted - ms["resolved"] - queued
        stm = [r.summary["stm_rate"] for r in self.completed
               if r.summary is not None and r.summary["tasks"] > 0]
        # task-weighted STM over the WHOLE submitted workload: a shed
        # route's tasks were never processed, so they count as unmet —
        # this is the number the paper's "100% within period" claim maps
        # to at the serving boundary
        met_tasks = sum(r.summary["stm_rate"] * r.summary["tasks"]
                        for r in self.completed if r.summary is not None)
        total_tasks = (sum(r.n_tasks for r in self.completed)
                       + sum(d["n_tasks"] for d in self.dead_letter))
        return {
            "policy": self.cfg.policy,
            "submitted": submitted,
            "resolved": ms["resolved"],
            "in_flight": in_flight,
            "queued": queued,
            "completed": ms["completed"],
            "shed": shed,
            "missed_deadline": ms["missed_deadline"],
            "miss_rate": ms["miss_rate"],
            "p50_slack_s": ms["p50_slack"],
            "p99_slack_s": ms["p99_slack"],
            "mean_stm_rate": float(np.mean(stm)) if stm else 0.0,
            "stm_rate_incl_shed": (met_tasks / total_tasks) if total_tasks
            else 0.0,
            "waves": len(self.wave_log),
            "preemptions": self.preemption_count,
            "dispatches": self.dispatches,
            "refills": self.refills,
            "virtual_time_s": self.now,
        }
