"""Fault tolerance: heartbeats, straggler detection, preemption-safe runner,
elastic rescale.

On a real multi-pod deployment each host runs this next to the training
loop; the coordinator-side logic (who is slow, when to checkpoint, when to
re-mesh) is pure Python over step-timing records and is fully unit-testable
on CPU, which is what we do here.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import numpy as np

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class HeartbeatRecord:
    host_id: int
    step: int
    step_time_s: float
    timestamp: float


class StragglerDetector:
    """Flags hosts whose recent step times exceed ``threshold`` x the fleet
    median, and hosts that missed ``dead_after_s`` of heartbeats.

    Mitigation hooks (what a coordinator does with the flags):
      * straggler  -> reduce its data shard / trigger in-place restart
      * dead       -> evict host, trigger elastic re-mesh from checkpoint
    """

    def __init__(self, n_hosts: int, threshold: float = 1.5,
                 window: int = 16, dead_after_s: float = 60.0,
                 clock: Optional[Callable[[], float]] = None):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.window = window
        self.dead_after_s = dead_after_s
        # ``clock`` makes heartbeat timeouts deterministic: the QoS serving
        # layer injects its virtual clock, tests inject a counter — only
        # the default wall-clock path ever touches time.time()
        self._clock = time.time if clock is None else clock
        self._times: dict[int, list[float]] = {h: [] for h in range(n_hosts)}
        self._last_seen: dict[int, float] = {h: self._clock()
                                             for h in range(n_hosts)}

    def record(self, hb: HeartbeatRecord) -> None:
        times = self._times[hb.host_id]
        times.append(hb.step_time_s)
        if len(times) > self.window:
            del times[: len(times) - self.window]
        self._last_seen[hb.host_id] = hb.timestamp

    def stragglers(self) -> list[int]:
        means = {h: float(np.mean(t)) for h, t in self._times.items() if t}
        if len(means) < 2:
            return []
        median = float(np.median(list(means.values())))
        return [h for h, m in means.items() if m > self.threshold * median]

    def dead_hosts(self, now: Optional[float] = None) -> list[int]:
        now = self._clock() if now is None else now
        return [h for h, seen in self._last_seen.items()
                if now - seen > self.dead_after_s]


class PreemptionGuard:
    """SIGTERM-aware flag; checked once per step by the runner."""

    def __init__(self, install_handler: bool = True):
        self.preempted = False
        if install_handler:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not the main thread (tests)

    def _handler(self, signum, frame):
        self.preempted = True


@dataclasses.dataclass
class RunResult:
    completed_steps: int
    final_state: object
    interrupted: bool


def run_with_fault_tolerance(
    train_step: Callable,
    state,
    batch_at_step: Callable[[int], dict],
    *,
    num_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    start_step: int = 0,
    guard: Optional[PreemptionGuard] = None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
    fail_at_step: Optional[int] = None,  # fault-injection for tests
) -> RunResult:
    """Checkpointed training driver with preemption handling.

    Restart pattern: the caller finds ``latest_checkpoint``, restores state,
    and calls this again with ``start_step`` = restored step.  The data
    pipeline is step-indexed (``batch_at_step``), so restarts consume
    exactly the batches they would have seen (deterministic skip-ahead).
    """
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    step = start_step
    while step < num_steps:
        if guard is not None and guard.preempted:
            saver.wait()
            ckpt_lib.save_checkpoint(ckpt_dir, step, state)
            return RunResult(step, state, interrupted=True)
        if fail_at_step is not None and step == fail_at_step:
            saver.wait()
            raise RuntimeError(f"injected fault at step {step}")
        batch = batch_at_step(step)
        state, metrics = train_step(state, batch)
        step += 1
        if on_metrics is not None:
            on_metrics(step, metrics)
        if step % ckpt_every == 0 or step == num_steps:
            saver.save(step, state)
    saver.wait()
    return RunResult(step, state, interrupted=False)


def elastic_restore(ckpt_dir: str, template, target_shardings=None):
    """Restore the latest checkpoint onto a (possibly different) mesh.

    Returns (state, step) or (None, 0) when no checkpoint exists.  Because
    checkpoints are stored as full arrays, the same checkpoint restores on
    any device count — this is the elastic-rescale path.
    """
    path = ckpt_lib.latest_checkpoint(ckpt_dir)
    if path is None:
        return None, 0
    state = ckpt_lib.restore_checkpoint(path, template, target_shardings)
    return state, ckpt_lib.checkpoint_step(path)
