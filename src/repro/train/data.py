"""Deterministic, step-indexed synthetic data pipeline.

Every batch is a pure function of (seed, step), which is what makes
checkpoint-restart exact: a job restarted at step k consumes the same batch
stream it would have seen, with no persisted iterator state (the skip-ahead
property the fault-tolerance runner relies on).

For language modelling the stream is a mixture of (a) a repeating-ngram
synthetic language, which has learnable structure so loss decreases, and
(b) uniform noise tokens.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int
    seq_len: int
    seed: int = 0
    structure: float = 0.9  # fraction of learnable (ngram) tokens


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def lm_batch_at_step(cfg: ModelConfig, data: DataConfig, step: int) -> dict:
    """Markov-chain tokens: next token = (3*tok + 7) % V with noise."""
    rng = _rng(data.seed, step)
    b, s, v = data.batch_size, data.seq_len, cfg.vocab_size
    start = rng.integers(0, v, size=(b, 1))
    toks = [start]
    for _ in range(s):
        nxt = (3 * toks[-1] + 7) % v
        noise = rng.integers(0, v, size=(b, 1))
        use_noise = rng.random((b, 1)) > data.structure
        toks.append(np.where(use_noise, noise, nxt))
    seq = np.concatenate(toks, axis=1).astype(np.int32)  # [B, S+1]
    batch = {
        "tokens": seq[:, :-1],
        "labels": seq[:, 1:],
        "loss_mask": np.ones((b, s), dtype=np.float32),
    }
    if cfg.frontend is not None:
        t = max(1, cfg.num_frontend_tokens)
        batch["frontend_embeds"] = rng.standard_normal(
            (b, t, cfg.d_model)).astype(np.float32)
    return batch


def batch_fn(cfg: ModelConfig, data: DataConfig):
    return lambda step: lm_batch_at_step(cfg, data, step)
