"""Checkpointing: sharded-array save/restore with a JSON manifest.

orbax/tensorstore are not available in this environment, so this is a
self-contained implementation with the properties the fault-tolerance story
needs:

* **Mesh-independent**: arrays are written as full (unsharded) host numpy
  buffers, so a checkpoint written on a 256-chip mesh restores onto a
  512-chip or 8-chip mesh (elastic rescale) — resharding happens at
  ``device_put`` with the *target* mesh's shardings.
* **Atomic**: writes go to ``<dir>.tmp`` then ``os.replace`` — a crash
  mid-write never corrupts the latest checkpoint.
* **Async**: ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes to disk on a background thread, overlapping I/O with
  the next training steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    """Blocking save. Returns the checkpoint path."""
    names, leaves, _ = _flatten_with_names(state)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    return _write(directory, step, names, host)


def _write(directory: str, step: int, names, host_arrays) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "arrays": []}
    for i, (name, arr) in enumerate(zip(names, host_arrays)):
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["arrays"].append({
            "name": name, "file": fname,
            "dtype": str(arr.dtype), "shape": list(arr.shape),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = [d for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    if not steps:
        return None
    return os.path.join(directory, sorted(steps)[-1])


def _load_entry(path: str, entry: dict) -> np.ndarray:
    """Load one manifest array, recovering extension dtypes.

    ``np.save`` round-trips ml_dtypes extension arrays (bfloat16,
    float8_*) as raw void bytes — ``np.load`` hands back ``|V2`` with the
    values intact but the type gone.  The manifest dtype is the source of
    truth: reinterpret the buffer when the loaded dtype disagrees.
    """
    arr = np.load(os.path.join(path, entry["file"]))
    want = entry["dtype"]
    if str(arr.dtype) != want and arr.dtype.kind == "V":
        import ml_dtypes
        arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
    return arr


def load_checkpoint_arrays(path: str) -> tuple[int, list, list]:
    """Template-free restore: ``(step, host_arrays, names)`` in manifest
    order.  This is the self-describing path the serving snapshots use —
    after a crash there is no live object tree to mirror, so the manifest
    itself defines the structure."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = [_load_entry(path, e) for e in manifest["arrays"]]
    names = [e["name"] for e in manifest["arrays"]]
    return manifest["step"], arrays, names


def restore_checkpoint(path: str, template: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``template``.

    ``shardings``: optional matching tree of NamedShardings for the *target*
    mesh — this is where elastic rescale happens (full arrays are resharded
    onto whatever mesh the restarted job runs with).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(template)
    by_name = {a["name"]: a for a in manifest["arrays"]}
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for name, leaf, sh in zip(names, leaves, shard_leaves):
        entry = by_name[name]
        arr = _load_entry(path, entry)
        expected = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expected:
            raise ValueError(
                f"checkpoint shape mismatch for {name}: "
                f"{arr.shape} vs {expected}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


class AsyncCheckpointer:
    """Snapshot synchronously, write on a background thread.

    Writes are **serialized in submission order** (each background write
    chains on the previous one) and **stale steps lose**: a ``save`` whose
    step is <= the newest step already submitted is dropped, so
    ``latest_checkpoint`` can never go backwards even when saves overlap
    or a caller resubmits an old step.  ``save()`` itself never blocks on
    I/O — the host snapshot copy is its only synchronous cost.

    ``state`` may also be a zero-arg callable producing the pytree: then
    even the flatten/device-transfer/host copy runs on the writer thread
    and ``save()`` costs only the submission.  The caller owns
    consistency — every leaf the callable closes over must be immutable
    (jax arrays are; host arrays must not be mutated in place).

    Disk writes retry transient ``OSError`` up to ``retries`` times with
    exponential backoff (``backoff_s * 2**attempt``): a blip on a network
    filesystem must not silently kill the snapshot thread — before the
    retry loop, one ``ENOSPC`` hiccup meant every later ``save`` wrote
    nothing and the failure only surfaced at the next ``wait()``.  The
    atomic tmp-dir protocol makes a failed attempt restartable: the
    partial ``.tmp`` is wiped at the top of ``_write``.
    """

    def __init__(self, directory: str, keep: int = 3, *,
                 retries: int = 3, backoff_s: float = 0.05):
        self.directory = directory
        self.keep = keep
        self.retries = retries
        self.backoff_s = backoff_s
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._highest_step: int = -1

    def save(self, step: int, state: Any) -> None:
        if callable(state):
            names = host = None  # materialized on the writer thread
        else:
            names, leaves, _ = _flatten_with_names(state)
            host = [np.asarray(jax.device_get(x)) for x in leaves]
        with self._lock:
            if step <= self._highest_step:
                return  # a newer (or equal) step is already in flight
            self._highest_step = step
            prev = self._thread

            def work():
                if prev is not None:
                    prev.join()  # keep disk order == submission order
                try:
                    if names is None:
                        n, leaves, _ = _flatten_with_names(state())
                        h = [np.asarray(jax.device_get(x)) for x in leaves]
                    else:
                        n, h = names, host
                    for attempt in range(self.retries + 1):
                        try:
                            _write(self.directory, step, n, h)
                            break
                        except OSError:
                            if attempt == self.retries:
                                raise
                            time.sleep(self.backoff_s * (2 ** attempt))
                    self._gc()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
