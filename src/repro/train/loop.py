"""Train state + jit-able train step (mixed precision, grad accumulation,
optional gradient compression).

The step is written against the global (SPMD) view: batch arrives sharded
over ("pod", "data"), params/optimizer FSDP+TP sharded per the logical-axis
rules.  Gradient reductions are implicit in ``jax.grad`` under GSPMD; the
memory lever for big archs is the grad-accumulation scan (saved activations
scale with one microbatch).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.api import ModelAPI
from repro.sharding import Param, is_param
from repro.train import compression as C
from repro.train.optimizer import OptState, adamw_init, adamw_update, lr_schedule


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    ef: Any  # error-feedback residuals (None unless int8_ef)


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    compression: str = "none"  # none | bf16 | int8_ef


def init_train_state(params, hyper: TrainHyper) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params),
        ef=C.ef_init(params) if hyper.compression == "int8_ef" else None,
    )


def train_state_boxed(boxed_params, hyper: TrainHyper) -> TrainState:
    """Boxed TrainState (for tree_shardings / dry-run input specs).

    Optimizer moments inherit the parameter logical axes.
    """
    as_f32 = lambda p: Param(
        jax.ShapeDtypeStruct(p.value.shape, jnp.float32)
        if isinstance(p.value, jax.ShapeDtypeStruct)
        else jnp.zeros(p.value.shape, jnp.float32),
        p.axes)
    mu = jax.tree_util.tree_map(as_f32, boxed_params, is_leaf=is_param)
    nu = jax.tree_util.tree_map(as_f32, boxed_params, is_leaf=is_param)
    ef = (jax.tree_util.tree_map(as_f32, boxed_params, is_leaf=is_param)
          if hyper.compression == "int8_ef" else None)
    return TrainState(
        params=boxed_params,
        opt=OptState(step=Param(jnp.zeros((), jnp.int32), ()), mu=mu, nu=nu),
        ef=ef,
    )


def train_state_axes(boxed_state: TrainState):
    """Logical-axes tree matching TrainState (for documentation/tests)."""
    from repro.sharding import boxed_axes
    return boxed_axes(boxed_state)


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def make_train_step(api: ModelAPI, hyper: TrainHyper):
    cfg = api.cfg
    n_micro = max(1, cfg.use_grad_accum_microbatches)

    def loss_fn(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    def compute_grads(params, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        micro = _split_microbatches(batch, n_micro)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum / n_micro, metrics, grads

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = compute_grads(state.params, batch)

        ef = state.ef
        if hyper.compression == "bf16":
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16), grads)
        elif hyper.compression == "int8_ef":
            grads, ef = C.compress_grads_int8_ef(grads, state.ef)

        lr = lr_schedule(state.opt.step, peak_lr=hyper.peak_lr,
                         warmup_steps=hyper.warmup_steps,
                         total_steps=hyper.total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, lr,
            b1=hyper.b1, b2=hyper.b2,
            weight_decay=hyper.weight_decay,
            grad_clip_norm=hyper.grad_clip_norm)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["lr"] = lr
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, ef), metrics

    return train_step
