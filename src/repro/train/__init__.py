from repro.train.optimizer import adamw_init, adamw_update, OptState, lr_schedule
from repro.train.loop import TrainState, make_train_step, train_state_axes
