"""Gradient compression with error feedback.

Two usable levers on TPU:

* ``bf16``     — carry the backward pass/reduction in bf16 (2x bytes saved on
                 every grad all-reduce; free, standard).
* ``int8_ef``  — per-tensor-scaled int8 quantization with an error-feedback
                 residual carried in the train state (1-bit-SGD/EF-SGD
                 lineage).  Applied to the gradient tree before the optimizer;
                 under SPMD the quantized representation is what crosses the
                 slow inter-pod links when the cross-pod reduction is staged
                 explicitly (see ``train/loop.py``).

Both are exact-shape pytree transforms, unit-tested against the property
that EF compensates: sum of applied updates converges to sum of true grads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_int8_ef(grads, ef_state):
    """Error-feedback int8 compression of a grad tree.

    Returns (decompressed grads, new ef_state).  The quantize->dequantize
    round trip is what a wire transfer would carry; the residual
    (g - dequant) is added back next step.
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        return deq, gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
