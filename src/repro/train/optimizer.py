"""AdamW + schedules, built from scratch (no optax in this environment).

Optimizer states mirror the parameter tree (same logical axes), so the
``tree_shardings`` used for params apply verbatim to m/v — fully sharded
optimizer states (ZeRO-style) fall out of the FSDP param sharding.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: Any          # first moment  (pytree like params)
    nu: Any          # second moment (pytree like params)


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(params, grads, state: OptState, lr,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 grad_clip_norm: float | None = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gflat = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in gflat))
    if grad_clip_norm is not None:
        scale = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        scale = jnp.float32(1.0)

    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    results = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([r[0] for r in results])
    new_mu = treedef.unflatten([r[1] for r in results])
    new_nu = treedef.unflatten([r[2] for r in results])
    return new_params, OptState(step, new_mu, new_nu), {"grad_norm": gnorm}


def lr_schedule(step, *, peak_lr=3e-4, warmup_steps=100, total_steps=10_000,
                min_ratio=0.1):
    """Linear warmup + cosine decay."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / jnp.maximum(
        total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup_steps, warm, cos)
