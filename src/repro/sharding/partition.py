"""Logical-axis parameter partitioning.

Every parameter in the framework is created as a :class:`Param` box carrying
both its value (a ``jax.Array`` — or a ``ShapeDtypeStruct`` under
``jax.eval_shape``) and a tuple of *logical axis names* (one per dim).  A rule
table maps logical names onto physical mesh axes; changing the rule table is
how sharding experiments (§Perf hillclimbs) are done, without touching model
code.

Logical axis vocabulary used across the model zoo:

    "batch"      activation batch                  -> ("pod", "data")
    "seq"        activation sequence (SP regions)  -> "model"
    "embed"      residual-stream / d_model dim     -> "data"   (FSDP shard)
    "vocab"      embedding-table vocabulary        -> "model"
    "heads"      query heads                       -> "model"  (TP)
    "kv_heads"   KV heads (may be < TP degree)     -> None     (replicated)
    "head_dim"   per-head dim                      -> None
    "mlp"        FFN hidden dim                    -> "model"  (TP)
    "expert"     MoE expert dim                    -> "model"  (EP)
    "layers"     stacked scan-over-layers dim      -> None
    "kv_seq"     KV-cache sequence dim (decode)    -> "model"  (flash-decoding)
    "ssm_state"  SSM state dim                     -> None
    "ssm_heads"  SSD heads                         -> "model"
    "lora"       MLA latent / low-rank dims        -> None
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter value boxed with its logical axis names.

    ``axes`` is pytree *metadata*, so ``jax.eval_shape`` /
    ``jax.tree_util.tree_map`` over boxed trees treat only ``value`` as a
    leaf.  ``len(axes)`` must equal ``value.ndim``.
    """

    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @property
    def shape(self):
        return self.value.shape

    @property
    def dtype(self):
        return self.value.dtype


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree):
    """Boxed param tree -> plain value tree (same structure minus boxes).

    Non-Param leaves pass through unchanged, so mixed trees are fine.
    """
    return jax.tree_util.tree_map(
        lambda p: p.value if is_param(p) else p, tree, is_leaf=is_param)


def boxed_axes(tree):
    """Boxed param tree -> tree of logical-axes tuples (leaves are tuples)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_param)
    return jax.tree_util.tree_unflatten(
        treedef, [p.axes if is_param(p) else None for p in leaves]
    )


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

# Each logical axis maps to a mesh axis name, a tuple of mesh axis names, or
# None (replicated).  First matching rule wins.
AxisRules = tuple  # tuple[tuple[str, str | tuple | None], ...]

DEFAULT_RULES: AxisRules = (
    ("batch", ("pod", "data")),
    ("cache_batch", ("pod", "data")),  # KV-cache batch dim (decode)
    ("seq", "model"),
    ("embed", "data"),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", None),
    ("head_dim", None),
    ("mlp", "model"),
    ("expert", "model"),
    ("expert_cap", "data"),  # MoE dispatch-buffer capacity dim (2D EP)
    ("expert_mlp", None),
    ("layers", None),
    ("kv_seq", "model"),
    ("ssm_state", None),
    ("ssm_heads", "model"),
    ("lora", None),
    ("conv_kernel", None),
    ("unsharded", None),
)

# Platform-simulation logical axes (the device-resident scheduler /
# serving engines, ``core/pipeline.py``).  The scan engines historically
# hard-coded a 1-D ``("routes",)`` mesh; pipeline parallelism generalizes
# it to 2-D — stage groups along "stages" (inter-op, alpa-style), route
# lanes along "routes" (data parallel).  Per-accelerator state rows and
# scheduler task windows stay replicated within a stage group.
PLATFORM_RULES: AxisRules = (
    ("routes", "routes"),    # independent route lanes (data parallel)
    ("stages", "stages"),    # pipeline stage groups (inter-op parallel)
    ("accel", None),         # per-accelerator state rows
    ("window", None),        # scheduler task windows
    ("tasks", None),         # per-task queue rows
)

# Decode-time rules (§Perf, decode cells).  The training layout FSDP-shards
# weights along the *contraction* (embed) dim over "data", which at decode
# forces an fp32 weight all-gather per matmul per token (84 MB/matmul for
# mistral-large in the baseline HLO).  For decode we instead 2D-shard every
# weight along NON-embed dims — (heads|mlp) x (head_dim|data-split of mlp) —
# so each matmul is local-partial + an activation-sized all-reduce
# (O(100 KB)), the textbook 2D-TP serving layout.  Activations replicate
# over "data"; the KV cache keeps its own distributed batch sharding
# ("cache_batch").
_DECODE_OVERRIDES = {
    "batch": ("pod",),
    "embed": None,            # never shard the contraction dim of weights
    "mlp": ("model", "data"),
    "expert_mlp": "data",
    "head_dim": "data",
    "seq": None,
}
DECODE_RULES: AxisRules = tuple(
    (name, _DECODE_OVERRIDES.get(name, target))
    if name in _DECODE_OVERRIDES else (name, target)
    for name, target in DEFAULT_RULES
)


def _rules_dict(rules: AxisRules) -> dict:
    return dict(rules)


def logical_to_mesh_axes(
    axes: Sequence[str | None], rules: AxisRules, mesh: Mesh
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    Mesh axes not present in ``mesh`` are dropped (so one rule table works
    for both the single-pod and multi-pod meshes).  A mesh axis may be used
    at most once in a spec; later logical dims asking for an already-used
    mesh axis are left replicated.
    """
    table = _rules_dict(rules)
    used: set = set()
    spec = []
    for name in axes:
        if name is None:
            spec.append(None)
            continue
        if name not in table:
            raise ValueError(f"no partition rule for logical axis {name!r}")
        target = table[name]
        if target is None:
            spec.append(None)
            continue
        targets = target if isinstance(target, tuple) else (target,)
        avail = tuple(
            t for t in targets if t in mesh.axis_names and t not in used
        )
        if not avail:
            spec.append(None)
            continue
        used.update(avail)
        spec.append(avail if len(avail) > 1 else avail[0])
    return P(*spec)


def named_sharding(
    axes: Sequence[str | None], mesh: Mesh, rules: AxisRules = DEFAULT_RULES
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_mesh_axes(axes, rules, mesh))


def _divisible(shape, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide a dim (avoids padded/uneven
    shardings in the dry-run, which inflate memory)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if total and dim % total == 0:
            out.append(entry)
        else:
            # try a prefix of the axes that still divides
            kept = []
            prod = 1
            for n in names:
                if dim % (prod * mesh.shape[n]) == 0:
                    kept.append(n)
                    prod *= mesh.shape[n]
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def tree_shardings(boxed_tree, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Boxed param tree -> tree of NamedShardings (same structure)."""

    def one(p: Param):
        spec = logical_to_mesh_axes(p.axes, rules, mesh)
        spec = _divisible(p.value.shape, spec, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, boxed_tree, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Ambient mesh/rules context (set by the launcher; no-op in plain tests)
# ---------------------------------------------------------------------------

import contextlib
import contextvars

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_mesh_ctx", default=None)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Install ``mesh`` + ``rules`` as the ambient partitioning context.

    Also enters the legacy mesh context manager so bare-PartitionSpec
    sharding constraints resolve inside ``jit``.
    """
    token = _CTX.set((mesh, rules))
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.reset(token)


def current_mesh_and_rules():
    return _CTX.get()


def with_logical_constraint(x: jax.Array, axes: Sequence[str | None], rules=None):
    """``with_sharding_constraint`` by logical axis names.

    Uses the mesh installed by :func:`activate`; no-op otherwise so model
    code runs unchanged in single-device tests.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, ctx_rules = ctx
    spec = logical_to_mesh_axes(axes, rules or ctx_rules, mesh)
    spec = _divisible(x.shape, spec, mesh)
    return jax.lax.with_sharding_constraint(x, spec)
