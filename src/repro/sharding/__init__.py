from repro.compat import abstract_mesh, make_mesh
from repro.sharding.partition import (
    Param,
    is_param,
    unbox,
    boxed_axes,
    logical_to_mesh_axes,
    named_sharding,
    tree_shardings,
    with_logical_constraint,
    activate,
    current_mesh_and_rules,
    DEFAULT_RULES,
    AxisRules,
)
