"""Fused TD-update kernel: parity against the autodiff oracle.

The oracle is the production trainer math itself
(``repro.core.flexai.dqn``): ``dqn_td_grads`` = ``jax.value_and_grad``
over the Huber double-DQN loss + 10.0 global-norm clip, ``dqn_td_update``
= grads + ``adam_apply``.  The kernel re-derives the backward by hand and
fuses everything into one Pallas pass, so every test here is a parity
pin, not a behavior spec.

Execution mode follows ``repro.kernels.protocol``: interpret on CPU,
compiled under ``REPRO_KERNEL_COMPILED=1`` on TPU/GPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flexai.dqn import (DQNParams, _adam_init, adam_apply,
                                   dqn_td_grads, dqn_td_update, init_qnet)
from repro.kernels.dqn_update import (dqn_td_grads_fused,
                                      dqn_td_update_fused)
from repro.kernels.protocol import compiled_available

KEY = jax.random.PRNGKey(11)
INTERPRET = not compiled_available()
D, A = 18, 3  # state_dim / n_actions of the 3-core HMAI platform


def _nets(key):
    ep = init_qnet(key, D, A)
    tp = init_qnet(jax.random.fold_in(key, 99), D, A)
    return ep, tp


def _batch(key, b, done_rate=0.2):
    ks = jax.random.split(key, 5)
    return {
        "s": jax.random.normal(ks[0], (b, D), jnp.float32),
        "a": jax.random.randint(ks[1], (b,), 0, A),
        "r": jax.random.normal(ks[2], (b,), jnp.float32) * 3.0,
        "s_next": jax.random.normal(ks[3], (b, D), jnp.float32),
        "done": (jax.random.uniform(ks[4], (b,))
                 < done_rate).astype(jnp.float32),
    }


def _assert_grads_close(g_ref: DQNParams, g_ker: DQNParams, tol=1e-5):
    for name, a, b in zip(g_ref._fields, g_ref, g_ker):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=tol, atol=tol, err_msg=name)


@pytest.mark.parametrize("b,tile", [
    (8, 128),    # single tile, tile > B
    (32, 128),   # the engine default shape
    (64, 16),    # multi-tile, exact division
    (40, 16),    # B NOT a multiple of the tile -> masked tail block
    (17, 8),     # prime B, masked tail
])
def test_grads_parity_vs_value_and_grad(b, tile):
    ep, tp = _nets(KEY)
    batch = _batch(jax.random.fold_in(KEY, b), b)
    loss_ref, g_ref = dqn_td_grads(ep, tp, batch)
    loss_ker, g_ker = dqn_td_grads_fused(ep, tp, batch, batch_tile=tile,
                                         interpret=INTERPRET)
    np.testing.assert_allclose(float(loss_ker), float(loss_ref),
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(g_ref, g_ker)


def test_grads_parity_all_done_batch():
    """done = 1 everywhere: the bootstrap term vanishes (y = r), so the
    TargNet forward must contribute exactly nothing."""
    ep, tp = _nets(KEY)
    batch = _batch(jax.random.fold_in(KEY, 1), 32)
    batch["done"] = jnp.ones_like(batch["done"])
    loss_ref, g_ref = dqn_td_grads(ep, tp, batch)
    loss_ker, g_ker = dqn_td_grads_fused(ep, tp, batch,
                                         interpret=INTERPRET)
    np.testing.assert_allclose(float(loss_ker), float(loss_ref),
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(g_ref, g_ker)


def test_grads_parity_no_done_and_gamma():
    ep, tp = _nets(jax.random.fold_in(KEY, 5))
    batch = _batch(jax.random.fold_in(KEY, 2), 24)
    batch["done"] = jnp.zeros_like(batch["done"])
    loss_ref, g_ref = dqn_td_grads(ep, tp, batch, gamma=0.5)
    loss_ker, g_ker = dqn_td_grads_fused(ep, tp, batch, gamma=0.5,
                                         interpret=INTERPRET)
    np.testing.assert_allclose(float(loss_ker), float(loss_ref),
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(g_ref, g_ker)


@pytest.mark.parametrize("nudge", [1.0 - 1e-3, 1.0, 1.0 + 1e-3])
def test_clip_boundary_gnorm_exactly_ten(nudge):
    """Engineered batch whose UNclipped gradient norm is exactly 10.0
    (the clip threshold), then nudged just below / onto / just above it.

    Construction: s = 0 and b1 = 0 kill layer 1 (h1 = 0); b2 = c makes
    h2 = c on all 64 lanes; w3 = 0, b3 = 0 make every Q zero; a huge
    reward saturates the Huber (per-sample dL/dq_sel = -1/B) and every
    sample takes action 0, so the only nonzero gradients are
    dW3[:, 0] = -c (64 entries) and db3[0] = -1:
    gnorm = sqrt(64 c^2 + 1) = 10  <=>  c = sqrt(99/64).
    The kernel's clip factor must track the oracle through the boundary.
    """
    b = 16
    c = float(np.sqrt(99.0 / 64.0)) * nudge
    h1, h2 = 256, 64
    zeros = DQNParams(
        w1=jnp.zeros((D, h1)), b1=jnp.zeros((h1,)),
        w2=jnp.zeros((h1, h2)), b2=jnp.full((h2,), c),
        w3=jnp.zeros((h2, A)), b3=jnp.zeros((A,)))
    batch = {
        "s": jnp.zeros((b, D)), "a": jnp.zeros((b,), jnp.int32),
        "r": jnp.full((b,), 100.0), "s_next": jnp.zeros((b, D)),
        "done": jnp.ones((b,), jnp.float32),
    }
    loss_ref, g_ref = dqn_td_grads(zeros, zeros, batch)
    loss_ker, g_ker = dqn_td_grads_fused(zeros, zeros, batch,
                                         interpret=INTERPRET)
    gnorm_ref = float(jnp.sqrt(sum(jnp.sum(g * g) for g in g_ref)))
    gnorm_ker = float(jnp.sqrt(sum(jnp.sum(g * g) for g in g_ker)))
    # post-clip norms agree to 1e-5 AND sit where the construction says:
    # min(10, gnorm_unclipped) with gnorm_unclipped = 10 * nudge-ish
    np.testing.assert_allclose(gnorm_ker, gnorm_ref, rtol=1e-5, atol=1e-6)
    assert gnorm_ref <= 10.0 + 1e-4
    np.testing.assert_allclose(float(loss_ker), float(loss_ref),
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(g_ref, g_ker)


def test_update_parity_vs_dqn_td_update():
    ep, tp = _nets(KEY)
    opt = _adam_init(ep)
    batch = _batch(jax.random.fold_in(KEY, 3), 64)
    p_ref, o_ref, l_ref = dqn_td_update(ep, tp, opt, batch)
    p_ker, o_ker, l_ker = dqn_td_update_fused(ep, tp, opt, batch,
                                              interpret=INTERPRET)
    np.testing.assert_allclose(float(l_ker), float(l_ref),
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(p_ref, p_ker)
    _assert_grads_close(o_ref.mu, o_ker.mu)
    _assert_grads_close(o_ref.nu, o_ker.nu)
    assert int(o_ker.step) == int(o_ref.step) == 1


def test_update_trajectory_64_updates_within_1e5():
    """The acceptance pin: >= 64 consecutive fused updates (with TargNet
    syncs every 20) stay within 1e-5 of the oracle trajectory on BOTH the
    loss and every parameter."""
    ep, _ = _nets(jax.random.fold_in(KEY, 7))
    p_ref = p_ker = ep
    t_ref = t_ker = ep
    o_ref, o_ker = _adam_init(ep), _adam_init(ep)
    upd_ref = jax.jit(dqn_td_update)
    upd_ker = jax.jit(lambda e, t, o, b: dqn_td_update_fused(
        e, t, o, b, interpret=INTERPRET))
    max_l = max_p = 0.0
    for i in range(64):
        batch = _batch(jax.random.fold_in(KEY, 1000 + i), 32)
        p_ref, o_ref, l_ref = upd_ref(p_ref, t_ref, o_ref, batch)
        p_ker, o_ker, l_ker = upd_ker(p_ker, t_ker, o_ker, batch)
        if (i + 1) % 20 == 0:
            t_ref, t_ker = p_ref, p_ker
        max_l = max(max_l, abs(float(l_ref) - float(l_ker)))
        max_p = max(max_p, max(
            float(jnp.max(jnp.abs(a - b))) for a, b in zip(p_ref, p_ker)))
    assert max_l <= 1e-5, f"loss drifted {max_l:.2e}"
    assert max_p <= 1e-5, f"params drifted {max_p:.2e}"


def test_grads_under_vmap_dp_seam():
    """The DP trainer vmaps the grads half over per-lane batches and
    pmeans the result before a shared adam_apply; the kernel must
    reproduce that whole seam."""
    ep, tp = _nets(KEY)
    lanes, b = 4, 16
    batches = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[_batch(jax.random.fold_in(KEY, 50 + i), b) for i in range(lanes)])
    l_ref, g_ref = jax.vmap(
        lambda bt: dqn_td_grads(ep, tp, bt))(batches)
    l_ker, g_ker = jax.vmap(
        lambda bt: dqn_td_grads_fused(ep, tp, bt,
                                      interpret=INTERPRET))(batches)
    np.testing.assert_allclose(np.asarray(l_ker), np.asarray(l_ref),
                               rtol=1e-5, atol=1e-6)
    _assert_grads_close(
        jax.tree_util.tree_map(lambda g: g.mean(0), g_ref),
        jax.tree_util.tree_map(lambda g: g.mean(0), g_ker))
    # lane-averaged grads feed the same adam_apply on both sides
    opt = _adam_init(ep)
    pa, _ = adam_apply(ep, opt,
                       jax.tree_util.tree_map(lambda g: g.mean(0), g_ref))
    pb, _ = adam_apply(ep, opt,
                       jax.tree_util.tree_map(lambda g: g.mean(0), g_ker))
    _assert_grads_close(pa, pb)


def test_kernel_inside_jit_scan_cond():
    """The engine inlines the update inside lax.cond inside lax.scan —
    the kernel must trace and run there."""
    ep, tp = _nets(KEY)
    opt = _adam_init(ep)
    batch = _batch(jax.random.fold_in(KEY, 4), 32)

    @jax.jit
    def run(p, o):
        def body(carry, do):
            p, o = carry
            p2, o2, loss = jax.lax.cond(
                do,
                lambda _: dqn_td_update_fused(p, tp, o, batch,
                                              interpret=INTERPRET),
                lambda _: (p, o, jnp.float32(0.0)), None)
            return (p2, o2), loss
        return jax.lax.scan(body, (p, o),
                            jnp.array([True, False, True]))

    (p_f, o_f), losses = run(ep, opt)
    # two real updates, one skip
    assert int(o_f.step) == 2
    assert float(losses[1]) == 0.0 and float(losses[0]) > 0.0


def test_protocol_interpret_decision_table():
    """The pure decision core of the REPRO_KERNEL_COMPILED contract."""
    from repro.compat import _interpret_for
    assert _interpret_for("cpu", None) is True
    assert _interpret_for("cpu", "1") is True    # no compiler on CPU
    assert _interpret_for("tpu", None) is False  # Mosaic native
    assert _interpret_for("tpu", "0") is True    # forced-interpret debug
    assert _interpret_for("gpu", None) is True   # opt-in only
    assert _interpret_for("gpu", "1") is False   # the hardware run
    assert _interpret_for("gpu", "0") is True


# ---------------------------------------------------------------------------
# engine integration: ScanFlexAI(td_kernel=...)
# ---------------------------------------------------------------------------

def _engine_setup():
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.flexai import FlexAIConfig
    from repro.core.hmai import HMAIPlatform
    q = build_task_queue(EnvironmentParams(
        route_km=0.06, rate_scale=0.05, seed=9, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0))
    plat = HMAIPlatform(capacity_scale=0.05)
    cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=4,
                       target_sync_every=10, seed=3)
    return plat, cfg, q


def test_scanflexai_td_kernel_off_bit_identical():
    """td_kernel=False IS the default trainer: same compiled trace, so
    the episode trajectory must match bit-exactly."""
    from repro.core.flexai import ScanFlexAI
    plat, cfg, q = _engine_setup()
    t_def = ScanFlexAI(plat, cfg)
    t_off = ScanFlexAI(plat, cfg, td_kernel=False)
    t_def.train_episode(q)
    t_off.train_episode(q)
    for name, a, b in zip(t_def.ts.eval_p._fields, t_def.ts.eval_p,
                          t_off.ts.eval_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_scanflexai_td_kernel_default_trace_has_no_pallas():
    """The off switch must COMPILE OUT: the default episode jaxpr may not
    contain a pallas_call (the no-regression guarantee for the default
    path is structural, not just a timing)."""
    from repro.core.flexai.engine import make_train_fn, train_init
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import tasks_to_arrays
    plat, cfg, q = _engine_setup()
    spec = spec_from_platform(plat)
    ts = train_init(jax.random.PRNGKey(0), 3 + 5 * plat.n, plat.n,
                    cfg.replay_capacity)
    ta = tasks_to_arrays(q)
    jaxpr_off = jax.make_jaxpr(make_train_fn(spec, cfg))(ts, ta)
    assert "pallas_call" not in str(jaxpr_off)
    jaxpr_on = jax.make_jaxpr(
        make_train_fn(spec, cfg, td_kernel=True))(ts, ta)
    assert "pallas_call" in str(jaxpr_on)


def test_scanflexai_td_kernel_trains_at_parity():
    """The acceptance pin at the ScanFlexAI surface: a full fused episode
    (dozens of in-scan TD updates + TargNet syncs + greedy acting off the
    updated params) stays within 1e-5 of the default trainer on losses
    and final EvalNet params."""
    from repro.core.flexai import ScanFlexAI
    plat, cfg, q = _engine_setup()
    t_ref = ScanFlexAI(plat, cfg)
    t_ker = ScanFlexAI(plat, cfg, td_kernel=True)
    s_ref = t_ref.train_episode(q)
    s_ker = t_ker.train_episode(q)
    assert len(t_ref.losses) >= 30, "route too short to exercise updates"
    assert len(t_ker.losses) == len(t_ref.losses)
    np.testing.assert_allclose(np.asarray(t_ker.losses),
                               np.asarray(t_ref.losses),
                               rtol=1e-5, atol=1e-5)
    for name, a, b in zip(t_ref.ts.eval_p._fields, t_ref.ts.eval_p,
                          t_ker.ts.eval_p):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
    assert s_ker["stm_rate"] == pytest.approx(s_ref["stm_rate"], abs=1e-6)


def test_scanflexai_td_kernel_dp_path():
    """DP trainer (shared agent, per-lane grads + mean + shared Adam)
    with the kernel grads variant walks the oracle DP trajectory."""
    from repro.core.flexai import ScanFlexAI
    plat, cfg, q = _engine_setup()
    t_ref = ScanFlexAI(plat, cfg, lanes=2, dp=True)
    t_ker = ScanFlexAI(plat, cfg, lanes=2, dp=True, td_kernel=True)
    t_ref.train_episode([q, q])
    t_ker.train_episode([q, q])
    assert len(t_ref.losses) >= 10
    assert len(t_ker.losses) == len(t_ref.losses)
    np.testing.assert_allclose(np.asarray(t_ker.losses),
                               np.asarray(t_ref.losses),
                               rtol=1e-5, atol=1e-5)
    for name, a, b in zip(t_ref.ts.eval_p._fields, t_ref.ts.eval_p,
                          t_ker.ts.eval_p):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
