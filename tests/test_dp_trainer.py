"""Data-parallel fused trainer + FlexAIAgent<->ScanFlexAI weight interop.

Contracts:

* lossless weight round-trip across the two training worlds (bit-exact
  params, identical greedy placements), through objects and through the
  shared npz checkpoint format;
* the DP trainer with 1 shard / 1 lane reproduces the unsharded fused
  trainer's TrainState trajectory (identical actions and counters,
  params to fp32 tolerance);
* the shard_map'd DP trainer is a pure re-layout of the unsharded DP
  runner at equal global batch (subprocess: forced host devices must be
  set before jax imports);
* eval-based model selection on the scan path keeps the best-eval
  weights.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.flexai import (FlexAIAgent, FlexAIConfig, ScanFlexAI,
                               dp_train_init, make_dp_train_fn,
                               make_train_fn, train_init)
from repro.core.hmai import HMAIPlatform
from repro.core.platform_jax import spec_from_platform
from repro.core.tasks import (TaskArrays, stack_task_arrays,
                              tasks_to_arrays)

RS = 0.05


def _queue(seed, km=0.02):
    return build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0))


def _platform():
    return HMAIPlatform(capacity_scale=RS)


def _cfg(**over):
    kw = dict(min_replay=32, batch_size=16, update_every=2,
              eps_decay_steps=500, replay_capacity=2048, seed=2)
    kw.update(over)
    return FlexAIConfig(**kw)


# ---------------------------------------------------------------------------
# weight interop
# ---------------------------------------------------------------------------

def test_agent_scan_agent_roundtrip_bit_exact():
    """FlexAIAgent -> ScanFlexAI -> FlexAIAgent preserves params
    bit-exactly and produces identical greedy placements."""
    q = _queue(33)
    agent = FlexAIAgent(_platform(), _cfg())
    trainer = ScanFlexAI.from_agent(agent, _platform())
    back = trainer.to_agent(_platform())
    for a, b in zip(agent.learner.eval_p, back.learner.eval_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s_agent = agent.schedule_scan(_platform(), q)
    s_scan = trainer.schedule(q)
    s_back = back.schedule_scan(_platform(), q)
    np.testing.assert_array_equal(s_agent["placements"],
                                  s_scan["placements"])
    np.testing.assert_array_equal(s_agent["placements"],
                                  s_back["placements"])


def test_npz_checkpoint_shared_format(tmp_path):
    """ScanFlexAI reads/writes FlexAIAgent's npz checkpoint format in
    both directions, bit-exactly — including the DP and population
    wrappers (broadcast import)."""
    path = str(tmp_path / "w.npz")
    trainer = ScanFlexAI(_platform(), _cfg())
    trainer.train_episode(_queue(31))
    trainer.save_weights(path)

    agent = FlexAIAgent(_platform(), _cfg())
    agent.load_weights(path)
    for a, b in zip(trainer.eval_params(), agent.learner.eval_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    agent_path = str(tmp_path / "a.npz")
    agent.save_weights(agent_path)
    for wrapper in (ScanFlexAI(_platform(), _cfg()),
                    ScanFlexAI(_platform(), _cfg(), lanes=2, dp=True),
                    ScanFlexAI(_platform(), _cfg(), lanes=2)):
        wrapper.load_weights(agent_path)
        for lane in range(1 if wrapper.dp else wrapper.lanes):
            for a, b in zip(wrapper.eval_params(lane),
                            trainer.eval_params()):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))


# ---------------------------------------------------------------------------
# DP trainer parity
# ---------------------------------------------------------------------------

def test_dp_one_shard_matches_unsharded_fused_trainer():
    """make_dp_train_fn with 1 lane and no mesh walks the same TrainState
    trajectory as make_train_fn: identical actions, update cadence and
    counters; params/losses to fp32 tolerance (batched-vs-vector matmul
    shapes round differently at the ulp level)."""
    q = _queue(21)
    plat = _platform()
    spec = spec_from_platform(plat)
    cfg = _cfg()
    ta = tasks_to_arrays(q)
    state_dim = 3 + 5 * plat.n
    key = jax.random.PRNGKey(cfg.seed)

    ts_s, _, recs_s, loss_s, upd_s = make_train_fn(spec, cfg)(
        train_init(key, state_dim, plat.n, cfg.replay_capacity), ta)
    ts_d, _, recs_d, loss_d, upd_d = make_dp_train_fn(spec, cfg, 1)(
        dp_train_init(key, state_dim, plat.n, cfg.replay_capacity, 1),
        TaskArrays(*[np.asarray(f)[None] for f in ta]))

    np.testing.assert_array_equal(np.asarray(recs_s.action),
                                  np.asarray(recs_d.action)[0])
    np.testing.assert_array_equal(np.asarray(upd_s, bool),
                                  np.asarray(upd_d, bool))
    assert int(ts_s.env_steps) == int(ts_d.env_steps) == len(q)
    assert int(ts_s.updates) == int(ts_d.updates)
    np.testing.assert_allclose(np.asarray(loss_s), np.asarray(loss_d),
                               atol=1e-4)
    for a, b in zip(ts_s.eval_p, ts_d.eval_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4)


def test_dp_chunked_collectives_match_legacy_trajectory():
    """``chunk_collectives=True`` (one 2-float stats psum per step; grads
    + pmean + adam only inside the update-step cond) must walk the same
    trajectory as the legacy every-step-pmean path at equal global batch:
    identical actions and update cadence, losses/params to fp32 tolerance
    (the cond-inlined vs always-on graphs fuse differently at ulp level)."""
    plat = _platform()
    spec = spec_from_platform(plat)
    cfg = _cfg()
    batch = stack_task_arrays(
        [tasks_to_arrays(_queue(s)) for s in (21, 22)])
    sd = 3 + 5 * plat.n
    ts0 = dp_train_init(jax.random.PRNGKey(cfg.seed), sd, plat.n,
                        cfg.replay_capacity, 2)
    ts_c, _, recs_c, loss_c, upd_c = make_dp_train_fn(
        spec, cfg, 2, chunk_collectives=True)(ts0, batch)
    ts_l, _, recs_l, loss_l, upd_l = make_dp_train_fn(
        spec, cfg, 2, chunk_collectives=False)(ts0, batch)
    np.testing.assert_array_equal(np.asarray(recs_c.action),
                                  np.asarray(recs_l.action))
    np.testing.assert_array_equal(np.asarray(upd_c, bool),
                                  np.asarray(upd_l, bool))
    assert int(ts_c.env_steps) == int(ts_l.env_steps)
    assert int(ts_c.updates) == int(ts_l.updates) > 0
    np.testing.assert_allclose(np.asarray(loss_c), np.asarray(loss_l),
                               atol=1e-4)
    for a, b in zip(ts_c.eval_p, ts_l.eval_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4)


@pytest.mark.slow
def test_dp_sharded_matches_unsharded_equal_global_batch():
    """2-device shard_map DP == unsharded DP on the same 4-route global
    batch: identical action trajectory, params to accumulated-fp32
    tolerance (pmean reduction order vs the local lane mean)."""
    script = textwrap.dedent("""
        import jax
        import numpy as np
        from repro.compat import make_mesh
        from repro.core.environment import EnvironmentParams, \\
            build_task_queue
        from repro.core.flexai import (FlexAIConfig, dp_train_init,
                                       make_dp_train_fn)
        from repro.core.hmai import HMAIPlatform
        from repro.core.platform_jax import spec_from_platform
        from repro.core.tasks import stack_task_arrays, tasks_to_arrays

        RS = 0.05
        def queue(seed):
            return build_task_queue(EnvironmentParams(
                route_km=0.02, rate_scale=RS, seed=seed, max_times_turn=2,
                max_times_reverse=1, max_duration_turn=4.0,
                max_duration_reverse=6.0))
        plat = HMAIPlatform(capacity_scale=RS)
        spec = spec_from_platform(plat)
        cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=2,
                           eps_decay_steps=500, replay_capacity=2048,
                           seed=2)
        batch = stack_task_arrays(
            [tasks_to_arrays(queue(s)) for s in (21, 22, 23, 24)])
        sd = 3 + 5 * plat.n
        ts0 = dp_train_init(jax.random.PRNGKey(cfg.seed), sd, plat.n,
                            cfg.replay_capacity, 4)
        o_u = jax.block_until_ready(
            make_dp_train_fn(spec, cfg, 4)(ts0, batch))
        mesh = make_mesh((2,), ("routes",))
        o_s = jax.block_until_ready(
            make_dp_train_fn(spec, cfg, 4, mesh=mesh)(ts0, batch))
        assert np.array_equal(np.asarray(o_u[2].action),
                              np.asarray(o_s[2].action))
        assert int(o_u[0].env_steps) == int(o_s[0].env_steps)
        assert int(o_u[0].updates) == int(o_s[0].updates)
        np.testing.assert_allclose(np.asarray(o_u[3]), np.asarray(o_s[3]),
                                   atol=1e-3)
        for a, b in zip(o_u[0].eval_p, o_s[0].eval_p):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-3)
        print("OK", int(o_u[0].env_steps))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_dp_wrapper_trains_one_synchronized_agent():
    """ScanFlexAI(dp=True): one shared parameter set over the route
    batch (no per-lane weight axis), counters track the global batch,
    losses flow, greedy schedule works."""
    cfg = _cfg()
    trainer = ScanFlexAI(_platform(), cfg, lanes=2, dp=True)
    routes = [_queue(31), _queue(32)]
    out = trainer.train(routes, episodes=1)[0]
    assert len(out["lanes"]) == 2
    for lane in out["lanes"]:
        assert 0.0 <= lane["stm_rate"] <= 1.0
    # ONE agent: params have no lane axis
    assert trainer.ts.eval_p.w1.ndim == 2
    assert int(trainer.ts.env_steps) == sum(len(r) for r in routes)
    assert trainer.losses and np.isfinite(trainer.losses).all()
    s = trainer.schedule(routes[0])
    assert s["tasks"] == len(routes[0])


# ---------------------------------------------------------------------------
# eval-based model selection
# ---------------------------------------------------------------------------

def test_eval_selection_keeps_best_params():
    """train(eval_queue=...) records eval_stm on the cadence and restores
    the best-eval weights into EvalNet/TargNet at the end."""
    cfg = _cfg()
    val_q = tasks_to_arrays(_queue(50))
    trainer = ScanFlexAI(_platform(), cfg)
    hist = trainer.train([_queue(1), _queue(2)], episodes=4,
                         eval_queue=val_q, eval_every=2)
    evals = [h["eval_stm"] for h in hist if "eval_stm" in h]
    assert len(evals) == 2
    assert trainer.best_eval_stm == pytest.approx(max(evals))
    # the restored params reproduce the best recorded eval STM
    final, recs = trainer._sched_fn(trainer.eval_params(), val_q)
    from repro.core.platform_jax import summarize
    stm = summarize(trainer.spec, final, recs)["stm_rate"]
    assert stm == pytest.approx(trainer.best_eval_stm, abs=1e-9)
    # TargNet synced to the winner
    for a, b in zip(trainer.ts.eval_p, trainer.ts.targ_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_eval_selection_population_lanes():
    """Population training evaluates every lane and installs the best
    lane's weights everywhere at the end."""
    cfg = _cfg()
    trainer = ScanFlexAI(_platform(), cfg, lanes=2)
    hist = trainer.train([_queue(1), _queue(2), _queue(3), _queue(4)],
                         episodes=2, eval_queue=_queue(50), eval_every=2)
    assert isinstance(hist[1]["eval_stm"], list)
    assert len(hist[1]["eval_stm"]) == 2
    assert trainer.best_eval_stm is not None
    # broadcast import: both lanes now carry the winner
    w = np.asarray(trainer.ts.eval_p.w1)
    np.testing.assert_array_equal(w[0], w[1])
