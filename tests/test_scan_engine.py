"""Scan/loop parity: the device-resident engine (platform_jax + flexai
engine + scan schedulers) must reproduce the NumPy oracle path."""
import jax
import numpy as np
import pytest

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.flexai import FlexAIAgent, FlexAIConfig, ScanFlexAI
from repro.core.flexai.engine import make_schedule_fn
from repro.core.hmai import HMAIPlatform
from repro.core.platform_jax import (platform_init, platform_step,
                                     spec_from_platform, summarize)
from repro.core.schedulers import get_scheduler, scan_schedule
from repro.core.tasks import (Task, TaskKind, pad_task_arrays,
                              stack_task_arrays, tasks_to_arrays)

RS = 0.05


def _queue(seed, km=0.06):
    return build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0))


def _platform():
    return HMAIPlatform(capacity_scale=RS)


# ---------------------------------------------------------------------------
# platform_step vs HMAIPlatform.execute
# ---------------------------------------------------------------------------

def test_platform_step_matches_execute():
    rng = np.random.default_rng(0)
    plat = _platform()
    spec = spec_from_platform(plat)
    state = platform_init(plat.n)
    step = jax.jit(platform_step)
    t = 0.0
    for uid in range(120):
        t += float(rng.uniform(0, 0.005))
        kind = [TaskKind.YOLO, TaskKind.SSD, TaskKind.GOTURN][uid % 3]
        task = Task(uid=uid, kind=kind, camera_group="FC", camera_id=0,
                    arrival_time=t, safety_time=0.05)
        a = int(rng.integers(0, plat.n))
        rec_np = plat.execute(task, a)
        ta = tasks_to_arrays([task])
        row = jax.tree_util.tree_map(lambda x: x[0], ta)
        state, rec = step(spec, state, row, np.int32(a))
        np.testing.assert_allclose(float(rec.response),
                                   rec_np.response_time, rtol=1e-5)
        np.testing.assert_allclose(float(rec.ms), rec_np.ms, rtol=1e-5)
        np.testing.assert_allclose(float(rec.energy), rec_np.energy,
                                   rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state.avail), plat.avail,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state.E), plat.E, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state.T), plat.T, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state.MS), plat.MS, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.R_Balance), plat.R_Balance,
                               rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(state.num_tasks),
                                  plat.num_tasks)


# ---------------------------------------------------------------------------
# greedy inference parity (the ISSUE-1 acceptance test)
# ---------------------------------------------------------------------------

def test_schedule_scan_parity_with_loop():
    """Same weights -> same placements, STM rate and Gvalue as the Python
    loop, to fp32 tolerance."""
    q = _queue(7)
    assert len(q) > 200
    agent = FlexAIAgent(_platform(), FlexAIConfig(seed=3))

    p_loop = _platform()
    loop = agent.schedule(p_loop, q)
    loop_placements = np.asarray([r.accel_index for r in p_loop.records])

    scan = agent.schedule_scan(_platform(), q)

    np.testing.assert_array_equal(scan["placements"], loop_placements)
    assert scan["stm_rate"] == pytest.approx(loop["stm_rate"], abs=1e-6)
    assert scan["gvalue"] == pytest.approx(loop["gvalue"], rel=1e-4)
    assert scan["makespan_s"] == pytest.approx(loop["makespan_s"], rel=1e-4)
    assert scan["total_energy_j"] == pytest.approx(loop["total_energy_j"],
                                                   rel=1e-4)
    assert scan["total_ms"] == pytest.approx(loop["total_ms"], rel=1e-3)


@pytest.mark.parametrize("name", ["worst", "ata", "minmin"])
def test_heuristic_scan_parity(name):
    q = _queue(11)
    loop = get_scheduler(name).schedule(_platform(), q)
    scan = scan_schedule(name, _platform(), q)
    assert scan["tasks"] == loop["tasks"] == len(q)
    assert scan["stm_rate"] == pytest.approx(loop["stm_rate"], abs=5e-3)
    assert scan["makespan_s"] == pytest.approx(loop["makespan_s"], rel=1e-3)
    assert scan["total_energy_j"] == pytest.approx(loop["total_energy_j"],
                                                   rel=2e-3)
    assert scan["r_balance"] == pytest.approx(loop["r_balance"], abs=2e-3)


def test_state_to_platform_restores_oracle():
    """state_from_platform -> state_to_platform round-trips every §7.2
    field, and a restored oracle continues a route exactly like the
    uninterrupted one (the NumPy half of the serving preemption seam)."""
    from repro.core.platform_jax import state_from_platform, state_to_platform
    q = _queue(3, km=0.02)
    cut = len(q) // 2
    agent = FlexAIAgent(_platform(), FlexAIConfig(seed=4))
    p_full = _platform()
    agent.schedule(p_full, q)
    p_head = _platform()
    agent.schedule(p_head, q[:cut])
    p_resume = _platform()
    state_to_platform(state_from_platform(p_head), p_resume)
    np.testing.assert_allclose(p_resume.avail, p_head.avail, rtol=1e-6)
    np.testing.assert_allclose(p_resume.MS, p_head.MS, rtol=1e-6)
    np.testing.assert_array_equal(p_resume.num_tasks, p_head.num_tasks)
    agent.schedule(p_resume, q[cut:])
    np.testing.assert_allclose(p_resume.avail, p_full.avail, rtol=1e-5)
    np.testing.assert_allclose(p_resume.E, p_full.E, rtol=1e-5)
    np.testing.assert_allclose(p_resume.T, p_full.T, rtol=1e-5)
    np.testing.assert_array_equal(p_resume.num_tasks, p_full.num_tasks)


# ---------------------------------------------------------------------------
# scheduler edge cases: empty windows, single task, all-equal ties
# (the happy-path parity above never hits these branches)
# ---------------------------------------------------------------------------

def _synthetic_tasks(n, kind=TaskKind.YOLO, arrival=0.0, safety=0.05):
    return [Task(uid=i, kind=kind, camera_group="FC", camera_id=0,
                 arrival_time=arrival, safety_time=safety)
            for i in range(n)]


@pytest.mark.parametrize("name", ["worst", "ata", "minmin"])
def test_scan_single_task_parity(name):
    """A one-task route exercises the degenerate window (29 padding rows
    in Min-Min's first window; a length-1 scan elsewhere)."""
    q = _synthetic_tasks(1)
    loop = get_scheduler(name).schedule(_platform(), q)
    scan = scan_schedule(name, _platform(), q)
    assert scan["tasks"] == loop["tasks"] == 1
    assert scan["makespan_s"] == pytest.approx(loop["makespan_s"], rel=1e-5)
    assert scan["total_energy_j"] == pytest.approx(loop["total_energy_j"],
                                                   rel=1e-5)
    assert scan["stm_rate"] == loop["stm_rate"]


@pytest.mark.parametrize("name", ["worst", "ata", "minmin"])
def test_scan_empty_window_is_noop(name):
    """Padding a route to spill whole extra windows (Min-Min) / extra scan
    steps (ATA, worst) must not change any metric: all-invalid steps pass
    the platform state through."""
    from repro.core.schedulers.scan import get_scan_scheduler
    q = _queue(13, km=0.02)
    plat = _platform()
    spec = spec_from_platform(plat)
    fn = get_scan_scheduler(name)
    ta = tasks_to_arrays(q)
    # 2 fully-invalid Min-Min windows (window=30) beyond the real tasks
    padded = pad_task_arrays(ta, ta.num_tasks + 60)
    final_a, recs_a = fn(spec, ta)
    final_b, recs_b = fn(spec, padded)
    for a, b in zip(final_a, final_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert not np.asarray(recs_b.valid)[ta.num_tasks:].any()
    s_a, s_b = (summarize(spec, f, r)
                for f, r in ((final_a, recs_a), (final_b, recs_b)))
    assert s_a["tasks"] == s_b["tasks"] == len(q)
    assert s_a["stm_rate"] == pytest.approx(s_b["stm_rate"], abs=1e-9)


@pytest.mark.parametrize("name", ["ata", "minmin"])
def test_scan_all_equal_completion_time_tiebreak(name):
    """Identical tasks tie on completion time across every window row; the
    scan path's flat argmin must break ties exactly like the loop's
    strict-< first-hit (row-major), or placements drift."""
    q = _synthetic_tasks(45)  # 1.5 Min-Min windows of identical tasks
    p_loop = _platform()
    loop = get_scheduler(name).schedule(p_loop, q)
    loop_actions = np.asarray([r.accel_index for r in p_loop.records])
    scan = scan_schedule(name, _platform(), q)
    np.testing.assert_array_equal(scan["placements"], loop_actions)
    assert scan["makespan_s"] == pytest.approx(loop["makespan_s"], rel=1e-5)
    assert scan["r_balance"] == pytest.approx(loop["r_balance"], abs=1e-5)


def test_minmin_incremental_ct_matches_rebuild():
    """The default incremental completion-time carry (row->inf + one
    column recompute per commit) must be bit-identical to rebuilding the
    full [W, n] matrix every inner step — same elementwise float
    expressions, so same flat argmin and row-major tie-break."""
    from repro.core.schedulers.scan import minmin_scan
    plat = _platform()
    spec = spec_from_platform(plat)
    inc = jax.jit(lambda s, t: minmin_scan(s, t, incremental=True))
    ref = jax.jit(lambda s, t: minmin_scan(s, t, incremental=False))
    for seed in (11, 13):
        ta = tasks_to_arrays(_queue(seed, km=0.03))
        f_i, r_i = inc(spec, ta)
        f_r, r_r = ref(spec, ta)
        np.testing.assert_array_equal(np.asarray(r_i.action),
                                      np.asarray(r_r.action))
        for a, b in zip(jax.tree_util.tree_leaves((f_i, r_i)),
                        jax.tree_util.tree_leaves((f_r, r_r))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # alive-mask reroute path: masked accelerator never chosen, still exact
    import jax.numpy as jnp
    alive = jnp.ones((spec.n,), bool).at[0].set(False)
    ta = tasks_to_arrays(_queue(17, km=0.02))
    f_i, r_i = minmin_scan(spec, ta, alive=alive, incremental=True)
    f_r, r_r = minmin_scan(spec, ta, alive=alive, incremental=False)
    np.testing.assert_array_equal(np.asarray(r_i.action),
                                  np.asarray(r_r.action))
    acts = np.asarray(r_i.action)[np.asarray(r_i.valid, bool)]
    assert not (acts == 0).any()
    for a, b in zip(jax.tree_util.tree_leaves(f_i),
                    jax.tree_util.tree_leaves(f_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# vmapped multi-route batching
# ---------------------------------------------------------------------------

def test_vmap_batch_matches_single_route():
    routes = [tasks_to_arrays(_queue(s)) for s in (1, 2)]
    plat = _platform()
    agent = FlexAIAgent(plat, FlexAIConfig(seed=5))
    spec = spec_from_platform(plat)
    params = agent.learner.eval_p

    single = make_schedule_fn(spec, agent.cfg.backlog_scale)
    batched = make_schedule_fn(spec, agent.cfg.backlog_scale, batched=True)
    batch = stack_task_arrays(routes)
    finals_b, recs_b = batched(params, batch)

    for lane, ta in enumerate(routes):
        final_s, recs_s = single(params, ta)
        n = ta.num_tasks
        np.testing.assert_array_equal(
            np.asarray(recs_b.action)[lane, :n],
            np.asarray(recs_s.action))
        np.testing.assert_allclose(
            np.asarray(jax.tree_util.tree_map(lambda a: a[lane],
                                              finals_b).T),
            np.asarray(final_s.T), rtol=1e-5)


def test_padding_is_noop():
    """Invalid rows must leave the platform state untouched."""
    ta = tasks_to_arrays(_queue(4))
    plat = _platform()
    agent = FlexAIAgent(plat, FlexAIConfig(seed=1))
    spec = spec_from_platform(plat)
    fn = make_schedule_fn(spec, agent.cfg.backlog_scale)
    final_a, recs_a = fn(agent.learner.eval_p, ta)
    padded = pad_task_arrays(ta, ta.num_tasks + 37)
    final_b, recs_b = fn(agent.learner.eval_p, padded)
    for a, b in zip(final_a, final_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert not np.asarray(recs_b.valid)[ta.num_tasks:].any()
    s_a = summarize(spec, final_a, recs_a)
    s_b = summarize(spec, final_b, recs_b)
    assert s_a["tasks"] == s_b["tasks"]
    assert s_a["stm_rate"] == pytest.approx(s_b["stm_rate"], abs=1e-9)


# ---------------------------------------------------------------------------
# fused training episode
# ---------------------------------------------------------------------------

def test_train_episode_scan_smoke():
    q = _queue(21, km=0.03)
    cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=2,
                       eps_decay_steps=500, replay_capacity=4096, seed=2)
    trainer = ScanFlexAI(_platform(), cfg)
    summ = trainer.train_episode(q)
    assert summ["tasks"] == len(q)
    assert 0.0 <= summ["stm_rate"] <= 1.0
    assert int(trainer.ts.env_steps) == len(q)
    assert int(trainer.ts.replay.size) == min(len(q), 4096)
    assert trainer.losses and np.isfinite(trainer.losses).all()
    assert summ["mean_loss"] is not None
    # counters persist across episodes (epsilon keeps decaying)
    trainer.train_episode(q)
    assert int(trainer.ts.env_steps) == 2 * len(q)


def test_schedule_scan_cache_not_shared_across_platforms():
    """Two platforms with equal n but different hardware tables must not
    reuse one compiled closure (regression: cache keyed only on n)."""
    q = _queue(17, km=0.03)
    agent = FlexAIAgent(_platform(), FlexAIConfig(seed=9))
    p_fast = HMAIPlatform(capacity_scale=RS)
    p_slow = HMAIPlatform(capacity_scale=RS / 4)
    assert p_fast.n == p_slow.n
    agent.schedule_scan(p_fast, q)  # populate the cache
    scan = agent.schedule_scan(p_slow, q)
    p_ref = HMAIPlatform(capacity_scale=RS / 4)
    loop = agent.schedule(p_ref, q)
    assert scan["makespan_s"] == pytest.approx(loop["makespan_s"], rel=1e-4)
    np.testing.assert_array_equal(
        scan["placements"], [r.accel_index for r in p_ref.records])


def test_train_episode_padded_route_matches_unpadded():
    """Padding rows must not shift the terminal transition: the replay
    ring holds exactly one done=1 row per episode either way."""
    q = _queue(23, km=0.02)
    cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=4,
                       eps_decay_steps=500, replay_capacity=4096, seed=8)
    plain = ScanFlexAI(_platform(), cfg)
    plain.train_episode(tasks_to_arrays(q))
    padded = ScanFlexAI(_platform(), cfg)
    padded.train_episode(pad_task_arrays(tasks_to_arrays(q), len(q) + 50))
    assert int(plain.ts.env_steps) == int(padded.ts.env_steps) == len(q)
    assert int(plain.ts.replay.size) == int(padded.ts.replay.size)
    for tr in (plain, padded):
        done = np.asarray(tr.ts.replay.done)[: int(tr.ts.replay.size)]
        assert done.sum() == pytest.approx(1.0)


def test_train_vmapped_lanes_smoke():
    routes = [_queue(31, km=0.03), _queue(32, km=0.03)]
    cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=4,
                       eps_decay_steps=500, replay_capacity=2048, seed=4)
    trainer = ScanFlexAI(_platform(), cfg, lanes=2)
    out = trainer.train(routes, episodes=1)[0]  # round-robins lanes
    assert len(out["lanes"]) == 2
    for lane in out["lanes"]:
        assert 0.0 <= lane["stm_rate"] <= 1.0
    # lanes are independent seeds: EvalNet weights must differ
    w0 = np.asarray(trainer.ts.eval_p.w1)[0]
    w1 = np.asarray(trainer.ts.eval_p.w1)[1]
    assert not np.allclose(w0, w1)
    # greedy schedule from a trained lane works
    s = trainer.schedule(routes[0], lane=1)
    assert s["tasks"] == len(routes[0])


# ---------------------------------------------------------------------------
# serving path
# ---------------------------------------------------------------------------

def test_placement_service_buckets_and_trims():
    from repro.serve.engine import FlexAIPlacementService
    plat = _platform()
    agent = FlexAIAgent(plat, FlexAIConfig(seed=6))
    svc = FlexAIPlacementService(plat, agent.learner.eval_p, min_bucket=64)
    queues = [_queue(41, km=0.02), _queue(42, km=0.03), _queue(43, km=0.02)]
    results = svc.place(queues)
    assert len(results) == len(queues)
    for q, r in zip(queues, results):
        assert r["tasks"] == len(q)
        assert r["placements"].shape == (len(q),)
        assert r["bucket"] >= len(q)
    # same-bucket queues share a dispatch
    assert svc.dispatches == len({r["bucket"] for r in results})


def test_placement_service_routes_tight_deadlines_to_fused_path():
    """With a deadline vector, requests whose slack is under
    ``tight_slack_s`` dispatch solo through the fused scan path and the
    rest co-batch — with identical placements either way."""
    from repro.serve.engine import FlexAIPlacementService
    plat = _platform()
    agent = FlexAIAgent(plat, FlexAIConfig(seed=6))
    queues = [_queue(41, km=0.02), _queue(42, km=0.02), _queue(43, km=0.02)]
    base = FlexAIPlacementService(plat, agent.learner.eval_p, min_bucket=64)
    ref = base.place(queues)
    svc = FlexAIPlacementService(plat, agent.learner.eval_p, min_bucket=64,
                                 tight_slack_s=0.05)
    results = svc.place(queues, deadlines=[0.01, 10.0, 10.0], now=0.0)
    assert results[0]["path"] == "fused"
    assert results[1]["path"] == results[2]["path"] == "batched"
    assert svc.fused_dispatches == 1
    for r, rr in zip(ref, results):
        np.testing.assert_array_equal(r["placements"], rr["placements"])
        assert r["stm_rate"] == pytest.approx(rr["stm_rate"], abs=1e-9)
    # no deadline vector -> unchanged batched behaviour
    plain = svc.place(queues)
    assert all(r["path"] == "batched" for r in plain)


# ---------------------------------------------------------------------------
# cached exec-time table (satellite)
# ---------------------------------------------------------------------------

def test_exec_time_table_matches_specs():
    plat = _platform()
    from repro.core.tasks import KIND_ORDER
    for i, spec in enumerate(plat.specs):
        for j, kind in enumerate(KIND_ORDER):
            assert plat.exec_time_table[i, j] == pytest.approx(
                spec.exec_time(kind))
            assert plat.energy_table[i, j] == pytest.approx(
                spec.energy(kind))
