"""Device GA/SA parity vs the NumPy oracles: window fitness against
``ga._evaluate``, committed placements against ``HMAIPlatform.execute``,
and the vmap/shard_map layout invariances."""
import jax
import numpy as np
import pytest

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.hmai import HMAIPlatform
from repro.core.platform_jax import (spec_from_platform, state_from_platform,
                                     summarize)
from repro.core.schedulers import (GAConfig, SAConfig, get_scheduler,
                                   make_metaheuristic_fn,
                                   make_sharded_metaheuristic_fn,
                                   metaheuristic_schedule, window_fitness)
from repro.core.schedulers.ga import _evaluate
from repro.core.tasks import pad_task_arrays, stack_task_arrays, \
    tasks_to_arrays

RS = 0.05


def _queue(seed, km=0.05):
    return build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0))


def _platform():
    return HMAIPlatform(capacity_scale=RS)


# ---------------------------------------------------------------------------
# window fitness vs ga._evaluate
# ---------------------------------------------------------------------------

def test_window_fitness_matches_oracle():
    """Fixed-seed fitness parity from a warm mid-route snapshot (the
    ISSUE-3 acceptance bar: <= 1e-4 relative)."""
    q = _queue(7)
    plat = _platform()
    rng = np.random.default_rng(0)
    for t in q[:60]:
        plat.execute(t, int(rng.integers(0, plat.n)))
    spec = spec_from_platform(plat)
    snap = state_from_platform(plat)
    window = q[60:90]
    wa = tasks_to_arrays(window)
    fit = jax.jit(lambda a: window_fitness(spec, snap, wa, a))
    for _ in range(16):
        assign = rng.integers(0, plat.n, len(window))
        ref = _evaluate(plat, window, assign)
        dev = float(fit(np.asarray(assign, np.int32)))
        assert dev == pytest.approx(ref, rel=1e-4)


def test_window_fitness_ignores_padding():
    q = _queue(9)
    plat = _platform()
    spec = spec_from_platform(plat)
    snap = state_from_platform(plat)
    window = q[:20]
    wa = tasks_to_arrays(window)
    wa_pad = pad_task_arrays(wa, 32)
    rng = np.random.default_rng(1)
    assign = np.asarray(rng.integers(0, plat.n, 20), np.int32)
    assign_pad = np.concatenate([assign,
                                 np.zeros(12, np.int32)])
    a = float(window_fitness(spec, snap, wa, assign))
    b = float(window_fitness(spec, snap, wa_pad, assign_pad))
    assert a == pytest.approx(b, rel=1e-6)


# ---------------------------------------------------------------------------
# committed placements vs the HMAIPlatform oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["ga", "sa"])
def test_device_commit_matches_oracle_replay(name):
    """Replaying the device search's placements through the NumPy
    platform must land on the same metrics — the commit path and the
    oracle agree on the §7.2 semantics."""
    q = _queue(11)
    summ = metaheuristic_schedule(name, _platform(), q, seed=3)
    assert summ["tasks"] == len(q)
    placements = summ["placements"]
    assert placements.shape == (len(q),)
    oracle = _platform()
    for task, a in zip(q, placements):
        oracle.execute(task, int(a))
    ref = oracle.summary()
    assert summ["makespan_s"] == pytest.approx(ref["makespan_s"], rel=1e-4)
    assert summ["total_energy_j"] == pytest.approx(ref["total_energy_j"],
                                                   rel=1e-4)
    assert summ["stm_rate"] == pytest.approx(ref["stm_rate"], abs=1e-6)
    assert summ["r_balance"] == pytest.approx(ref["r_balance"], abs=2e-3)


def test_device_ga_quality_comparable_to_numpy_ga():
    """Same fitness function, same budget: the device GA's Table-11 cost
    (makespan + 0.1 * energy) must land in the NumPy GA's ballpark."""
    q = _queue(13)
    dev = metaheuristic_schedule("ga", _platform(), q, seed=0)
    ref = get_scheduler("ga").schedule(_platform(), q)
    cost = lambda s: s["makespan_s"] + 0.1 * s["total_energy_j"]
    assert cost(dev) <= cost(ref) * 1.05


# ---------------------------------------------------------------------------
# layout invariances
# ---------------------------------------------------------------------------

def test_batched_matches_single_route():
    routes = [tasks_to_arrays(_queue(s, km=0.03)) for s in (1, 2)]
    spec = spec_from_platform(_platform())
    cfg = GAConfig(generations=4)
    single = make_metaheuristic_fn(spec, "ga", cfg)
    batched = make_metaheuristic_fn(spec, "ga", cfg, batched=True)
    batch = stack_task_arrays(routes)
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    finals_b, recs_b = batched(keys, batch)
    for lane, ta in enumerate(routes):
        final_s, recs_s = single(keys[lane], ta)
        n = ta.num_tasks
        np.testing.assert_array_equal(
            np.asarray(recs_b.action)[lane, :n][
                np.asarray(recs_b.valid)[lane, :n]],
            np.asarray(recs_s.action)[np.asarray(recs_s.valid)])
        np.testing.assert_allclose(
            np.asarray(jax.tree_util.tree_map(lambda a: a[lane],
                                              finals_b).T),
            np.asarray(final_s.T), rtol=1e-5)


def test_sharded_matches_batched():
    """shard_map over a 1-lane-per-device mesh is a pure re-layout."""
    from repro.compat import make_mesh
    n_dev = len(jax.devices())
    routes = [tasks_to_arrays(_queue(20 + s, km=0.03))
              for s in range(n_dev)]
    spec = spec_from_platform(_platform())
    cfg = SAConfig(iters=16, chains=2)
    batch = stack_task_arrays(routes)
    keys = jax.random.split(jax.random.PRNGKey(8), n_dev)
    batched = make_metaheuristic_fn(spec, "sa", cfg, batched=True)
    mesh = make_mesh((n_dev,), ("routes",))
    sharded = make_sharded_metaheuristic_fn(spec, "sa", mesh, cfg)
    f_b, r_b = jax.device_get(batched(keys, batch))
    f_s, r_s = jax.device_get(sharded(keys, batch))
    np.testing.assert_array_equal(np.asarray(r_s.action),
                                  np.asarray(r_b.action))
    for a, b in zip(f_s, f_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_state0_resume_continues_route():
    """Scheduling from a resumed state must match the oracle replay of the
    same placements over the concatenated queue."""
    q = _queue(31, km=0.03)
    cut = len(q) // 2
    spec = spec_from_platform(_platform())
    fn = make_metaheuristic_fn(spec, "ga", GAConfig(generations=3))
    key = jax.random.PRNGKey(2)
    final1, recs1 = fn(key, tasks_to_arrays(q[:cut]))
    final2, recs2 = fn(key, tasks_to_arrays(q[cut:]), final1)
    placements = np.concatenate([
        np.asarray(recs1.action)[np.asarray(recs1.valid)],
        np.asarray(recs2.action)[np.asarray(recs2.valid)]])
    oracle = _platform()
    for task, a in zip(q, placements):
        oracle.execute(task, int(a))
    summ = summarize(spec, final2, recs2)
    assert summ["makespan_s"] == pytest.approx(oracle.makespan, rel=1e-4)
    np.testing.assert_allclose(np.asarray(final2.avail), oracle.avail,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# serving edge case (satellite)
# ---------------------------------------------------------------------------

def test_placement_service_empty_input():
    from repro.core.flexai import FlexAIAgent, FlexAIConfig
    from repro.serve.engine import FlexAIPlacementService
    plat = _platform()
    agent = FlexAIAgent(plat, FlexAIConfig(seed=1))
    svc = FlexAIPlacementService(plat, agent.learner.eval_p)
    assert svc.place([]) == []
    assert svc.dispatches == 0
