import jax
import pytest

# Tests run on the single host CPU device (the 512-device fleet exists only
# inside launch/dryrun.py).  Multi-device sharding tests spawn subprocesses
# with their own XLA_FLAGS.
jax.config.update("jax_platform_name", "cpu")


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (multi-device subprocess parity runs)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: slow multi-device subprocess test, skipped unless --runslow "
        "(CI runs them; tier-1 stays fast)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow subprocess test: use --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def fixed_seed() -> int:
    """Deflaking seam for seed-sensitive serving tests: one fixed seed, so
    workload generation is identical across runs and machines."""
    return 1234
