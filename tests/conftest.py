import jax
import pytest

# Tests run on the single host CPU device (the 512-device fleet exists only
# inside launch/dryrun.py).  Multi-device sharding tests spawn subprocesses
# with their own XLA_FLAGS.
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
