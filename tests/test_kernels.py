"""Per-kernel correctness: shape/dtype sweeps vs pure-jnp oracles.

Execution mode follows the hardware-run protocol
(``repro.kernels.protocol``): interpret mode on CPU hosts (the kernel
body executes as XLA ops), compiled Mosaic/Triton when
``REPRO_KERNEL_COMPILED=1`` runs this suite on a TPU/GPU host — same
tests, same tolerances, real tiles."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv_dataflow import conv2d, conv2d_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.protocol import compiled_available
from repro.kernels.ssd_scan import ssd_ref, ssd_scan

KEY = jax.random.PRNGKey(3)
INTERPRET = not compiled_available()

_TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
        jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


CONV_SHAPES = [
    (1, 8, 8, 4, 8, 3),
    (2, 12, 10, 8, 16, 5),
    (1, 6, 6, 3, 5, 1),
    (2, 16, 16, 16, 32, 3),
]


@pytest.mark.parametrize("dataflow", ["SconvOD", "SconvIC", "MconvMC"])
@pytest.mark.parametrize("shape", CONV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv_dataflow_vs_oracle(dataflow, shape, dtype):
    n, h, w_, ci, co, k = shape
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (n, h, w_, ci), jnp.float32)
    w = jax.random.normal(k2, (k, k, ci, co), jnp.float32) * 0.2
    ref = conv2d_ref(x, w)
    out = conv2d(x.astype(dtype), w.astype(dtype), dataflow=dataflow,
                 interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_TOL[dtype])


def test_conv_same_padding_and_stride():
    x = jax.random.normal(KEY, (1, 9, 9, 4))
    w = jax.random.normal(KEY, (3, 3, 4, 8)) * 0.2
    out = conv2d(x, w, dataflow="MconvMC", padding="SAME", stride=2,
                 interpret=INTERPRET)
    assert out.shape == (1, 5, 5, 8)


def test_sconv_direct_calls_with_indivisible_tiles():
    """Tiles that don't divide the dim keep the REQUESTED tile: sconv_ic
    pads the output-row grid (masked tail band), sconv_od zero-pads the
    channel axis — neither degrades to a smaller divisor tile."""
    from repro.kernels.conv_dataflow.sconv_ic import sconv_ic
    from repro.kernels.conv_dataflow.sconv_od import sconv_od
    k1, k2 = jax.random.split(KEY)
    # ho = 9 with row_tile=8 and cin = 6 with cin_tile=4: the requested
    # tile does NOT divide the dim even after the min() clamp
    x = jax.random.normal(k1, (1, 11, 8, 6), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 6, 8), jnp.float32) * 0.2
    ref = conv2d_ref(x, w)
    out_ic = sconv_ic(x, w, row_tile=8, interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(out_ic), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    out_od = sconv_od(x, w, cin_tile=4, interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(out_od), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ho", [13, 7, 23])
def test_sconv_prime_output_heights_keep_requested_tile(ho):
    """Prime output heights used to degrade the sconv_ic grid to
    row_tile=1 (one grid step per output row) and sconv_od to whatever
    divisor survived; both now pad to the requested tile and stay
    parity-exact."""
    from repro.kernels.conv_dataflow.sconv_ic import sconv_ic
    from repro.kernels.conv_dataflow.sconv_od import sconv_od
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, ho))
    x = jax.random.normal(k1, (2, ho + 2, 9, 11), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 11, 4), jnp.float32) * 0.2
    ref = conv2d_ref(x, w)
    out_ic = sconv_ic(x, w, row_tile=8, interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(out_ic), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # cin = 11 (prime) with cin_tile=8: zero-pads to 16, two grid steps
    out_od = sconv_od(x, w, cin_tile=8, interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(out_od), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sconv_ic_tall_ifmap_halo_window():
    """H = 515: the old whole-ifmap-height BlockSpec would demand the
    full ifmap resident per grid step; the halo-window kernel streams
    bounded row_tile + kh - 1 windows and must stay parity-exact,
    including the padded tail band (ho = 513 = 64 * 8 + 1)."""
    from repro.kernels.conv_dataflow.sconv_ic import sconv_ic
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (1, 515, 8, 2), jnp.float32)
    w = jax.random.normal(k2, (3, 3, 2, 4), jnp.float32) * 0.2
    out = sconv_ic(x, w, row_tile=8, interpret=INTERPRET)
    np.testing.assert_allclose(np.asarray(out), np.asarray(conv2d_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


ATTN_SHAPES = [
    (1, 64, 4, 4, 32, True),
    (2, 128, 4, 2, 16, True),
    (1, 64, 2, 1, 32, False),   # MQA
    (2, 96, 8, 8, 64, True),
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(shape, dtype):
    b, s, h, kh, d, causal = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    out = flash_attention(q.astype(dtype), k.astype(dtype), v.astype(dtype),
                          causal=causal, block_q=32, block_k=32,
                          interpret=INTERPRET)
    kr = jnp.repeat(k, h // kh, axis=2)
    vr = jnp.repeat(v, h // kh, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = kr.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = vr.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref = attention_ref(qf, kf, vf, causal=causal, scale=1 / math.sqrt(d))
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_TOL[dtype])


SSD_SHAPES = [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 3, 16, 8, 16),
    (1, 48, 1, 8, 16, 16),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_vs_oracle(shape, dtype):
    b, s, h, p, n, chunk = shape
    ks = jax.random.split(KEY, 4)
    u = (jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.3)
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.2
    Bm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.5
    y, sfin = ssd_scan(u.astype(dtype), a, Bm.astype(dtype),
                       Cm.astype(dtype), chunk=chunk, interpret=INTERPRET)
    uf = u.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    af = a.transpose(0, 2, 1).reshape(b * h, s)
    Bf = jnp.repeat(Bm[:, None], h, 1).reshape(b * h, s, n)
    Cf = jnp.repeat(Cm[:, None], h, 1).reshape(b * h, s, n)
    yr, hr = ssd_ref(uf, af, Bf, Cf)
    yr = yr.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    hr = hr.reshape(b, h, n, p)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_TOL[dtype])
    np.testing.assert_allclose(np.asarray(sfin, np.float32),
                               np.asarray(hr, np.float32), **_TOL[dtype])
