"""Durability contract for the crash-recoverable serving layer
(``repro.serve.durability``):

* **snapshot round-trip** — pack/unpack of the full serving state (stub
  and real executor) reproduces the uninterrupted run bit-exactly, and
  taking snapshots never perturbs the serving outcome;
* **crash recovery** — a run cut off mid-wave restores from its latest
  on-disk snapshot and finishes with the reference digest, including
  through a real SIGKILL of the serving process (subprocess test);
* **elastic resume** — a snapshot taken on one device restores onto a
  two-device ``("routes",)`` mesh with placement parity (subprocess);
* **fault injection** — a degraded accelerator with graceful degradation
  (detect -> mask -> reroute -> shed) strictly beats the same fault
  unhandled, and the unhandled arm honestly pays the overrun.

Bit-exactness is always checked via ``serving_digest`` — completed
uids/finish/slack, shed uids, the wave log, per-request placements and
final platform states.
"""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.flexai import FlexAIAgent, FlexAIConfig
from repro.core.hmai import HMAIPlatform
from repro.core.tasks import TaskArrays, pad_route_batch
from repro.serve.durability import (DEAD_CORE_FACTOR, DurableQoSEngine,
                                    FaultInjection, decode_snapshot,
                                    digests_equal, encode_snapshot,
                                    injections_from_fault_events,
                                    pack_engine, serving_digest)
from repro.serve.qos import QoSConfig
from repro.train import checkpoint as ckpt_lib

RS = 0.05
_PLATFORM = HMAIPlatform(capacity_scale=RS)
_AGENT = FlexAIAgent(_PLATFORM, FlexAIConfig(seed=3))


def _route(n: int, seed: int = 0) -> TaskArrays:
    rng = np.random.default_rng(seed)
    return TaskArrays(
        kind=rng.integers(0, 3, n).astype(np.int32),
        arrival=np.sort(rng.uniform(0, 0.01 * n, n)).astype(np.float32),
        safety=np.full(n, 0.05, np.float32),
        group=np.zeros(n, np.int32),
        valid=np.ones(n, bool))


def _engine(executor=None, **kw) -> DurableQoSEngine:
    cfg = QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16)
    return DurableQoSEngine(_PLATFORM, _AGENT.learner.eval_p, cfg,
                            backlog_scale=_AGENT.cfg.backlog_scale,
                            executor=executor, **kw)


def _submit(eng, n_req=6, seed=0, tight=False):
    rng = np.random.default_rng(seed)
    t = 0.0
    for i in range(n_req):
        n = int(rng.integers(40, 90))
        budget = None
        if tight:
            budget = t + float(eng._bucket(n) * eng.base_svc
                               * rng.uniform(1.0, 2.0))
        eng.submit(_route(n, seed + 10 * i), arrival=t, deadline=budget)
        t += float(rng.uniform(0.0, eng.base_svc * 16))


# ---------------------------------------------------------------------------
# snapshot round-trip (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["stub", None],
                         ids=["stub", "real"])
def test_pack_unpack_roundtrip_bit_exact(executor):
    """Crash at a wave boundary, rebuild from the in-memory pack, finish:
    the digest must equal the uninterrupted run's."""
    n_req = 6 if executor == "stub" else 4
    ref = _engine(executor)
    _submit(ref, n_req)
    ref.run_until_done()

    crashed = _engine(executor)
    _submit(crashed, n_req)
    crashed.serve_waves(2)
    arrays, meta = pack_engine(crashed)
    resumed = DurableQoSEngine.from_packed(
        arrays, meta, _PLATFORM,
        backlog_scale=_AGENT.cfg.backlog_scale, executor=executor)
    resumed.run_until_done()
    assert digests_equal(serving_digest(ref), serving_digest(resumed))


def test_blob_encode_roundtrip():
    """The 2-file on-disk form (byte blob + JSON meta) loses nothing."""
    eng = _engine("stub")
    _submit(eng)
    eng.serve_waves(2)
    arrays, meta = pack_engine(eng)
    arrays2, meta2 = decode_snapshot(encode_snapshot(arrays, meta))
    assert meta2 == __import__("json").loads(
        __import__("json").dumps(meta))  # json-normalized equality
    assert len(arrays) == len(arrays2)
    for a, b in zip(arrays, arrays2):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_disk_restore_mid_wave_bit_exact(tmp_path):
    """The cadence snapshot lands *inside* a wave; restoring it resumes
    the in-flight wave (re-applying the preemption check) and still ends
    bit-exact vs the uninterrupted run."""
    ref = _engine()
    _submit(ref, 4)
    ref.run_until_done()

    crashed = _engine(snapshot_dir=str(tmp_path), snapshot_every=3)
    _submit(crashed, 4)
    crashed.serve_waves(2)  # crash: no boundary snapshot
    crashed.saver.wait()
    assert crashed.snapshots_written > 0

    restored = DurableQoSEngine.restore(
        str(tmp_path), _PLATFORM, backlog_scale=_AGENT.cfg.backlog_scale)
    assert restored._inflight is not None  # genuinely mid-wave
    restored.run_until_done()
    restored.saver.wait()
    assert digests_equal(serving_digest(ref), serving_digest(restored))


def test_snapshots_do_not_perturb_serving(tmp_path):
    ref = _engine("stub")
    _submit(ref)
    ref.run_until_done()

    snap = _engine("stub", snapshot_dir=str(tmp_path), snapshot_every=4)
    _submit(snap)
    snap.run_until_done()
    snap.saver.wait()
    assert snap.snapshots_written > 0
    assert digests_equal(serving_digest(ref), serving_digest(snap))


def test_restored_engine_keeps_snapshotting_monotonically(tmp_path):
    """A restored engine inherits the snapshot cadence, and its snapshot
    steps continue the crashed run's counter — ``latest_checkpoint``
    never goes backwards across the crash."""
    crashed = _engine("stub", snapshot_dir=str(tmp_path), snapshot_every=3)
    _submit(crashed)
    crashed.serve_waves(2)
    crashed.saver.wait()
    step_at_crash = ckpt_lib.checkpoint_step(
        ckpt_lib.latest_checkpoint(str(tmp_path)))
    assert step_at_crash == crashed.snapshots_written

    restored = DurableQoSEngine.restore(
        str(tmp_path), _PLATFORM, backlog_scale=_AGENT.cfg.backlog_scale,
        executor="stub")
    restored.run_until_done()
    restored.saver.wait()
    assert restored.snapshots_written > step_at_crash
    assert ckpt_lib.checkpoint_step(
        ckpt_lib.latest_checkpoint(str(tmp_path))) \
        == restored.snapshots_written


# ---------------------------------------------------------------------------
# fault injection + graceful degradation (in-process)
# ---------------------------------------------------------------------------

def _fault_workload():
    """The recovery benchmark's degradation workload verbatim: an offered
    load high enough that the policy cannot simply route around a dead
    core — at light load a degraded exec table alone reroutes placements
    and handled/unhandled become indistinguishable."""
    from benchmarks.recovery import _busiest_core, _engine as bench_engine
    from benchmarks.recovery import _routes, _submit
    plat = HMAIPlatform(capacity_scale=RS)
    agent = FlexAIAgent(plat, FlexAIConfig(seed=0))
    queues = _routes(16)

    def run(faults=None):
        eng = bench_engine(plat, agent, faults=faults)
        _submit(eng, queues)
        eng.run_until_done()
        return eng

    ref = run()
    fault = lambda handled: [FaultInjection(  # noqa: E731
        at_time=0.25 * float(ref.now), core=_busiest_core(ref),
        factor=50.0, handled=handled)]
    return ref, run(fault(True)), run(fault(False))


def test_fault_graceful_degradation_contract(fixed_seed):
    ref, handled, unhandled = _fault_workload()
    sh, su = handled.stats(), unhandled.stats()
    assert sh["faults_fired"] == su["faults_fired"] == 1
    # graceful degradation: the dead core is heartbeat-detected, masked
    # out, and the capacity loss shows up as a service-rate rescale that
    # drives QoS shedding
    assert sh["cores_masked"] == 1 and su["cores_masked"] == 0
    assert sh["svc_scale"] > 1.0
    assert handled.fired[0]["detected_at"] is not None
    # rescheduling onto survivors: the scheduler's belief drops the core,
    # and the last-finishing request (served long after detection) never
    # lands a task on it
    masked = handled.fired[0]["core"]
    assert not handled.alive[masked]
    last = max((r for r in handled.completed if r.summary is not None),
               key=lambda r: r.finish)
    assert masked not in np.asarray(last.summary["placements"]).tolist()
    # the whole point: mitigation strictly reduces deadline misses
    assert sh["miss_rate"] < su["miss_rate"]
    # and an unhandled fault honestly pays the degraded core's overrun
    assert unhandled.now > ref.now


def test_straggler_mitigation_keeps_core_in_argmax(fixed_seed):
    """A throttled core (factor below DEAD_CORE_FACTOR) keeps
    heartbeating with its step time inflated by the degradation: the
    detector's threshold (straggler) arm flags it, admission capacity
    shrinks through the shared ``set_health`` seam, but the core stays
    in the placement argmax — it still makes progress."""
    assert 3.0 < DEAD_CORE_FACTOR
    eng = _engine("stub",
                  faults=[FaultInjection(at_time=0.0, core=1, factor=3.0)],
                  dead_after_segments=1)
    _submit(eng, 6, seed=fixed_seed)
    eng.run_until_done()
    s = eng.stats()
    assert s["faults_fired"] == 1
    assert eng.fired[0]["detected_at"] is not None
    assert s["cores_masked"] == 0 and eng.alive.all()
    assert eng.health[1] == pytest.approx(1.0 / 3.0)
    assert s["svc_scale"] > 1.0


def test_dead_core_health_belief_zeroed(fixed_seed):
    """Dead-core mitigation routes through ``set_health`` too: the
    belief row shows the core at zero capacity and the svc stretch
    matches the old alive-mask formula (total / surviving capacity)."""
    eng = _engine("stub",
                  faults=[FaultInjection(at_time=0.0, core=2, factor=50.0)],
                  dead_after_segments=1)
    _submit(eng, 6, seed=fixed_seed)
    eng.run_until_done()
    assert not eng.alive[2] and eng.health[2] == 0.0
    et = np.asarray(eng.healthy_spec.exec_time, np.float64)
    cap = 1.0 / et.mean(axis=1)
    assert eng.svc_scale == pytest.approx(
        cap.sum() / cap[eng.alive].sum())


def test_injections_from_fault_events_bridge():
    """The in-scan schedule maps onto serving injections: step -> virtual
    time, capacity -> relative exec multiplier, recovery divides the
    slowdown back out, and a dead core lands past DEAD_CORE_FACTOR."""
    from repro.core.faults import FaultEvent
    from repro.core.platform_jax import HEALTH_FLOOR
    svc = 0.01
    events = [FaultEvent(step=4, core=2, factor=0.0),
              FaultEvent(step=2, core=1, factor=0.5),
              FaultEvent(step=9, core=1, factor=1.0)]
    inj = injections_from_fault_events(events, svc)
    assert [f.at_time for f in inj] == [2 * svc, 4 * svc, 9 * svc]
    assert [f.core for f in inj] == [1, 2, 1]
    # capacity 0.5 -> 2x exec; the recovery event cancels it cumulatively
    assert inj[0].factor == pytest.approx(2.0)
    assert inj[0].factor * inj[2].factor == pytest.approx(1.0)
    assert inj[1].factor == pytest.approx(1.0 / HEALTH_FLOOR)
    assert inj[1].factor >= DEAD_CORE_FACTOR


def test_seeded_schedule_drives_serving(fixed_seed):
    """One seeded ``core.faults`` schedule drives the serving layer end
    to end: faults fire, and conservation holds through fault-induced
    degradation (every uid completed or dead-lettered)."""
    from repro.core.faults import random_fault_events
    events = random_fault_events(fixed_seed, n_steps=64,
                                 n_cores=_PLATFORM.n, n_faults=2)
    probe = _engine("stub")
    eng = _engine("stub",
                  faults=injections_from_fault_events(events, probe.svc),
                  dead_after_segments=1)
    n_req = 8
    _submit(eng, n_req, seed=fixed_seed, tight=True)
    eng.run_until_done()
    assert eng.stats()["faults_fired"] >= 1
    done = [r.uid for r in eng.completed]
    shed = [d["uid"] for d in eng.dead_letter]
    assert sorted(done + shed) == list(range(n_req))


# ---------------------------------------------------------------------------
# AsyncCheckpointer retry-with-backoff (flaky filesystem)
# ---------------------------------------------------------------------------

def test_async_checkpointer_retries_transient_oserror(tmp_path, monkeypatch):
    """Two transient disk failures, then success: the snapshot thread
    survives and the checkpoint lands (before the retry loop, the first
    OSError silently killed the write and only surfaced at wait())."""
    calls = {"n": 0}
    real = ckpt_lib._write

    def flaky(directory, step, names, host):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient filesystem blip")
        return real(directory, step, names, host)

    monkeypatch.setattr(ckpt_lib, "_write", flaky)
    saver = ckpt_lib.AsyncCheckpointer(str(tmp_path), retries=3,
                                       backoff_s=0.0)
    saver.save(1, {"w": np.arange(4.0)})
    saver.wait()  # must not raise
    assert calls["n"] == 3
    path = ckpt_lib.latest_checkpoint(str(tmp_path))
    assert path is not None and ckpt_lib.checkpoint_step(path) == 1


def test_async_checkpointer_exhausted_retries_surface(tmp_path, monkeypatch):
    """A persistent failure still surfaces on wait() after the bounded
    retries run out — durability never hides a genuinely broken disk."""
    def broken(directory, step, names, host):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_lib, "_write", broken)
    saver = ckpt_lib.AsyncCheckpointer(str(tmp_path), retries=2,
                                       backoff_s=0.0)
    saver.save(1, {"w": np.zeros(2)})
    with pytest.raises(OSError, match="disk full"):
        saver.wait()


def test_inject_core_validated_against_platform(capsys):
    """--inject-core outside the platform's accelerator range is refused
    up front instead of constructing an engine that faults a phantom
    core."""
    from repro.launch.serve import main
    assert main(["--placement", "--routes", "1", "--inject-core", "99"]) == 1
    assert "out of range" in capsys.readouterr().out
    assert main(["--placement", "--routes", "1", "--inject-core", "-1"]) == 1
    assert "out of range" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# mesh dispatch + elastic padding (in-process, 1 device)
# ---------------------------------------------------------------------------

def test_mesh_dispatch_parity_single_device():
    """The shard_map lockstep path (mesh dispatch + lane padding) must be
    a pure execution detail: same digest as the plain engine."""
    from repro.compat import make_mesh
    import jax
    ref = _engine()
    _submit(ref, 4)
    ref.run_until_done()

    mesh = make_mesh((len(jax.devices()),), ("routes",))
    meshed = _engine(mesh=mesh)
    _submit(meshed, 4)
    meshed.run_until_done()
    assert digests_equal(serving_digest(ref), serving_digest(meshed))


def test_pad_route_batch_pads_with_invalid_lanes():
    batch = TaskArrays(*[np.stack([np.asarray(x)] * 3)
                         for x in _route(20, seed=1)])
    padded = pad_route_batch(batch, 2)
    assert padded.kind.shape[0] == 4
    np.testing.assert_array_equal(padded.valid[:3], batch.valid)
    assert not padded.valid[3].any()


# ---------------------------------------------------------------------------
# subprocess recovery: SIGKILL mid-wave, elastic resume on a bigger mesh
# ---------------------------------------------------------------------------

_SERVE = [sys.executable, "-m", "repro.launch.serve", "--placement",
          "--routes", "4", "--rate-scale", "0.005", "--seed", "0"]


def _env(n_devices=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if n_devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n_devices}"
    return env


def _digest_npz(path):
    with np.load(path, allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


def _run(args, env, timeout=240):
    r = subprocess.run(_SERVE + args, env=env, capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"serve failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sigkill_mid_wave_recovery_bit_exact(tmp_path):
    """Kill -9 the serving process between wave segments (after its 3rd
    cadence snapshot), resume from disk, and require the final digest to
    equal an uninterrupted run's — the ISSUE's recovery contract."""
    ref_out = str(tmp_path / "ref.npz")
    _run(["--qos", "edf", "--state-out", ref_out], _env())

    snap_dir = str(tmp_path / "snaps")
    proc = subprocess.Popen(
        _SERVE + ["--qos", "edf", "--snapshot-dir", snap_dir,
                  "--snapshot-every", "4", "--segment-sleep", "0.02",
                  "--trace"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    snapshots_seen, deadline = 0, time.time() + 240
    try:
        for line in proc.stdout:
            if line.startswith("SNAPSHOT"):
                snapshots_seen += 1
                if snapshots_seen >= 3:
                    break
            assert time.time() < deadline, "no snapshots before timeout"
    finally:
        if proc.poll() is None:
            os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
        proc.stdout.close()
    assert snapshots_seen >= 3, "server exited before being killed"

    out = str(tmp_path / "resumed.npz")
    stdout = _run(["--resume", "--snapshot-dir", snap_dir,
                   "--state-out", out], _env())
    assert "resumed snapshot" in stdout
    assert digests_equal(_digest_npz(ref_out), _digest_npz(out))


@pytest.mark.slow
def test_elastic_resume_onto_two_device_mesh(tmp_path):
    """Snapshot a partial single-device run, resume it onto a 2-device
    ``("routes",)`` mesh: placement parity with the single-device run."""
    ref_out = str(tmp_path / "ref.npz")
    _run(["--qos", "edf", "--state-out", ref_out], _env())

    snap_dir = str(tmp_path / "snaps")
    stdout = _run(["--qos", "edf", "--snapshot-dir", snap_dir,
                   "--serve-waves", "2"], _env())
    assert "partial run" in stdout

    out = str(tmp_path / "elastic.npz")
    stdout = _run(["--resume", "--shard", "--snapshot-dir", snap_dir,
                   "--state-out", out], _env(n_devices=2))
    assert "durable QoS mesh: 2 device(s)" in stdout
    assert digests_equal(_digest_npz(ref_out), _digest_npz(out))
