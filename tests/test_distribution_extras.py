"""Distribution extras: explicit-EP MoE equivalence (subprocess, 8 devices),
HLO collective parser, decode-rules structure, virtual platform."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np


def _run_sub(script: str, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_moe_shard_map_matches_gspmd():
    """Explicit all-to-all EP == GSPMD scatter MoE (fwd bit-exact, grads)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.models.config import ModelConfig
        from repro.models.moe import init_moe, moe_apply_gspmd, moe_apply_shard_map
        from repro.sharding import activate, unbox
        from repro.launch.mesh import make_test_mesh
        cfg = ModelConfig(name="sm", family="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=24, vocab_size=64,
                          num_experts=8, num_experts_per_token=2,
                          moe_capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = unbox(init_moe(key, cfg, jnp.float32))
        x = jax.random.normal(key, (4, 16, 32))
        ref, _ = jax.jit(lambda p, x: moe_apply_gspmd(p, cfg, x))(p, x)
        mesh = make_test_mesh((2, 4), ("data", "model"))
        with activate(mesh):
            out, _ = jax.jit(lambda p, x: moe_apply_shard_map(p, cfg, x, mesh))(p, x)
            g1 = jax.jit(jax.grad(lambda p, x: jnp.sum(
                moe_apply_shard_map(p, cfg, x, mesh)[0] ** 2)))(p, x)
        g2 = jax.jit(jax.grad(lambda p, x: jnp.sum(
            moe_apply_gspmd(p, cfg, x)[0] ** 2)))(p, x)
        fwd_err = float(jnp.max(jnp.abs(out - ref)))
        g_err = max(float(jnp.max(jnp.abs(g1[k] - g2[k])))
                    for k in ("wi_gate", "wo", "router"))
        print(f"RESULT {fwd_err} {g_err}")
    """)
    out = _run_sub(script)
    line = [l for l in out.splitlines() if l.startswith("RESULT")][0]
    fwd_err, g_err = map(float, line.split()[1:])
    assert fwd_err < 1e-5, fwd_err
    assert g_err < 1e-3, g_err


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
      %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %p), dims={0}
      %ar = f32[64]{0} all-reduce(f32[64]{0} %q), to_apply=%add
      %a2a = f32[16,32]{1,0} all-to-all(f32[16,32]{1,0} %r), dims={0}
      %dot = f32[8,8]{1,0} dot(f32[8,4]{1,0} %a, f32[4,8]{1,0} %b)
    """
    res = parse_collectives(hlo)
    assert res["all-gather"]["count"] == 1
    assert res["all-gather"]["operand_bytes"] == 1 * 128 * 2
    assert res["all-reduce"]["operand_bytes"] == 64 * 4
    assert res["all-to-all"]["count"] == 1
    assert res["total_count"] == 3  # the dot is not a collective


def test_decode_rules_structure():
    from repro.sharding.partition import DECODE_RULES, DEFAULT_RULES
    d = dict(DECODE_RULES)
    assert d["embed"] is None          # no FSDP weight gathers at decode
    assert d["mlp"] == ("model", "data")
    assert d["cache_batch"] == ("pod", "data")
    assert dict(DEFAULT_RULES)["embed"] == "data"  # training keeps FSDP


def test_virtual_platform_schedules():
    from repro.core.virtual_platform import VirtualPlatform
    from repro.core.tasks import Task, TaskKind
    plat = VirtualPlatform(run_real=False)
    assert plat.n == 3
    assert all(p.measured_fps for p in plat.pools)
    rec = plat.execute(Task(uid=0, kind=TaskKind.YOLO, camera_group="FC",
                            camera_id=0, arrival_time=0.0, safety_time=5.0), 0)
    assert rec.exec_time > 0
    spec = plat.pools[0].as_accelerator_spec()
    assert spec.arch.name == "MconvMC"
