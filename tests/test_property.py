"""Property-based tests (hypothesis) on system invariants.

``hypothesis`` is an optional dev dependency (requirements-dev.txt); the
module skips cleanly when it isn't installed so ``pytest -x -q`` still
collects the rest of the suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.criteria import (gvalue, matching_score_det,
                                 matching_score_tra, rss_safe_distance,
                                 rss_safety_time)
from repro.core.hmai import HMAIPlatform
from repro.core.tasks import Task, TaskKind
from repro.sharding import logical_to_mesh_axes
from repro.train.compression import (compress_grads_int8_ef, dequantize_int8,
                                     ef_init, quantize_int8)

SETTINGS = settings(max_examples=60, deadline=None)


# ---------------------------------------------------------------------------
# RSS / criteria
# ---------------------------------------------------------------------------

@SETTINGS
@given(d=st.floats(30.0, 500.0), v1=st.floats(1.0, 40.0),
       v2=st.floats(0.0, 40.0))
def test_rss_roundtrip(d, v1, v2):
    """safety_time inverts safe_distance whenever a positive budget exists."""
    rho = rss_safety_time(d, v1, v2)
    assert rho >= 0.0
    if rho > 0:
        np.testing.assert_allclose(rss_safe_distance(v1, v2, rho), d,
                                   rtol=1e-6)


@SETTINGS
@given(d=st.floats(30.0, 500.0), v=st.floats(1.0, 40.0),
       dv=st.floats(0.1, 10.0))
def test_rss_monotonic_in_speed(d, v, dv):
    """Faster closing speed -> strictly less response budget."""
    assert rss_safety_time(d, v + dv, v + dv) <= rss_safety_time(d, v, v)


@SETTINGS
@given(t=st.floats(0.0, 10.0), s=st.floats(0.01, 10.0))
def test_matching_score_bounds(t, s):
    ms_det = matching_score_det(t, s)
    ms_tra = matching_score_tra(t, s)
    assert -1.0 <= ms_det <= 1.0
    assert ms_tra in (-1.0, 1.0)
    if t > s:
        assert ms_det == -1.0 and ms_tra == -1.0


@SETTINGS
@given(e=st.floats(0.0, 100.0), t=st.floats(0.0, 100.0),
       r=st.floats(0.0, 1.0), de=st.floats(0.01, 10.0))
def test_gvalue_monotonicity(e, t, r, de):
    """More energy or time strictly lowers Gvalue; more balance raises it."""
    base = gvalue(e, t, r, e_scale=100.0, t_scale=100.0)
    assert gvalue(e + de, t, r, e_scale=100.0, t_scale=100.0) < base
    assert gvalue(e, t + de, r, e_scale=100.0, t_scale=100.0) < base
    if r + 0.01 <= 1.0:
        assert gvalue(e, t, r + 0.01, e_scale=100.0, t_scale=100.0) > base


# ---------------------------------------------------------------------------
# Platform simulator
# ---------------------------------------------------------------------------

@SETTINGS
@given(assignments=st.lists(st.integers(0, 10), min_size=1, max_size=40),
       seed=st.integers(0, 1000))
def test_platform_invariants(assignments, seed):
    """Response >= exec time; per-accelerator time monotone; energy adds up."""
    rng = np.random.default_rng(seed)
    plat = HMAIPlatform()
    t = 0.0
    total_e = 0.0
    for uid, a in enumerate(assignments):
        t += float(rng.uniform(0, 0.01))
        kind = [TaskKind.YOLO, TaskKind.SSD, TaskKind.GOTURN][uid % 3]
        task = Task(uid=uid, kind=kind, camera_group="FC", camera_id=0,
                    arrival_time=t, safety_time=1.0)
        rec = plat.execute(task, a % plat.n)
        assert rec.response_time >= rec.exec_time - 1e-12
        assert rec.finish >= rec.start
        assert rec.wait >= 0.0
        total_e += rec.energy
    np.testing.assert_allclose(plat.total_energy, total_e, rtol=1e-9)
    assert 0.0 <= plat.r_balance <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

@SETTINGS
@given(vals=st.lists(st.floats(-100.0, 100.0, allow_nan=False),
                     min_size=1, max_size=64))
def test_int8_quantize_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_compensates():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
              for _ in range(50)]
    ef = ef_init({"w": g_true[0]})
    applied = jnp.zeros((8, 8))
    for g in g_true:
        out, ef = compress_grads_int8_ef({"w": g}, ef)
        applied = applied + out["w"]
    total_true = sum(g_true)
    resid = float(jnp.max(jnp.abs(applied + ef["w"] - total_true)))
    assert resid < 1e-3  # applied + residual == true sum (EF identity)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

@SETTINGS
@given(names=st.lists(st.sampled_from(
    ["batch", "embed", "heads", "mlp", "vocab", "expert", None]),
    min_size=1, max_size=4))
def test_mesh_axes_never_reused(names):
    from repro.sharding import DEFAULT_RULES, abstract_mesh
    mesh = abstract_mesh((2, 2), ("data", "model"))
    spec = logical_to_mesh_axes(tuple(names), DEFAULT_RULES, mesh)
    used = []
    for entry in spec:
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        used.extend(entries)
    assert len(used) == len(set(used)), spec


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

@SETTINGS
@given(seed=st.integers(0, 100))
def test_moe_capacity_and_gates(seed):
    from repro.models.config import ModelConfig
    from repro.models.moe import init_moe, moe_apply, _capacity
    from repro.sharding import unbox
    cfg = ModelConfig(name="pm", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32,
                      num_experts=4, num_experts_per_token=2)
    key = jax.random.PRNGKey(seed)
    p = unbox(init_moe(key, cfg, jnp.float32))
    x = jax.random.normal(key, (2, 8, 16))
    out, aux = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0
    assert _capacity(cfg, 16) >= 8
