"""Model-substrate behaviour: every family forward/loss/prefill/decode, and
teacher-forcing consistency between the parallel and incremental paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.sharding import unbox

KEY = jax.random.PRNGKey(0)

TINY = {
    "dense": ModelConfig(name="t-dense", family="dense", num_layers=2,
                         d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=128, attention_impl="naive"),
    "moe": ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                       num_experts=4, num_experts_per_token=2,
                       attention_impl="naive"),
    "ssm": ModelConfig(name="t-ssm", family="ssm", num_layers=2, d_model=64,
                       num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=128,
                       layer_pattern="M", ssm_state_dim=16, ssm_head_dim=16,
                       ssm_chunk=8),
    "hybrid": ModelConfig(name="t-hyb", family="hybrid", num_layers=4,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
                          vocab_size=128, layer_pattern="MMAM",
                          num_experts=4, num_experts_per_token=2,
                          moe_layer_period=2, ssm_state_dim=16,
                          ssm_head_dim=32, ssm_chunk=8,
                          attention_impl="naive"),
    "mla": ModelConfig(name="t-mla", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=4, d_ff=96, vocab_size=128,
                       attention_kind="mla", q_lora_rank=32, kv_lora_rank=32,
                       qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                       head_dim=24, attention_impl="naive"),
}


def _batch(cfg, bs=2, seq=16):
    k1, k2 = jax.random.split(KEY)
    return {
        "tokens": jax.random.randint(k1, (bs, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (bs, seq), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((bs, seq), jnp.float32),
    }


@pytest.mark.parametrize("family", sorted(TINY))
def test_family_loss_finite(family):
    cfg = TINY[family]
    api = model_api(cfg)
    params = unbox(api.init(KEY))
    loss, metrics = jax.jit(api.loss)(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(metrics["perplexity"]) > 1.0


@pytest.mark.parametrize("family", ["dense", "ssm", "mla"])
def test_decode_matches_teacher_forcing(family):
    """Greedy incremental decode logits == parallel forward logits (fp32)."""
    import dataclasses
    cfg = dataclasses.replace(TINY[family], dtype="float32")
    api = model_api(cfg)
    params = unbox(api.init(KEY))
    bs, seq = 2, 12
    batch = _batch(cfg, bs, seq)

    # parallel logits at final position
    from repro.models import transformer as T
    logits_prefill, _ = jax.jit(api.prefill)(params, batch)

    # incremental: zero cache, feed tokens one at a time
    cache = unbox(api.init_cache(bs, seq + 4))
    logits_step = None
    decode = jax.jit(api.decode_step)
    for t in range(seq):
        logits_step, cache = decode(params, cache,
                                    batch["tokens"][:, t: t + 1],
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_prefill[:, -1]),
                               np.asarray(logits_step[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_swa_matches_naive_window():
    """Chunk+neighbour SWA == naive masked attention with the same window."""
    from repro.models.attention import naive_attention, sliding_window_attention
    b, s, h, d, w = 2, 64, 4, 16, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    scale = d ** -0.5
    ref = naive_attention(q, k, v, causal=True, scale=scale, window=w)
    out = sliding_window_attention(q, k, v, scale=scale, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_naive():
    from repro.models.attention import chunked_attention, naive_attention
    b, s, h, d = 2, 48, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, 2, d))
    v = jax.random.normal(ks[2], (b, s, 2, d))
    scale = d ** -0.5
    ref = naive_attention(q, k, v, causal=True, scale=scale)
    out = chunked_attention(q, k, v, causal=True, scale=scale, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_stepwise():
    """Chunked SSD == per-token recurrence, including the returned state."""
    from repro.models.ssm import ssd_chunked
    from repro.kernels.ssd_scan.ref import ssd_ref
    b, s, h, p, n = 2, 24, 2, 8, 4
    ks = jax.random.split(KEY, 4)
    u = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.2
    Bm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    y, s_fin = ssd_chunked(u, a, Bm, Cm, chunk=8)
    uf = u.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    af = a.transpose(0, 2, 1).reshape(b * h, s)
    Bf = jnp.repeat(Bm[:, None], h, 1).reshape(b * h, s, n)
    Cf = jnp.repeat(Cm[:, None], h, 1).reshape(b * h, s, n)
    yr, hr = ssd_ref(uf, af, Bf, Cf)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(yr.reshape(b, h, s, p).transpose(0, 2, 1, 3)),
        rtol=1e-4, atol=1e-4)
    # state layouts: ssd_chunked [B,H,P,N] vs ref [B*H,N,P]
    np.testing.assert_allclose(
        np.asarray(s_fin), np.asarray(
            hr.reshape(b, h, n, p).transpose(0, 1, 3, 2)),
        rtol=1e-4, atol=1e-4)


def test_encdec_loss_and_decode():
    cfg = ModelConfig(name="t-ed", family="audio", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=96, vocab_size=128,
                      is_encoder_decoder=True, num_encoder_layers=2,
                      frontend="audio_stub", attention_impl="naive")
    api = model_api(cfg)
    params = unbox(api.init(KEY))
    batch = _batch(cfg, 2, 12)
    batch["frontend_embeds"] = jax.random.normal(KEY, (2, 3, 64))
    loss, _ = jax.jit(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    logits, cache = jax.jit(api.prefill)(params, batch)
    assert logits.shape == (2, 1, 128)


def test_perception_nets_apply():
    """Reduced-width YOLO/SSD/GOTURN actually run (residual wiring)."""
    from repro.models.perception.nets import (
        init_yolo, yolo_apply, init_ssd, ssd_apply, init_goturn, goturn_apply)
    from repro.sharding import unbox
    x = jax.random.normal(KEY, (1, 32, 32, 3))
    y = yolo_apply(unbox(init_yolo(KEY, width_mult=0.1)), x)
    assert np.isfinite(np.asarray(y)).all()
    s = ssd_apply(unbox(init_ssd(KEY, width_mult=0.1)), x)
    assert np.isfinite(np.asarray(s)).all()
    crop = jax.random.normal(KEY, (1, 24, 24, 3))
    g = goturn_apply(unbox(init_goturn(KEY, width_mult=0.2)), crop, crop)
    assert g.shape == (1, 4)
