"""Scenario generator: family semantics, determinism, and engine feed."""
import jax
import numpy as np
import pytest

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.flexai.dqn import init_qnet
from repro.core.flexai.engine import make_schedule_fn
from repro.core.hmai import HMAIPlatform
from repro.core.platform_jax import spec_from_platform, summarize
from repro.core.scenarios import (FAMILIES, scenario_batch,
                                  scenario_lane_batches)
from repro.core.tasks import tasks_to_arrays

RS = 0.05


def _base(seed=21, km=0.06):
    return tasks_to_arrays(build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0)))


@pytest.fixture(scope="module")
def batch():
    plat = HMAIPlatform(capacity_scale=RS)
    return _base(), scenario_batch(_base(), plat.n, seed=3, n_per_family=4)


def test_batch_shapes_and_determinism(batch):
    base, b = batch
    t = base.arrival.shape[0]
    n = HMAIPlatform(capacity_scale=RS).n
    assert b.num_scenarios == 4 * len(FAMILIES)
    assert b.tasks.arrival.shape == (b.num_scenarios, t)
    assert b.health.shape == (b.num_scenarios, t, n)
    b2 = scenario_batch(_base(), n, seed=3, n_per_family=4)
    for x, y in zip(jax.tree_util.tree_leaves(b.tasks),
                    jax.tree_util.tree_leaves(b2.tasks)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(b.health),
                                  np.asarray(b2.health))
    b3 = scenario_batch(_base(), n, seed=4, n_per_family=4)
    assert not np.array_equal(np.asarray(b.health), np.asarray(b3.health))


def test_clean_family_is_base(batch):
    base, b = batch
    for r in b.family_rows("clean"):
        np.testing.assert_array_equal(np.asarray(b.tasks.arrival[r]),
                                      np.asarray(base.arrival))
        np.testing.assert_array_equal(np.asarray(b.tasks.valid[r]),
                                      np.asarray(base.valid))
        assert np.all(np.asarray(b.health[r]) == 1.0)


def test_sensor_dropout_keeps_front_center(batch):
    base, b = batch
    group = np.asarray(base.group)
    bvalid = np.asarray(base.valid)
    dropped_any = False
    for r in b.family_rows("sensor_dropout"):
        valid = np.asarray(b.tasks.valid[r])
        # front-center tasks always survive; drops are whole-group
        np.testing.assert_array_equal(valid[(group == 0) & bvalid],
                                      True)
        assert not np.any(valid & ~bvalid)   # never resurrects padding
        dropped_any |= bool(np.any(bvalid & ~valid))
    assert dropped_any


def test_weather_and_burst_preserve_order(batch):
    base, b = batch
    changed = {"weather": False, "burst": False}
    for fam in ("weather", "burst"):
        for r in b.family_rows(fam):
            arr = np.asarray(b.tasks.arrival[r])
            assert np.all(np.diff(arr) >= 0.0), fam
            changed[fam] |= not np.array_equal(arr,
                                               np.asarray(base.arrival))
    assert changed["weather"] and changed["burst"]


def test_fault_family_traces(batch):
    _, b = batch
    rows = b.family_rows("fault")
    hit = False
    for r in rows:
        tr = np.asarray(b.health[r])
        assert ((tr >= 0.0) & (tr <= 1.0)).all()
        assert (tr > 0.0).any(axis=1).all()      # a survivor every step
        hit |= bool((tr < 1.0).any())
    assert hit


def test_lane_batches_shapes(batch):
    _, b = batch
    lanes = 4
    got = list(scenario_lane_batches(b, lanes))
    assert len(got) == b.num_scenarios // lanes
    tasks, health = got[0]
    assert tasks.arrival.shape[0] == lanes
    assert health.shape[0] == lanes


def test_batched_engine_consumes_scenarios(batch):
    """The whole fleet schedules in one batched dispatch, traces and all."""
    _, b = batch
    plat = HMAIPlatform(capacity_scale=RS)
    spec = spec_from_platform(plat)
    params = init_qnet(jax.random.PRNGKey(0), 3 + 5 * plat.n, plat.n)
    fn = make_schedule_fn(spec, batched=True)
    finals, recs = fn(params, b.tasks, health=b.health)
    assert recs.valid.shape[0] == b.num_scenarios
    s0 = summarize(spec, jax.tree_util.tree_map(lambda a: a[0], finals),
                   jax.tree_util.tree_map(lambda a: a[0], recs))
    assert 0.0 <= s0["stm_rate"] <= 1.0
