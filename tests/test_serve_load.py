"""Serving under open-loop load (ISSUE 10): load-generator contracts,
serving-config validation, the bounded compiled-closure cache, measured
service times, and sharded-wave parity.

Queueing-level tests ride the ``stub`` executor; the parity and
state-reinit checks use the real scan executor (single device here — the
CI benchmark gate re-runs parity on a forced 2-device host).
"""
import numpy as np
import pytest

from repro.core.flexai import FlexAIAgent, FlexAIConfig
from repro.core.hmai import HMAIPlatform
from repro.core.tasks import TaskArrays
from repro.serve.loadgen import (LoadGenConfig, SERVE_FAMILIES,
                                 arrival_times, generate, submit_trace)
from repro.serve.qos import (QoSConfig, QoSPlacementEngine,
                             power_of_two_bucket)

RS = 0.05
_PLATFORM = HMAIPlatform(capacity_scale=RS)
_AGENT = FlexAIAgent(_PLATFORM, FlexAIConfig(seed=3))


def _route(n: int, seed: int = 0) -> TaskArrays:
    rng = np.random.default_rng(seed)
    return TaskArrays(
        kind=rng.integers(0, 3, n).astype(np.int32),
        arrival=np.sort(rng.uniform(0, 0.01 * n, n)).astype(np.float32),
        safety=np.full(n, 0.05, np.float32),
        group=np.zeros(n, np.int32),
        valid=np.ones(n, bool))


def _engine(cfg: QoSConfig, executor="stub", mesh=None):
    return QoSPlacementEngine(_PLATFORM, _AGENT.learner.eval_p, cfg,
                              backlog_scale=_AGENT.cfg.backlog_scale,
                              executor=executor, mesh=mesh)


def _gaps(times: np.ndarray) -> np.ndarray:
    return np.diff(np.concatenate([[0.0], times]))


# ---------------------------------------------------------------------------
# bucket / config validation (the serving-correctness bugfix sweep)
# ---------------------------------------------------------------------------

def test_power_of_two_bucket_rejects_nonpositive_minimum():
    """minimum < 1 used to loop forever (doubling from 0 never reaches n);
    it must be a ValueError, and sane minimums keep their contract."""
    for bad in (0, -4):
        with pytest.raises(ValueError, match="minimum"):
            power_of_two_bucket(5, bad)
    assert power_of_two_bucket(5, 16) == 16
    assert power_of_two_bucket(16, 16) == 16
    assert power_of_two_bucket(17, 16) == 32
    assert power_of_two_bucket(1, 1) == 1
    assert power_of_two_bucket(0, 1) == 1


def test_qos_config_validates_knobs():
    QoSConfig(chunk=8, min_bucket=16)  # sane config constructs
    with pytest.raises(ValueError, match="min_bucket"):
        QoSConfig(chunk=8, min_bucket=0)
    with pytest.raises(ValueError, match="power of two"):
        QoSConfig(chunk=8, min_bucket=24)
    with pytest.raises(ValueError, match="chunk"):
        QoSConfig(chunk=0, min_bucket=16)
    with pytest.raises(ValueError, match="multiple"):
        QoSConfig(chunk=12, min_bucket=16)
    with pytest.raises(ValueError, match="slots"):
        QoSConfig(slots=0)
    with pytest.raises(ValueError, match="stages"):
        QoSConfig(stages=0)
    with pytest.raises(ValueError, match="policy"):
        QoSConfig(policy="lifo")
    with pytest.raises(ValueError, match="svc_ema"):
        QoSConfig(svc_ema=0.0)
    with pytest.raises(ValueError, match="svc_ema"):
        QoSConfig(svc_ema=1.5)
    with pytest.raises(ValueError, match="pipeline"):
        QoSConfig(continuous=True, stages=2)


def test_seg_fn_cache_is_lru_bounded():
    """Churning more closure keys than the cap through the shared cache
    must evict cold entries and keep hot ones — a long-lived serving
    process cannot accumulate compiled closures forever."""
    from repro.serve.qos import (_SEG_FN_CACHE, _SEG_FN_CACHE_CAP,
                                 _seg_cache_get)
    saved = dict(_SEG_FN_CACHE)
    try:
        _SEG_FN_CACHE.clear()
        builds = []
        for i in range(_SEG_FN_CACHE_CAP + 5):
            _seg_cache_get(("lru-test", i),
                           lambda i=i: builds.append(i) or i)
            # re-touching the hot entry keeps it resident throughout
            hot = _seg_cache_get(("lru-test", 0),
                                 lambda: builds.append("rebuild"))
        assert hot == 0 and "rebuild" not in builds
        assert len(builds) == _SEG_FN_CACHE_CAP + 5  # each key built once
        assert len(_SEG_FN_CACHE) == _SEG_FN_CACHE_CAP
        assert ("lru-test", 0) in _SEG_FN_CACHE
        assert ("lru-test", 1) not in _SEG_FN_CACHE  # coldest evicted
    finally:
        _SEG_FN_CACHE.clear()
        _SEG_FN_CACHE.update(saved)


def test_mesh_rejects_stub_executor_and_pipeline_waves():
    import jax

    from repro.compat import make_mesh
    mesh = make_mesh((len(jax.devices()),), ("routes",))
    with pytest.raises(ValueError, match="executor"):
        _engine(QoSConfig(chunk=16, min_bucket=16), executor="stub",
                mesh=mesh)
    with pytest.raises(ValueError, match="single-stage"):
        _engine(QoSConfig(chunk=16, min_bucket=16, stages=2),
                executor=None, mesh=mesh)


def test_durable_engine_rejects_continuous_and_measured():
    from repro.serve.durability import DurableQoSEngine
    for kw in (dict(continuous=True), dict(measured_svc=True)):
        cfg = QoSConfig(policy="edf", chunk=16, min_bucket=16, **kw)
        with pytest.raises(ValueError):
            DurableQoSEngine(_PLATFORM, _AGENT.learner.eval_p, cfg,
                             backlog_scale=_AGENT.cfg.backlog_scale,
                             executor="stub")


# ---------------------------------------------------------------------------
# open-loop load generation
# ---------------------------------------------------------------------------

def test_loadgen_config_validation():
    with pytest.raises(ValueError, match="process"):
        LoadGenConfig(process="uniform")
    with pytest.raises(ValueError, match="offered_load"):
        LoadGenConfig(offered_load=0.0)
    with pytest.raises(ValueError, match="burstiness"):
        LoadGenConfig(process="gamma", burstiness=-1.0)
    with pytest.raises(ValueError, match="n_requests"):
        LoadGenConfig(n_requests=0)
    with pytest.raises(ValueError, match="families"):
        LoadGenConfig(families=("clean", "nope"))


def test_arrival_times_deterministic_and_rate():
    cfg = LoadGenConfig(process="poisson", n_requests=4000,
                        offered_load=2.0, seed=7)
    t1, t2 = arrival_times(cfg, 0.01), arrival_times(cfg, 0.01)
    np.testing.assert_array_equal(t1, t2)
    assert np.all(_gaps(t1) >= 0.0)
    assert _gaps(t1).mean() == pytest.approx(0.01, rel=0.1)


def test_gamma_arrivals_same_rate_higher_burstiness():
    """The gamma process holds the offered rate of its poisson twin but
    clumps arrivals: gap CV^2 tracks cfg.burstiness (poisson is 1)."""
    n, mean_gap = 6000, 0.02
    g_p = _gaps(arrival_times(LoadGenConfig(
        process="poisson", n_requests=n, seed=3), mean_gap))
    g_b = _gaps(arrival_times(LoadGenConfig(
        process="gamma", burstiness=6.0, n_requests=n, seed=3), mean_gap))
    assert g_b.mean() == pytest.approx(mean_gap, rel=0.15)
    assert g_p.var() / g_p.mean() ** 2 == pytest.approx(1.0, rel=0.2)
    assert g_b.var() / g_b.mean() ** 2 == pytest.approx(6.0, rel=0.3)


def test_generate_trace_deterministic_families_and_load():
    base = _route(24, 5)
    cfg = LoadGenConfig(n_requests=12, offered_load=2.0, seed=9)
    tr1 = generate(base, _PLATFORM.n, cfg, mean_service=0.05)
    tr2 = generate(base, _PLATFORM.n, cfg, mean_service=0.05)
    assert len(tr1) == 12
    assert [r.arrival for r in tr1] == [r.arrival for r in tr2]
    for a, b in zip(tr1, tr2):
        np.testing.assert_array_equal(np.asarray(a.tasks.kind),
                                      np.asarray(b.tasks.kind))
    assert [r.arrival for r in tr1] == sorted(r.arrival for r in tr1)
    assert set(r.family for r in tr1) <= set(SERVE_FAMILIES)
    assert len(set(r.family for r in tr1)) > 1  # a mix, not one family
    # offered_load 2.0 halves the mean gap relative to the service time
    assert _gaps(np.asarray([r.arrival for r in tr1])).mean() < 0.05


def test_submit_trace_serves_end_to_end():
    base = _route(24, 5)
    trace = generate(base, _PLATFORM.n,
                     LoadGenConfig(n_requests=8, offered_load=1.0, seed=2),
                     mean_service=0.05)
    eng = _engine(QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16,
                            continuous=True))
    reqs = submit_trace(eng, trace)
    assert [r.arrival for r in reqs] == [t.arrival for t in trace]
    eng.run_until_done()
    s = eng.stats()
    assert s["completed"] + s["shed"] == 8
    assert s["queued"] == 0 and s["in_flight"] == 0


# ---------------------------------------------------------------------------
# measured service times
# ---------------------------------------------------------------------------

def test_measured_service_ema_calibrates_with_virtual_fallback():
    cfg = QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16,
                    preempt=False, shed=False, measured_svc=True)
    eng = _engine(cfg)
    assert eng._service_need(16) == 16 * eng.svc  # uncalibrated fallback
    eng.submit(_route(10, 0), arrival=0.0, deadline=1e9)
    eng.run_until_done()
    key = (16, cfg.stages)
    assert key in eng._svc_measured and eng._svc_measured[key] > 0.0
    assert eng._service_need(16) == pytest.approx(
        16 * eng._svc_measured[key])
    assert eng._service_need(64) == 64 * eng.svc  # unseen bucket: virtual
    assert eng.now > 0.0  # the clock advanced by measured wall time


def test_measured_service_ema_update_rule():
    eng = _engine(QoSConfig(policy="edf", chunk=16, min_bucket=16,
                            measured_svc=True))  # svc_ema = 0.25
    eng._observe_service(16, 1.6)   # per-slot 0.1 seeds the EMA
    assert eng._svc_measured[(16, 1)] == pytest.approx(0.1)
    eng._observe_service(16, 3.2)   # 0.75 * 0.1 + 0.25 * 0.2
    assert eng._svc_measured[(16, 1)] == pytest.approx(0.125)


def test_virtual_clock_unchanged_without_measured_svc():
    """The deterministic default: clock charges the virtual constant and
    no EMA is collected (what the parity digests and CI gates rely on)."""
    eng = _engine(QoSConfig(policy="edf", slots=1, chunk=16, min_bucket=16,
                            preempt=False, shed=False))
    eng.submit(_route(10, 0), arrival=0.0, deadline=1e9)
    eng.run_until_done()
    assert eng._svc_measured == {}
    assert eng.now == pytest.approx(16 * eng.svc)


# ---------------------------------------------------------------------------
# sharded-wave parity (single host; CI re-runs on 2 forced devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("continuous", [False, True])
def test_sharded_wave_parity(continuous):
    import jax

    from repro.compat import make_mesh
    from repro.serve.durability import digests_equal, serving_digest
    mesh = make_mesh((len(jax.devices()),), ("routes",))

    def serve(mesh_arg):
        eng = _engine(QoSConfig(policy="edf", slots=3, chunk=8,
                                min_bucket=16, continuous=continuous),
                      executor=None, mesh=mesh_arg)
        for i in range(5):
            eng.submit(_route(10 + i, i), arrival=0.002 * i,
                       deadline=100.0)
        eng.run_until_done()
        assert eng.stats()["completed"] == 5
        return serving_digest(eng)

    assert digests_equal(serve(None), serve(mesh))
