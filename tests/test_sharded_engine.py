"""Multi-device FlexAI engine: the shard_map'd schedule/train paths must be
pure re-layouts of the vmapped single-device engine.  Multi-device cases run
in subprocesses (``--xla_force_host_platform_device_count`` must be set
before jax imports); route-batch padding is covered in-process."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.tasks import (invalid_task_arrays, pad_route_batch,
                              stack_task_arrays, tasks_to_arrays)


def _run_sub(script: str, devices: int, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


_PRELUDE = textwrap.dedent("""
    import jax
    import numpy as np
    from repro.compat import make_mesh
    from repro.core.environment import EnvironmentParams, build_task_queue
    from repro.core.flexai import (FlexAIAgent, FlexAIConfig, ScanFlexAI,
                                   make_schedule_fn,
                                   make_sharded_schedule_fn)
    from repro.core.hmai import HMAIPlatform
    from repro.core.platform_jax import spec_from_platform
    from repro.core.tasks import (pad_route_batch, stack_task_arrays,
                                  tasks_to_arrays)
    RS = 0.05
    def queue(seed, km=0.02):
        return build_task_queue(EnvironmentParams(
            route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
            max_times_reverse=1, max_duration_turn=4.0,
            max_duration_reverse=6.0))
    plat = HMAIPlatform(capacity_scale=RS)
    spec = spec_from_platform(plat)
""")


@pytest.mark.slow
def test_sharded_schedule_matches_vmapped():
    """4-device shard_map schedule == plain vmapped scan: identical
    placements, final platform states to fp32 tolerance.  6 routes on 4
    devices exercises the pad_route_batch path."""
    script = _PRELUDE + textwrap.dedent("""
        agent = FlexAIAgent(plat, FlexAIConfig(seed=3))
        routes = [tasks_to_arrays(queue(s)) for s in range(6)]
        batch = pad_route_batch(stack_task_arrays(routes), 4)
        mesh = make_mesh((4,), ("routes",))
        f_sh, r_sh = jax.device_get(
            make_sharded_schedule_fn(spec, mesh)(
                agent.learner.eval_p, batch))
        f_pl, r_pl = jax.device_get(
            make_schedule_fn(spec, batched=True)(
                agent.learner.eval_p, batch))
        assert np.array_equal(np.asarray(r_sh.action),
                              np.asarray(r_pl.action))
        for a, b in zip(f_sh, f_pl):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
        # padding lanes stayed no-ops
        assert not np.asarray(r_sh.valid)[len(routes):].any()
        print("OK", batch.arrival.shape[0])
    """)
    out = _run_sub(script, devices=4)
    assert "OK 8" in out


@pytest.mark.slow
def test_sharded_train_runs_and_lanes_differ():
    """ScanFlexAI over a 2-device mesh: one fused episode per lane, lanes
    keep independent seeds/weights, counters advance like the local path."""
    script = _PRELUDE + textwrap.dedent("""
        cfg = FlexAIConfig(min_replay=32, batch_size=16, update_every=4,
                           eps_decay_steps=500, replay_capacity=2048,
                           seed=4)
        mesh = make_mesh((2,), ("routes",))
        tr = ScanFlexAI(plat, cfg, lanes=2, mesh=mesh)
        routes = [queue(31), queue(32)]
        out = tr.train(routes, episodes=1)[0]
        assert len(out["lanes"]) == 2
        for lane in out["lanes"]:
            assert 0.0 <= lane["stm_rate"] <= 1.0
        w = np.asarray(tr.ts.eval_p.w1)
        assert not np.allclose(w[0], w[1])
        steps = np.asarray(tr.ts.env_steps)
        assert steps[0] == len(routes[0]) and steps[1] == len(routes[1])
        s = tr.schedule(routes[0], lane=0)
        assert s["tasks"] == len(routes[0])
        print("OK")
    """)
    out = _run_sub(script, devices=2)
    assert "OK" in out


@pytest.mark.slow
def test_placement_service_sharded_matches_unsharded():
    """FlexAIPlacementService on a 4-device mesh returns the same
    placements and summaries as the single-device service."""
    script = _PRELUDE + textwrap.dedent("""
        from repro.serve.engine import FlexAIPlacementService
        agent = FlexAIAgent(plat, FlexAIConfig(seed=6))
        queues = [queue(41), queue(42, km=0.03), queue(43)]
        base = FlexAIPlacementService(
            plat, agent.learner.eval_p, min_bucket=64)
        mesh = make_mesh((4,), ("routes",))
        shard = FlexAIPlacementService(
            plat, agent.learner.eval_p, min_bucket=64, mesh=mesh)
        r_base = base.place(queues)
        r_shard = shard.place(queues)
        assert len(r_base) == len(r_shard) == len(queues)
        for q, a, b in zip(queues, r_base, r_shard):
            assert a["tasks"] == b["tasks"] == len(q)
            assert np.array_equal(a["placements"], b["placements"])
            assert abs(a["stm_rate"] - b["stm_rate"]) < 1e-9
            assert abs(a["gvalue"] - b["gvalue"]) < 1e-6
        print("OK", shard.dispatches)
    """)
    out = _run_sub(script, devices=4)
    assert "OK" in out


def test_pad_route_batch_shapes_and_validity():
    routes = [invalid_task_arrays(10) for _ in range(3)]
    for i, r in enumerate(routes):
        r.valid[: 4 + i] = True
    batch = stack_task_arrays(routes)
    padded = pad_route_batch(batch, 4)
    assert padded.arrival.shape == (4, 10)
    assert not padded.valid[3].any()          # padding lane all-invalid
    np.testing.assert_array_equal(padded.valid[:3], batch.valid)
    # already a multiple: unchanged object
    assert pad_route_batch(padded, 2) is padded


def test_invalid_route_is_noop_through_engine():
    """A fully-invalid lane must leave its platform state at init."""
    import jax
    from repro.core.flexai import FlexAIAgent, FlexAIConfig, \
        make_schedule_fn
    from repro.core.hmai import HMAIPlatform
    from repro.core.platform_jax import spec_from_platform
    plat = HMAIPlatform(capacity_scale=0.05)
    spec = spec_from_platform(plat)
    agent = FlexAIAgent(plat, FlexAIConfig(seed=0))
    fn = make_schedule_fn(spec)
    final, recs = fn(agent.learner.eval_p, invalid_task_arrays(32))
    assert not np.asarray(recs.valid).any()
    np.testing.assert_array_equal(np.asarray(final.num_tasks),
                                  np.zeros(plat.n, np.int32))
    np.testing.assert_array_equal(np.asarray(final.E),
                                  np.zeros(plat.n, np.float32))
