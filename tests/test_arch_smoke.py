"""Deliverable (f): reduced same-family smoke config per assigned arch —
one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, cell_applicable
from repro.models.api import model_api
from repro.sharding import unbox
from repro.train.loop import TrainHyper, init_train_state, make_train_step

KEY = jax.random.PRNGKey(7)


def _smoke_batch(cfg, bs=2, seq=16):
    k1, k2 = jax.random.split(KEY)
    if cfg.frontend is not None and not cfg.is_encoder_decoder:
        t = cfg.num_frontend_tokens
        batch = {
            "tokens": jax.random.randint(k1, (bs, seq), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (bs, seq), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((bs, seq), jnp.float32),
            "frontend_embeds": jax.random.normal(KEY, (bs, t, cfg.d_model)),
        }
        return batch
    batch = {
        "tokens": jax.random.randint(k1, (bs, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (bs, seq), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((bs, seq), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        batch["frontend_embeds"] = jax.random.normal(
            KEY, (bs, max(1, seq // cfg.encoder_seq_ratio), cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = get_smoke_config(arch_id)
    api = model_api(cfg)
    params = unbox(api.init(KEY))
    state = init_train_state(params, TrainHyper())
    step = jax.jit(make_train_step(api, TrainHyper(warmup_steps=1,
                                                   total_steps=10)))
    batch = _smoke_batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch_id
    assert np.isfinite(float(metrics["grad_norm"])), arch_id
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda acc, t: acc + float(jnp.sum(jnp.abs(t[0] - t[1]))),
        jax.tree_util.tree_map(lambda a, b: (a, b), new_state.params, params),
        0.0)
    assert delta > 0.0, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = get_smoke_config(arch_id)
    api = model_api(cfg)
    params = unbox(api.init(KEY))
    bs, cache_len = 2, 24
    if cfg.is_encoder_decoder:
        cache = unbox(api.init_cache(bs, cache_len, src_len=4))
    else:
        cache = unbox(api.init_cache(bs, cache_len))
    tok = jnp.zeros((bs, 1), jnp.int32)
    logits, new_cache = jax.jit(api.decode_step)(params, cache, tok,
                                                 jnp.int32(0))
    assert logits.shape == (bs, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch_id


def test_full_configs_match_brief():
    """The full (dry-run) configs carry the exact assigned dimensions."""
    expected = {
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for arch, (l, d, h, kv, ff, v) in expected.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch


def test_moe_configs():
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.num_experts, q.num_experts_per_token) == (128, 8)
    m = get_config("moonshot-v1-16b-a3b")
    assert (m.num_experts, m.num_experts_per_token) == (64, 6)
    j = get_config("jamba-v0.1-52b")
    assert (j.num_experts, j.num_experts_per_token) == (16, 2)
    assert j.pattern.count("A") * 8 == j.num_layers  # 1:7 interleave


def test_long_500k_applicability():
    runnable = {a for a in ARCH_IDS
                if cell_applicable(get_config(a), "long_500k")[0]}
    assert runnable == {"mamba2-130m", "jamba-v0.1-52b", "h2o-danube-3-4b"}


def test_param_counts_plausible():
    """Analytic param counts are within the advertised model scale."""
    approx = {
        "mistral-large-123b": (110e9, 135e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "internvl2-76b": (60e9, 80e9),
        "qwen3-moe-30b-a3b": (25e9, 35e9),
        # brief config (48L x 64e x d_ff 1408) arithmetically gives ~28B;
        # the advertised 16B corresponds to the 27-layer release
        "moonshot-v1-16b-a3b": (22e9, 32e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "stablelm-1.6b": (1.2e9, 2.0e9),
        "h2o-danube-3-4b": (3e9, 5e9),
        "minicpm3-4b": (3e9, 5.5e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
