"""Property-based serving contract for the deadline-aware QoS layer
(``repro.serve.qos``):

* **conservation** — every submitted request ends in exactly one of
  completed / shed, and nothing is left queued after ``run_until_done``;
* **no-starvation** — under EDF-with-aging, every admitted request waits
  at most ``ceil(spread/credit) + n_requests`` admission rounds;
* **EDF dominance** — on equal-service workloads (one bucket, common
  arrival), EDF admission's deadline-miss rate is <= bucket-FIFO's;
* **preemption round-trip** — a preempted wave's checkpoint/resume through
  the ``PlatformState`` seam reproduces the uninterrupted scan bit-exactly;
* **crash-replay conservation** — killing a durable engine after any
  number of admission rounds and replaying from its packed snapshot
  still ends with every submitted uid in exactly one of completed /
  dead-letter (nothing lost, nothing duplicated by the replay).

Each property is a plain check function; with ``hypothesis`` installed
(requirements-dev.txt) the checks run under randomized search with an
example budget bounded by ``SERVE_QOS_EXAMPLES`` (CI sets a small budget).
Without it — the air-gapped case — the same checks run over a fixed-seed
parameter sweep, so the serving contract is enforced either way instead
of skipping away.

Queueing-discipline properties run on the ``stub`` executor (state
pass-through, no device work) so example counts stay affordable; the
round-trip property uses the real scan executor.
"""
import math
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.hmai import HMAIPlatform
from repro.core.flexai import FlexAIAgent, FlexAIConfig
from repro.core.tasks import TaskArrays
from repro.serve.durability import (DurableQoSEngine, FaultInjection,
                                    pack_engine)
from repro.serve.qos import COMPLETED, QoSConfig, QoSPlacementEngine, SHED

MAX_EXAMPLES = int(os.environ.get("SERVE_QOS_EXAMPLES", "30"))

RS = 0.05
_PLATFORM = HMAIPlatform(capacity_scale=RS)
_AGENT = FlexAIAgent(_PLATFORM, FlexAIConfig(seed=3))


def _route(n: int, seed: int = 0) -> TaskArrays:
    """Synthetic [n] route (no environment build cost)."""
    rng = np.random.default_rng(seed)
    return TaskArrays(
        kind=rng.integers(0, 3, n).astype(np.int32),
        arrival=np.sort(rng.uniform(0, 0.01 * n, n)).astype(np.float32),
        safety=np.full(n, 0.05, np.float32),
        group=np.zeros(n, np.int32),
        valid=np.ones(n, bool))


def _engine(cfg: QoSConfig, executor="stub") -> QoSPlacementEngine:
    return QoSPlacementEngine(_PLATFORM, _AGENT.learner.eval_p, cfg,
                              backlog_scale=_AGENT.cfg.backlog_scale,
                              executor=executor)


def _miss_count(eng: QoSPlacementEngine) -> int:
    return (len(eng.dead_letter)
            + sum(1 for r in eng.completed if r.slack < 0.0))


# ---------------------------------------------------------------------------
# property checks (shared by the hypothesis and fixed-seed drivers)
# ---------------------------------------------------------------------------

def check_conservation(policy, slots, preempt, shed, jobs, seed):
    """Every submitted uid ends exactly once in completed|shed; the queues
    fully drain."""
    eng = _engine(QoSConfig(policy=policy, slots=slots, preempt=preempt,
                            shed=shed, chunk=16, min_bucket=16))
    for i, (n, arr, budget) in enumerate(jobs):
        eng.submit(_route(n, seed + i), arrival=arr, deadline=arr + budget)
    eng.run_until_done()
    assert not eng.backlog and not eng.pending and not eng.preempted
    done = [r.uid for r in eng.completed]
    shed_uids = [d["uid"] for d in eng.dead_letter]
    assert sorted(done + shed_uids) == list(range(len(jobs)))
    assert all(r.status == COMPLETED for r in eng.completed)
    s = eng.stats()
    assert s["submitted"] == len(jobs)
    assert s["completed"] + s["shed"] == len(jobs)


def check_crash_replay_conservation(policy, slots, kill_after, jobs, seed):
    """Kill a durable engine after ``kill_after`` admission rounds,
    replay from its in-memory snapshot, and require conservation on the
    combined history: every submitted uid in exactly one of completed /
    dead-letter, queues drained, dead-letter entries from before the
    crash preserved by the replay."""
    cfg = QoSConfig(policy=policy, slots=slots, chunk=16, min_bucket=16)

    def submit_all(eng):
        for i, (n, arr, budget) in enumerate(jobs):
            eng.submit(_route(n, seed + i), arrival=arr,
                       deadline=arr + budget)

    eng = DurableQoSEngine(_PLATFORM, _AGENT.learner.eval_p, cfg,
                           backlog_scale=_AGENT.cfg.backlog_scale,
                           executor="stub")
    submit_all(eng)
    eng.serve_waves(kill_after)
    shed_before = [d["uid"] for d in eng.dead_letter]

    arrays, meta = pack_engine(eng)
    resumed = DurableQoSEngine.from_packed(
        arrays, meta, _PLATFORM,
        backlog_scale=_AGENT.cfg.backlog_scale, executor="stub")
    resumed.run_until_done()

    assert not resumed.backlog and not resumed.pending \
        and not resumed.preempted
    done = [r.uid for r in resumed.completed]
    shed_uids = [d["uid"] for d in resumed.dead_letter]
    assert sorted(done + shed_uids) == list(range(len(jobs)))
    assert shed_uids[: len(shed_before)] == shed_before
    assert all(r.status == COMPLETED for r in resumed.completed)
    s = resumed.stats()
    assert s["completed"] + s["shed"] == len(jobs)


def check_continuous_conservation(slots, preempt, shed, jobs, seed):
    """Continuous batching keeps the conservation contract: lane refill
    and mid-flight overrun shedding still resolve every submitted uid
    exactly once, with nothing left queued or in flight."""
    eng = _engine(QoSConfig(policy="edf", slots=slots, preempt=preempt,
                            shed=shed, chunk=16, min_bucket=16,
                            continuous=True))
    for i, (n, arr, budget) in enumerate(jobs):
        eng.submit(_route(n, seed + i), arrival=arr, deadline=arr + budget)
    eng.run_until_done()
    assert not eng.backlog and not eng.pending and not eng.preempted
    done = [r.uid for r in eng.completed]
    shed_uids = [d["uid"] for d in eng.dead_letter]
    assert sorted(done + shed_uids) == list(range(len(jobs)))
    assert all(r.status == COMPLETED for r in eng.completed)
    s = eng.stats()
    assert s["completed"] + s["shed"] == len(jobs)
    assert s["in_flight"] == 0 and s["queued"] == 0


ADVERSARIAL_KINDS = ("bursty", "duplicate", "inverted")


def _adversarial_jobs(kind, n_jobs, seed):
    """Adversarial arrival streams as (n_tasks, arrival, budget) tuples:
    ``bursty`` collapses every arrival onto a few shared instants,
    ``duplicate`` replays one identical submission n times, and
    ``inverted`` hands later arrivals strictly earlier absolute
    deadlines (the anti-EDF ordering)."""
    rng = np.random.default_rng(seed)
    if kind == "bursty":
        instants = rng.uniform(0.0, 0.2, max(1, n_jobs // 4))
        return [(int(rng.integers(1, 41)), float(rng.choice(instants)),
                 float(rng.uniform(0.005, 0.6))) for _ in range(n_jobs)]
    if kind == "duplicate":
        job = (int(rng.integers(1, 41)), float(rng.uniform(0.0, 0.1)),
               float(rng.uniform(0.005, 0.6)))
        return [job] * n_jobs
    arrivals = np.sort(rng.uniform(0.0, 0.4, n_jobs))
    latest = float(arrivals[-1])
    # budget shrinks faster than arrival grows, so the absolute deadline
    # (arrival + budget) strictly decreases as arrival increases
    return [(int(rng.integers(1, 41)), float(a),
             float(2.2 * (latest - a) + 0.01)) for a in arrivals]


def check_adversarial_conservation(kind, policy, slots, n_jobs, seed):
    """Conservation must survive adversarial arrival shapes, not just the
    uniform random streams the base property draws."""
    check_conservation(policy=policy, slots=slots, preempt=True, shed=True,
                       jobs=_adversarial_jobs(kind, n_jobs, seed), seed=seed)


def check_fault_shed_conservation(kind, n_jobs, core, at_frac, seed):
    """Conservation through fault-induced shedding: a mid-stream dead
    core stretches the service cost (set_health) and sheds marginal
    routes — every submitted uid still ends exactly once in completed |
    dead-letter, and anything shed after detection carries a reason."""
    jobs = _adversarial_jobs(kind, n_jobs, seed)
    cfg = QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16)
    at = at_frac * 0.2
    eng = DurableQoSEngine(
        _PLATFORM, _AGENT.learner.eval_p, cfg,
        backlog_scale=_AGENT.cfg.backlog_scale, executor="stub",
        faults=[FaultInjection(at_time=at, core=core)],
        dead_after_segments=1)
    for i, (n, arr, budget) in enumerate(jobs):
        eng.submit(_route(n, seed + i), arrival=arr, deadline=arr + budget)
    eng.run_until_done()
    assert not eng.backlog and not eng.pending and not eng.preempted
    done = [r.uid for r in eng.completed]
    shed_uids = [d["uid"] for d in eng.dead_letter]
    assert sorted(done + shed_uids) == list(range(len(jobs)))
    assert all(d["reason"] == "infeasible" for d in eng.dead_letter)
    s = eng.stats()
    assert s["completed"] + s["shed"] == len(jobs)
    # faults are conserved too: fired at a dispatch, or still pending
    # when the stream drains first — never silently dropped
    # (guaranteed-firing coverage lives in tests/test_durability.py)
    assert s["faults_fired"] + len(eng.pending_faults) == 1


def _serve_stream(credit, long_deadline, tight_deadline, n_stream, seed):
    """One loose long-bucket request against a continuing stream of tight
    short-bucket newcomers, one fresh arrival per service round (the
    cross-bucket starvation scenario aging exists for)."""
    eng = _engine(QoSConfig(policy="edf", aging_credit=credit, slots=1,
                            preempt=False, shed=False,
                            chunk=16, min_bucket=16))
    long_r = eng.submit(_route(60, seed), arrival=0.0,
                        deadline=long_deadline)
    gap = 0.9 * 16 * eng.svc  # slightly faster than short-wave service:
    for i in range(n_stream):  # the tight backlog never runs dry
        eng.submit(_route(12, seed + 1 + i), arrival=i * gap,
                   deadline=tight_deadline)
    eng.run_until_done()
    return long_r


def check_no_starvation(long_budget, credit, seed):
    """Aging credit bounds cross-bucket admission delay: against an
    endless tighter-deadline stream, a request waits at most
    ``ceil(spread/credit) + O(1)`` waves — and the same stream *does*
    starve it for the whole stream length when the credit is zero, so the
    bound is earned by aging, not by the workload."""
    tight = 0.01
    spread = long_budget - tight
    k = math.ceil(spread / credit) + 3
    n_stream = k + 10  # stream strictly outlasts the bound
    long_r = _serve_stream(credit, long_budget, tight, n_stream, seed)
    assert long_r.status == COMPLETED
    assert long_r.waves_waited <= k, (long_r.waves_waited, k)
    starved = _serve_stream(0.0, long_budget, tight, n_stream, seed)
    assert starved.waves_waited >= n_stream - 3


def check_edf_dominates(n_jobs, slots, budgets, seed):
    """On equal-service workloads (one bucket, common arrival) EDF
    admission never misses more deadlines than bucket-FIFO.  Equal service
    keeps the classic exchange argument airtight: any FIFO schedule can be
    reordered toward EDF one swap at a time without adding a miss."""
    def serve(policy):
        eng = _engine(QoSConfig(policy=policy, slots=slots, preempt=False,
                                shed=(policy == "edf"),
                                chunk=16, min_bucket=16))
        for i in range(n_jobs):
            # fixed length -> one bucket -> identical wave service time
            eng.submit(_route(16, seed + i), arrival=0.0,
                       deadline=budgets[i % len(budgets)])
        eng.run_until_done()
        return eng
    assert _miss_count(serve("edf")) <= _miss_count(serve("fifo"))


def check_preemption_roundtrip(n_long, n_short, arrive_frac, seed):
    """A preempted wave resumes from its PlatformState checkpoint with the
    exact placements/metrics of an uninterrupted scan."""
    from repro.core.flexai.engine import make_schedule_fn
    from repro.core.tasks import pad_task_arrays

    long_route = _route(n_long, seed)
    short_route = _route(n_short, seed + 1)
    cfg = QoSConfig(policy="edf", slots=2, chunk=8, min_bucket=16,
                    laxity_s=1e-4, aging_credit=0.0)
    eng = _engine(cfg, executor=None)  # real scan executor
    service_long = eng._bucket(n_long) * eng.svc
    r_long = eng.submit(long_route, arrival=0.0,
                        deadline=10.0 + service_long)
    # short arrives mid-wave with a deadline tight enough to preempt but
    # feasible enough not to be shed
    arrive = arrive_frac * service_long
    r_short = eng.submit(short_route, arrival=arrive,
                         deadline=arrive + eng._bucket(n_short) * eng.svc
                         + 3 * cfg.chunk * eng.svc)
    eng.run_until_done()
    assert r_long.status == COMPLETED and r_short.status == COMPLETED

    ref_fn = make_schedule_fn(eng.spec, _AGENT.cfg.backlog_scale)
    final, recs = ref_fn(_AGENT.learner.eval_p,
                         pad_task_arrays(long_route, r_long.bucket))
    ref_actions = np.asarray(recs.action)[: n_long]
    np.testing.assert_array_equal(r_long.summary["placements"], ref_actions)
    # the checkpointed lane's final metrics must match bit-for-bit
    assert r_long.summary["stm_rate"] == pytest.approx(
        float(np.asarray(recs.met)[: n_long].mean()), abs=0)
    return eng.preemption_count


# ---------------------------------------------------------------------------
# hypothesis drivers
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = settings(max_examples=MAX_EXAMPLES, deadline=None)
    _JOBS = st.lists(
        st.tuples(st.integers(1, 40),          # n_tasks
                  st.floats(0.0, 0.5),         # arrival
                  st.floats(0.005, 0.6)),      # deadline budget
        min_size=1, max_size=12)

    @SETTINGS
    @given(policy=st.sampled_from(["edf", "fifo"]), slots=st.integers(1, 3),
           preempt=st.booleans(), shed=st.booleans(), jobs=_JOBS,
           seed=st.integers(0, 999))
    def test_conservation(policy, slots, preempt, shed, jobs, seed):
        check_conservation(policy, slots, preempt, shed, jobs, seed)

    @settings(max_examples=min(15, MAX_EXAMPLES), deadline=None)
    @given(long_budget=st.floats(0.05, 0.5), credit=st.floats(0.01, 0.05),
           seed=st.integers(0, 999))
    def test_no_starvation_bound(long_budget, credit, seed):
        check_no_starvation(long_budget, credit, seed)

    @SETTINGS
    @given(n_jobs=st.integers(2, 12), slots=st.integers(1, 2),
           budgets=st.lists(st.floats(0.005, 0.25), min_size=12,
                            max_size=12),
           seed=st.integers(0, 999))
    def test_edf_dominates_fifo(n_jobs, slots, budgets, seed):
        check_edf_dominates(n_jobs, slots, budgets, seed)

    @settings(max_examples=min(8, MAX_EXAMPLES), deadline=None)
    @given(n_long=st.integers(33, 64), n_short=st.integers(4, 16),
           arrive_frac=st.floats(0.1, 0.6), seed=st.integers(0, 99))
    def test_preemption_roundtrip_bit_exact(n_long, n_short, arrive_frac,
                                            seed):
        check_preemption_roundtrip(n_long, n_short, arrive_frac, seed)

    @SETTINGS
    @given(policy=st.sampled_from(["edf", "fifo"]), slots=st.integers(1, 3),
           kill_after=st.integers(0, 8), jobs=_JOBS,
           seed=st.integers(0, 999))
    def test_crash_replay_conservation(policy, slots, kill_after, jobs,
                                       seed):
        check_crash_replay_conservation(policy, slots, kill_after, jobs,
                                        seed)

    @SETTINGS
    @given(slots=st.integers(1, 3), preempt=st.booleans(),
           shed=st.booleans(), jobs=_JOBS, seed=st.integers(0, 999))
    def test_continuous_conservation(slots, preempt, shed, jobs, seed):
        check_continuous_conservation(slots, preempt, shed, jobs, seed)

    @SETTINGS
    @given(kind=st.sampled_from(ADVERSARIAL_KINDS),
           policy=st.sampled_from(["edf", "fifo"]),
           slots=st.integers(1, 3), n_jobs=st.integers(2, 12),
           seed=st.integers(0, 999))
    def test_adversarial_conservation(kind, policy, slots, n_jobs, seed):
        check_adversarial_conservation(kind, policy, slots, n_jobs, seed)

    @settings(max_examples=min(15, MAX_EXAMPLES), deadline=None)
    @given(kind=st.sampled_from(ADVERSARIAL_KINDS),
           n_jobs=st.integers(2, 10),
           core=st.integers(0, _PLATFORM.n - 1),
           at_frac=st.floats(0.0, 1.0), seed=st.integers(0, 999))
    def test_fault_shed_conservation(kind, n_jobs, core, at_frac, seed):
        check_fault_shed_conservation(kind, n_jobs, core, at_frac, seed)


# ---------------------------------------------------------------------------
# fixed-seed fallback drivers (air-gapped: no hypothesis available)
# ---------------------------------------------------------------------------

_FALLBACK_SEEDS = list(range(min(MAX_EXAMPLES, 20)))


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS)
def test_conservation_seeded(seed):
    rng = np.random.default_rng(seed)
    jobs = [(int(rng.integers(1, 41)), float(rng.uniform(0, 0.5)),
             float(rng.uniform(0.005, 0.6)))
            for _ in range(int(rng.integers(1, 13)))]
    check_conservation(policy=("edf", "fifo")[seed % 2],
                       slots=int(rng.integers(1, 4)),
                       preempt=bool(seed % 3), shed=bool((seed // 2) % 2),
                       jobs=jobs, seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS[:10])
def test_no_starvation_bound_seeded(seed):
    rng = np.random.default_rng(1000 + seed)
    check_no_starvation(long_budget=float(rng.uniform(0.05, 0.5)),
                        credit=float(rng.uniform(0.01, 0.05)), seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS)
def test_edf_dominates_fifo_seeded(seed):
    rng = np.random.default_rng(2000 + seed)
    check_edf_dominates(n_jobs=int(rng.integers(2, 13)),
                        slots=int(rng.integers(1, 3)),
                        budgets=[float(rng.uniform(0.005, 0.25))
                                 for _ in range(12)],
                        seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS)
def test_crash_replay_conservation_seeded(seed):
    rng = np.random.default_rng(4000 + seed)
    jobs = [(int(rng.integers(1, 41)), float(rng.uniform(0, 0.5)),
             float(rng.uniform(0.005, 0.6)))
            for _ in range(int(rng.integers(1, 13)))]
    check_crash_replay_conservation(policy=("edf", "fifo")[seed % 2],
                                    slots=int(rng.integers(1, 4)),
                                    kill_after=int(rng.integers(0, 9)),
                                    jobs=jobs, seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS)
def test_continuous_conservation_seeded(seed):
    rng = np.random.default_rng(7000 + seed)
    jobs = [(int(rng.integers(1, 41)), float(rng.uniform(0, 0.5)),
             float(rng.uniform(0.005, 0.6)))
            for _ in range(int(rng.integers(1, 13)))]
    check_continuous_conservation(slots=int(rng.integers(1, 4)),
                                  preempt=bool(seed % 3),
                                  shed=bool((seed // 2) % 2),
                                  jobs=jobs, seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS)
def test_adversarial_conservation_seeded(seed):
    rng = np.random.default_rng(5000 + seed)
    check_adversarial_conservation(
        kind=ADVERSARIAL_KINDS[seed % len(ADVERSARIAL_KINDS)],
        policy=("edf", "fifo")[seed % 2], slots=int(rng.integers(1, 4)),
        n_jobs=int(rng.integers(2, 13)), seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS[:10])
def test_fault_shed_conservation_seeded(seed):
    rng = np.random.default_rng(6000 + seed)
    check_fault_shed_conservation(
        kind=ADVERSARIAL_KINDS[seed % len(ADVERSARIAL_KINDS)],
        n_jobs=int(rng.integers(2, 11)),
        core=int(rng.integers(0, _PLATFORM.n)),
        at_frac=float(rng.uniform(0.0, 1.0)), seed=seed)


@pytest.mark.skipif(HAVE_HYPOTHESIS,
                    reason="hypothesis drives this property instead")
@pytest.mark.parametrize("seed", _FALLBACK_SEEDS[:6])
def test_preemption_roundtrip_bit_exact_seeded(seed):
    rng = np.random.default_rng(3000 + seed)
    check_preemption_roundtrip(n_long=int(rng.integers(33, 65)),
                               n_short=int(rng.integers(4, 17)),
                               arrive_frac=float(rng.uniform(0.1, 0.6)),
                               seed=seed)


def test_preemption_actually_fires():
    """Guard against the round-trip property passing vacuously: this
    construction must preempt at least once."""
    preempts = check_preemption_roundtrip(n_long=64, n_short=8,
                                          arrive_frac=0.3, seed=0)
    assert preempts >= 1


# ---------------------------------------------------------------------------
# deterministic spot-checks
# ---------------------------------------------------------------------------

def test_stats_mid_drain_honest(fixed_seed):
    """Mid-drain ``stats()`` must not deflate the miss rate with work that
    has no verdict yet (ISSUE 10 bugfix): the denominator is *resolved*
    requests only, and queued / in-flight counts are reported separately.
    The old submitted-denominated rate read 1/4 here."""
    eng = _engine(QoSConfig(policy="edf", slots=1, chunk=16, min_bucket=16,
                            preempt=False, shed=False))
    tight = eng.submit(_route(16, fixed_seed), arrival=0.0,
                       deadline=0.5 * 16 * eng.svc)  # will finish late
    for i in range(3):
        eng.submit(_route(16, fixed_seed + 1 + i), arrival=0.0,
                   deadline=100.0)
    eng._run_wave(eng._next_wave())  # serve only the tight head
    assert tight.status == COMPLETED and tight.slack < 0.0
    s = eng.stats()
    assert s["submitted"] == 4
    assert s["resolved"] == 1 and s["completed"] == 1
    assert s["queued"] == 3 and s["in_flight"] == 0
    assert s["miss_rate"] == 1.0          # 1 resolved, 1 missed
    eng.run_until_done()
    done = eng.stats()
    assert done["resolved"] == 4 and done["queued"] == 0
    assert done["miss_rate"] == pytest.approx(1 / 4)


def test_stats_counts_in_flight_lanes(fixed_seed):
    """A halted continuous wave's occupants are ``in_flight`` — neither
    resolved nor queued."""
    eng = _engine(QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16,
                            preempt=False, shed=False, continuous=True))
    eng.submit(_route(60, fixed_seed), arrival=0.0, deadline=100.0)
    eng.submit(_route(60, fixed_seed + 1), arrival=0.0, deadline=100.0)
    eng.submit(_route(60, fixed_seed + 2), arrival=0.0, deadline=100.0)
    wave = eng._next_wave()
    orig = eng._after_segment
    eng._after_segment = lambda w: setattr(eng, "_halt", True)
    eng._run_wave(wave)  # one segment, then the durability-style halt
    eng._after_segment = orig
    s = eng.stats()
    assert s["in_flight"] == 2 and s["queued"] == 1
    assert s["resolved"] == 0 and s["miss_rate"] == 0.0


def test_refilled_lane_state_is_reinitialized(fixed_seed):
    """Continuous batching must not leak platform state across lane
    occupants: a request admitted by refill produces placements
    bit-identical to serving it alone on a fresh engine."""
    cfg = QoSConfig(policy="edf", slots=1, chunk=8, min_bucket=16,
                    preempt=False, shed=False, continuous=True)
    eng = _engine(cfg, executor=None)  # real scan executor
    a = eng.submit(_route(16, fixed_seed), arrival=0.0, deadline=100.0)
    b = eng.submit(_route(16, fixed_seed + 1), arrival=0.0, deadline=100.0)
    eng.run_until_done()
    assert a.status == COMPLETED and b.status == COMPLETED
    assert eng.stats()["refills"] >= 1  # b rode a's wave via refill
    for req, seed in ((a, fixed_seed), (b, fixed_seed + 1)):
        solo = _engine(cfg, executor=None)
        ref = solo.submit(_route(16, seed), arrival=0.0, deadline=100.0)
        solo.run_until_done()
        np.testing.assert_array_equal(req.summary["placements"],
                                      ref.summary["placements"])
        assert req.summary["stm_rate"] == ref.summary["stm_rate"]


def test_continuous_starvation_bound_survives_refill(fixed_seed):
    """Refill admission must not bypass aging: a long-bucket request
    facing an endless short-bucket stream served through one continuously
    refilled wave is still admitted within the ``spread/credit + O(1)``
    bound (every refill round that admits anyone ages the backlog)."""
    credit, long_deadline, tight = 0.02, 0.3, 0.01
    k = math.ceil((long_deadline - tight) / credit) + 3
    n_stream = k + 10  # stream strictly outlasts the bound
    eng = _engine(QoSConfig(policy="edf", aging_credit=credit, slots=1,
                            preempt=False, shed=False, chunk=16,
                            min_bucket=16, continuous=True))
    long_r = eng.submit(_route(60, fixed_seed), arrival=0.0,
                        deadline=long_deadline)
    gap = 0.9 * 16 * eng.svc  # arrivals slightly outpace short service
    for i in range(n_stream):
        eng.submit(_route(12, fixed_seed + 1 + i), arrival=i * gap,
                   deadline=tight)
    eng.run_until_done()
    assert eng.stats()["refills"] >= 1  # the stream rode refilled lanes
    assert long_r.status == COMPLETED
    assert long_r.waves_waited <= k, (long_r.waves_waited, k)


def test_wave_inherits_aging_credit(fixed_seed):
    """A passed-over request keeps its earned aging credit when finally
    packed: the wave's counter starts at the member's, so a preemption
    right after admission cannot reset the anti-starvation clock."""
    eng = _engine(QoSConfig(policy="edf", slots=1, chunk=16, min_bucket=16,
                            preempt=False, shed=False))
    eng.submit(_route(10, fixed_seed), arrival=0.0, deadline=1.0)
    eng.submit(_route(10, fixed_seed + 1), arrival=0.0, deadline=2.0)
    loose = eng.submit(_route(10, fixed_seed + 2), arrival=0.0,
                       deadline=5.0)
    eng._run_wave(eng._next_wave())
    eng._run_wave(eng._next_wave())
    wave = eng._next_wave()
    assert [r.uid for r in wave.requests] == [loose.uid]
    assert wave.waves_waited == loose.waves_waited == 2


def test_set_health_shrinks_admission(fixed_seed):
    """Degradation-aware admission: a route that fits on the healthy pool
    is shed once ``set_health`` reports most of the capacity gone —
    before a single doomed segment dispatches — and an all-ones row
    restores the healthy service cost exactly."""
    eng = _engine(QoSConfig(policy="edf", chunk=16, min_bucket=16))
    deadline = 2.0 * 16 * eng.svc
    healthy_need = eng._service_need(16)
    assert healthy_need < deadline
    h = np.zeros(eng.spec.n)
    h[0] = 1.0                    # one survivor carries the whole pool
    eng.set_health(h)
    assert eng.svc_scale > 1.0
    assert eng._service_need(16) > healthy_need
    doomed = eng.submit(_route(16, fixed_seed), arrival=0.0,
                        deadline=deadline)
    eng.run_until_done()
    assert doomed.status == SHED
    assert eng.dead_letter[0]["reason"] == "infeasible"
    assert eng.dispatches == 0    # shed at admission, not after dispatch
    eng.set_health(np.ones(eng.spec.n))
    assert eng.svc == eng.base_svc
    assert eng._service_need(16) == healthy_need


def test_shed_goes_to_dead_letter(fixed_seed):
    """A request whose budget can't cover even solo service is shed with a
    reason, never served."""
    eng = _engine(QoSConfig(policy="edf", chunk=16, min_bucket=16))
    doomed = eng.submit(_route(16, fixed_seed), arrival=0.0,
                        deadline=0.25 * 16 * eng.svc)
    ok = eng.submit(_route(16, fixed_seed + 1), arrival=0.0, deadline=10.0)
    eng.run_until_done()
    assert doomed.status == SHED
    assert ok.status == COMPLETED
    assert [d["uid"] for d in eng.dead_letter] == [doomed.uid]
    assert eng.dead_letter[0]["reason"] == "infeasible"


def test_fifo_policy_matches_pre_qos_admission(fixed_seed):
    """policy="fifo" reproduces oldest-head bucket admission: submit order
    within a bucket, head picks the bucket."""
    eng = _engine(QoSConfig(policy="fifo", slots=2, chunk=16,
                            min_bucket=16))
    eng.submit(_route(60, fixed_seed), arrival=0.0, deadline=100.0)   # b=64
    eng.submit(_route(10, fixed_seed + 1), arrival=0.0, deadline=1.0)  # b=16
    eng.submit(_route(12, fixed_seed + 2), arrival=0.0, deadline=2.0)  # b=16
    eng.submit(_route(50, fixed_seed + 3), arrival=0.0, deadline=0.5)  # b=64
    eng.run_until_done()
    assert eng.wave_log == [[0, 3], [1, 2]]


def test_edf_reorders_by_deadline(fixed_seed):
    """Same workload under EDF: the tight bucket-64 head drags its bucket
    first (deadline order within the wave), then the bucket-16 pair."""
    eng = _engine(QoSConfig(policy="edf", slots=2, chunk=16, min_bucket=16,
                            preempt=False, shed=False))
    eng.submit(_route(60, fixed_seed), arrival=0.0, deadline=100.0)
    eng.submit(_route(10, fixed_seed + 1), arrival=0.0, deadline=1.0)
    eng.submit(_route(12, fixed_seed + 2), arrival=0.0, deadline=2.0)
    eng.submit(_route(50, fixed_seed + 3), arrival=0.0, deadline=0.5)
    eng.run_until_done()
    assert eng.wave_log == [[3, 0], [1, 2]]
