"""In-scan fault model: trace construction, health-aware engines, and the
bit-exact fused-vs-task-major-replay parity contract (ISSUE 8).

The reference semantics of a fault trace is ``faults.replay_actions``:
one ``platform_step`` per task in stream order with the trace row
installed first.  Every fused engine that emits records in task order
(worst/ATA/FlexAI/GA/SA, and the pipeline wavefront vs its task-major
reference) must reproduce it exactly under the same trace.  Min-Min
commits in completion-time order, not task order, so its contract is the
incremental-vs-rebuild equality plus a NumPy replication of the
window-level decisions driving eager ``platform_step`` commits.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.faults import (FaultEvent, build_health_trace, healthy_trace,
                               random_fault_events, replay_actions,
                               window_health)
from repro.core.flexai import FlexAIConfig
from repro.core.flexai.dqn import init_qnet
from repro.core.flexai.engine import (make_schedule_fn, make_train_fn,
                                      train_init)
from repro.core.hmai import HMAIPlatform
from repro.core.pipeline import (build_stage_plan,
                                 make_pipeline_reference_fn,
                                 make_pipeline_schedule_fn, stage_state_dim)
from repro.core.platform_jax import (HEALTH_FLOOR, health_capacity,
                                     platform_init, spec_from_platform,
                                     state_from_platform, with_health)
from repro.core.schedulers.metaheuristic_jax import (GAConfig, SAConfig,
                                                     _sa_window,
                                                     make_metaheuristic_fn,
                                                     window_fitness)
from repro.core.schedulers.scan import ata_scan, minmin_scan, worst_scan
from repro.core.tasks import tasks_to_arrays, window_task_arrays

RS = 0.05


def _queue(seed, km=0.06):
    return build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0))


def _platform():
    return HMAIPlatform(capacity_scale=RS)


def _setup(seed=11, fault_seed=5):
    plat = _platform()
    spec = spec_from_platform(plat)
    ta = tasks_to_arrays(_queue(seed))
    t = ta.arrival.shape[0]
    events = random_fault_events(fault_seed, t, plat.n, n_faults=2)
    trace = build_health_trace(t, plat.n, events)
    return plat, spec, ta, trace


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# trace construction
# ---------------------------------------------------------------------------

def test_build_health_trace_carry_forward():
    tr = build_health_trace(6, 3, [FaultEvent(2, 1, 0.0),
                                   FaultEvent(4, 1, 1.0),
                                   FaultEvent(3, 0, 0.5)])
    assert tr.shape == (6, 3)
    np.testing.assert_array_equal(tr[:, 2], np.ones(6))      # untouched
    np.testing.assert_array_equal(tr[:, 1], [1, 1, 0, 0, 1, 1])
    np.testing.assert_array_equal(tr[:, 0], [1, 1, 1, .5, .5, .5])


def test_build_health_trace_rejects_bad_core():
    with pytest.raises(ValueError):
        build_health_trace(4, 2, [FaultEvent(0, 2, 0.0)])


def test_random_fault_events_deterministic_with_survivor():
    ev1 = random_fault_events(9, 100, 6, n_faults=3)
    ev2 = random_fault_events(9, 100, 6, n_faults=3)
    assert ev1 == ev2
    # n_faults clamps below n_cores: some core never appears in a schedule
    ev = random_fault_events(3, 100, 4, n_faults=99, recover=False)
    assert len({e.core for e in ev}) <= 3
    tr = build_health_trace(100, 4, ev)
    assert (tr > 0.0).any(axis=1).all()                      # a survivor per row


def test_window_health_samples_window_starts():
    tr = np.arange(14, dtype=np.float32).reshape(7, 2)
    wh = np.asarray(window_health(tr, 3))
    assert wh.shape == (3, 2)
    np.testing.assert_array_equal(wh[0], tr[0])
    np.testing.assert_array_equal(wh[1], tr[3])
    np.testing.assert_array_equal(wh[2], tr[6])              # tail pad row


def test_with_health_semantics():
    state = platform_init(4)
    s = with_health(state, jnp.asarray([1.0, 0.5, 0.0, 1.0]))
    np.testing.assert_array_equal(np.asarray(s.alive),
                                  [True, True, False, True])
    eff = np.asarray(health_capacity(s))
    np.testing.assert_allclose(eff, [1.0, 0.5, HEALTH_FLOOR, 1.0])


# ---------------------------------------------------------------------------
# healthy trace == no trace (the bit-exact no-regression identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", [worst_scan, ata_scan, minmin_scan])
def test_healthy_trace_is_identity(engine):
    plat, spec, ta, _ = _setup()
    ones = healthy_trace(ta.arrival.shape[0], plat.n)
    f_none, r_none = jax.jit(engine)(spec, ta)
    f_ones, r_ones = jax.jit(functools.partial(engine, health=ones))(spec, ta)
    _assert_tree_equal(r_none, r_ones)
    _assert_tree_equal(f_none, f_ones)


# ---------------------------------------------------------------------------
# fused fault-trace runs vs the task-major replay (bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", [worst_scan, ata_scan])
def test_heuristic_replay_parity(engine):
    plat, spec, ta, trace = _setup()
    final, recs = jax.jit(functools.partial(engine, health=trace))(spec, ta)
    rfinal, rrecs = replay_actions(spec, ta, recs.action, trace)
    _assert_tree_equal(recs, rrecs)
    _assert_tree_equal(final, rfinal)


def test_flexai_replay_parity():
    plat, spec, ta, trace = _setup()
    params = init_qnet(jax.random.PRNGKey(2), 3 + 5 * plat.n, plat.n)
    fn = make_schedule_fn(spec)
    final, recs = fn(params, ta, health=trace)
    rfinal, rrecs = replay_actions(spec, ta, recs.action, trace)
    _assert_tree_equal(recs, rrecs)
    _assert_tree_equal(final, rfinal)


@pytest.mark.parametrize("name,cfg", [
    ("ga", GAConfig(population=8, generations=3)),
    ("sa", SAConfig(iters=30, chains=4)),
    ("sa", SAConfig(iters=30, chains=4, tempering=True, exchange_every=5)),
])
def test_metaheuristic_replay_parity(name, cfg):
    plat, spec, ta, trace = _setup()
    fn = make_metaheuristic_fn(spec, name, cfg)
    final, recs = fn(jax.random.PRNGKey(0), ta, health=trace)
    # windowed engines hold the window-start health row for the whole
    # window: the replay's per-task trace is the window-expanded one, over
    # the same zero-padded task stream the window reshape produced
    win = window_task_arrays(ta, cfg.window)
    flat = jax.tree_util.tree_map(
        lambda a: a.reshape(-1, *a.shape[2:]), win)
    wtrace = np.repeat(np.asarray(window_health(trace, cfg.window)),
                       cfg.window, axis=0)
    rfinal, rrecs = replay_actions(spec, flat, recs.action, wtrace)
    _assert_tree_equal(recs, rrecs)
    _assert_tree_equal(final, rfinal)


def test_minmin_incremental_matches_rebuild_under_trace():
    plat, spec, ta, trace = _setup()
    f_inc, r_inc = jax.jit(functools.partial(
        minmin_scan, incremental=True, health=trace))(spec, ta)
    f_reb, r_reb = jax.jit(functools.partial(
        minmin_scan, incremental=False, health=trace))(spec, ta)
    _assert_tree_equal(r_inc, r_reb)
    _assert_tree_equal(f_inc, f_reb)


def test_minmin_window_decisions_match_numpy_reference():
    """Replicate the window-level Min-Min decision rule in NumPy f32 —
    same ``max(arrival, avail) + exec/eff`` expression, same row-major
    flat-argmin tie-break — driving eager ``platform_step`` commits, and
    demand the fused run's records match bit-exactly."""
    from repro.core.platform_jax import platform_step

    plat, spec, ta, trace = _setup()
    window = 30
    final, recs = jax.jit(functools.partial(
        minmin_scan, window=window, health=trace))(spec, ta)

    win = window_task_arrays(ta, window)
    wh = np.asarray(window_health(trace, window))
    exec_t = np.asarray(spec.exec_time, np.float32)
    n = plat.n
    step = jax.jit(platform_step)
    state = platform_init(n)
    ref_actions, ref_valid = [], []
    for w in range(np.asarray(win.arrival).shape[0]):
        wtasks = jax.tree_util.tree_map(lambda a, w=w: a[w], win)
        state = with_health(state, jnp.asarray(wh[w]))
        eff = np.asarray(health_capacity(state), np.float32)
        alive = np.asarray(state.alive, bool)
        arrival = np.asarray(wtasks.arrival, np.float32)
        kind = np.asarray(wtasks.kind)
        scheduled = ~np.asarray(wtasks.valid, bool)
        for _ in range(window):
            avail = np.asarray(state.avail, np.float32)
            ct = (np.maximum(arrival[:, None], avail[None, :])
                  + exec_t.T[kind] / eff[None, :]).astype(np.float32)
            ct[:, ~alive] = np.inf
            ct[scheduled, :] = np.inf
            flat = int(np.argmin(ct))
            ti, a = flat // n, flat % n
            ok = not scheduled[ti]
            task_i = jax.tree_util.tree_map(lambda x, ti=ti: x[ti], wtasks)
            state, rec = step(spec, state, task_i,
                              jnp.int32(a), valid=jnp.bool_(ok))
            scheduled[ti] = True
            ref_actions.append(int(rec.action))
            ref_valid.append(bool(rec.valid))
    np.testing.assert_array_equal(np.asarray(recs.action), ref_actions)
    np.testing.assert_array_equal(np.asarray(recs.valid, bool), ref_valid)
    # decisions are the bit-exact contract; the per-commit-jitted state
    # accumulators may differ from the fused scan's by an ulp
    for x, y in zip(jax.tree_util.tree_leaves(final),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("policy", ["eft", "flexai"])
def test_pipeline_two_stage_parity_under_trace(policy):
    plat, spec, ta, trace = _setup()
    plan = build_stage_plan(plat, 2)
    params = init_qnet(jax.random.PRNGKey(4), stage_state_dim(plat.n),
                       plat.n)
    fused = make_pipeline_schedule_fn(spec, plan, policy=policy)
    ref = make_pipeline_reference_fn(spec, plan, policy=policy)
    f1, ring1, r1 = fused(params, ta, health=trace)
    f2, ring2, r2 = ref(params, ta, health=trace)
    _assert_tree_equal(r1, r2)
    np.testing.assert_array_equal(np.asarray(ring1), np.asarray(ring2))
    _assert_tree_equal(f1, f2)


# ---------------------------------------------------------------------------
# rerouting: no valid placement ever lands on a dead core
# ---------------------------------------------------------------------------

def _dead_core_trace(t, n, core=0):
    return build_health_trace(t, n, [FaultEvent(0, core, 0.0)])


def test_engines_avoid_dead_core():
    plat, spec, ta, _ = _setup()
    t = ta.arrival.shape[0]
    trace = _dead_core_trace(t, plat.n, core=1)
    for engine in (worst_scan, ata_scan, minmin_scan):
        final, recs = jax.jit(functools.partial(
            engine, health=trace))(spec, ta)
        acts = np.asarray(recs.action)[np.asarray(recs.valid, bool)]
        assert (acts != 1).all(), engine
    params = init_qnet(jax.random.PRNGKey(2), 3 + 5 * plat.n, plat.n)
    _, recs = make_schedule_fn(spec)(params, ta, health=trace)
    acts = np.asarray(recs.action)[np.asarray(recs.valid, bool)]
    assert (acts != 1).all()


def test_degradation_trainer_masks_greedy_arm():
    """eps=0 training under a dead-core trace: every (greedy) action must
    avoid the dead core, and the trainer still learns (runs updates)."""
    plat = _platform()
    spec = spec_from_platform(plat)
    ta = tasks_to_arrays(_queue(13))
    t = ta.arrival.shape[0]
    trace = _dead_core_trace(t, plat.n, core=2)
    cfg = FlexAIConfig(seed=0, eps_start=0.0, eps_end=0.0)
    ts = train_init(jax.random.PRNGKey(0), 3 + 5 * plat.n, plat.n,
                    cfg.replay_capacity)
    fn = make_train_fn(spec, cfg)
    ts2, plat_f, recs, losses, upd = fn(ts, ta, health=trace)
    acts = np.asarray(recs.action)[np.asarray(recs.valid, bool)]
    assert (acts != 2).all()
    assert np.asarray(upd).any()


# ---------------------------------------------------------------------------
# parallel tempering vs Kirkpatrick chains (window-level, fixed seeds)
# ---------------------------------------------------------------------------

def test_parallel_tempering_window_quality():
    """At an equal iteration budget the tempered chains' best window
    fitness should track the Kirkpatrick chains' (deterministic at fixed
    seeds; mean over seeds within a small slack — exchange moves buy
    mixing, not a guaranteed per-seed win)."""
    plat = _platform()
    spec = spec_from_platform(plat)
    ta = tasks_to_arrays(_queue(17))
    wtasks = jax.tree_util.tree_map(lambda a: a[:30], ta)
    state = state_from_platform(plat)
    plain = SAConfig(iters=60, chains=8)
    temper = SAConfig(iters=60, chains=8, tempering=True, exchange_every=5)
    fits = {"plain": [], "pt": []}
    for seed in range(4):
        key = jax.random.PRNGKey(seed)
        for label, cfg in (("plain", plain), ("pt", temper)):
            best = _sa_window(spec, cfg, state, wtasks, key)
            fits[label].append(float(window_fitness(
                spec, state, wtasks, best)))
    # determinism: same seed, same config -> same assignment
    again = _sa_window(spec, temper, state, wtasks, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(
        np.asarray(again),
        np.asarray(_sa_window(spec, temper, state, wtasks,
                              jax.random.PRNGKey(0))))
    mean_plain = np.mean(fits["plain"])
    mean_pt = np.mean(fits["pt"])
    assert mean_pt >= mean_plain - 0.05 * abs(mean_plain), fits
