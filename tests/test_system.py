"""End-to-end behaviour of the paper's system: environment -> task queues ->
HMAI -> schedulers (FlexAI vs baselines), plus the headline orderings the
paper reports (§8)."""
import numpy as np
import pytest

from repro.core.criteria import camera_safety_time
from repro.core.environment import (Area, CAMERA_GROUPS, DrivingEnvironment,
                                    EnvironmentParams, Scenario, camera_hz)
from repro.core.hmai import (ACCELERATOR_SPECS, HMAI_CONFIG, HMAIPlatform,
                             HOMOGENEOUS_CONFIGS, T4_SPEC)
from repro.core.flexai import FlexAIConfig
from repro.core.schedulers import get_scheduler
from repro.core.tasks import TaskKind

RS = 0.05  # rate/capacity subsampling (same load ratio as full deployment)


def _queue(seed, km=0.15):
    return DrivingEnvironment(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed)).build_task_queue()


def _platform():
    return HMAIPlatform(capacity_scale=RS)


def test_camera_rates_reproduce_table5():
    """Urban aggregate FPS requirements (Table 5)."""
    def total(scenario, tra=False):
        tot = 0.0
        for g in CAMERA_GROUPS:
            if tra and g.name == "RC" and scenario != Scenario.RE:
                continue
            tot += g.count * camera_hz(Area.UB, scenario, g.name)
        return tot
    assert total(Scenario.GS) == pytest.approx(870)
    assert total(Scenario.GS, tra=True) == pytest.approx(840)
    assert total(Scenario.TL) == pytest.approx(950)
    assert total(Scenario.TL, tra=True) == pytest.approx(920)
    assert total(Scenario.RE) == pytest.approx(740)
    assert total(Scenario.RE, tra=True) == pytest.approx(740)


def test_camera_count_is_30():
    assert sum(g.count for g in CAMERA_GROUPS) == 30


def test_highway_never_reverses():
    env = DrivingEnvironment(EnvironmentParams(area=Area.HW, route_km=0.5,
                                               rate_scale=0.01, seed=3))
    assert all(seg.scenario != Scenario.RE for seg in env.segments)


def test_safety_time_ordering():
    """Faster areas -> tighter budgets; forward cameras see farther."""
    fc_ub = camera_safety_time("FC", "UB", "GS")
    fc_hw = camera_safety_time("FC", "HW", "GS")
    rc_ub = camera_safety_time("RC", "UB", "GS")
    assert fc_hw < fc_ub          # Fig 7a: ST_250FC-HW < ST_250FC-UB
    assert rc_ub < fc_ub          # shorter range -> less budget
    assert fc_ub > 0


def test_queue_structure():
    q = _queue(0)
    assert len(q) > 100
    times = [t.arrival_time for t in q]
    assert times == sorted(times)
    kinds = {t.kind for t in q}
    assert kinds == {TaskKind.YOLO, TaskKind.SSD, TaskKind.GOTURN}
    # DET alternates YOLO/SSD per camera (§2.1)
    fc0 = [t.kind for t in q
           if t.camera_group == "FC" and t.camera_id == 0
           and t.kind != TaskKind.GOTURN]
    assert all(a != b for a, b in zip(fc0, fc0[1:]))


def test_hmai_heterogeneous_beats_worst_on_balance():
    q = _queue(1)
    p_good = _platform()
    get_scheduler("ata").schedule(p_good, q)
    p_bad = _platform()
    get_scheduler("worst").schedule(p_bad, q)
    assert p_good.r_balance > p_bad.r_balance
    assert p_good.summary()["stm_rate"] > p_bad.summary()["stm_rate"]


def test_scheduler_registry_complete():
    for name in ("minmin", "ata", "ga", "sa", "worst", "random"):
        assert get_scheduler(name) is not None


def test_flexai_learns_and_beats_random():
    """Short-budget training still beats the random scheduler on STM+wait.

    Trains on the device-resident scan engine: fused episodes are ~30x
    cheaper than the per-task Python loop, so the budget stretches to 12
    episodes — enough that a fixed seed lands comfortably above the random
    baseline (0.87-0.96 across seeds vs random ~0.78) instead of flaking
    at 6 loop episodes.
    """
    from repro.core.flexai import ScanFlexAI
    queues = [_queue(s, km=0.08) for s in range(2)]
    trainer = ScanFlexAI(_platform(), FlexAIConfig(
        lr=3e-4, min_replay=128, update_every=2, eps_decay_steps=8000,
        seed=0))
    trainer.train(queues, episodes=12)
    test_q = _queue(9, km=0.08)
    flex = trainer.schedule(test_q)
    p2 = _platform()
    rand = get_scheduler("random").schedule(p2, test_q)
    assert flex["stm_rate"] >= rand["stm_rate"] - 0.05
    assert flex["schedule_time_per_task_s"] < 0.01  # predictive: O(1)/task


def test_accelerator_specs_match_table8():
    assert ACCELERATOR_SPECS["SconvOD"].fps["yolo"] == pytest.approx(170.37)
    assert ACCELERATOR_SPECS["SconvIC"].fps["ssd"] == pytest.approx(82.94)
    assert ACCELERATOR_SPECS["MconvMC"].fps["goturn"] == pytest.approx(500.54)
    assert dict(HMAI_CONFIG) == {"SconvOD": 4, "SconvIC": 4, "MconvMC": 3}
    # §8.2 power calibration: HMAI ~= 2x Tesla T4
    hmai_power = sum(ACCELERATOR_SPECS[n].power_w * c for n, c in HMAI_CONFIG)
    assert hmai_power == pytest.approx(2 * T4_SPEC.power_w, rel=0.05)


def test_homogeneous_configs_match_paper():
    assert dict(HOMOGENEOUS_CONFIGS["homo-SconvOD"]) == {"SconvOD": 13}
    assert dict(HOMOGENEOUS_CONFIGS["homo-MconvMC"]) == {"MconvMC": 12}
