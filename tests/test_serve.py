"""Serving engine: wave batching, greedy determinism, sampling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.serve.engine import Request, ServeEngine, sample_token
from repro.sharding import unbox

KEY = jax.random.PRNGKey(5)

CFG = ModelConfig(name="serve-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  attention_impl="naive", dtype="float32")


def _engine(slots=2, max_seq=32):
    api = model_api(CFG)
    params = unbox(api.init(KEY))
    return ServeEngine(api, params, slots=slots, max_seq=max_seq)


def test_wave_serving_completes():
    eng = _engine()
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=np.array([1 + uid, 2, 3], np.int32),
                           max_new_tokens=4))
    eng.run_until_done()
    assert len(eng.finished) == 5
    assert all(len(r.generated) == 4 for r in eng.finished)


def test_greedy_decode_deterministic():
    eng1 = _engine()
    eng2 = _engine()
    for eng in (eng1, eng2):
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=6))
        eng.run_until_done()
    assert eng1.finished[0].generated == eng2.finished[0].generated


def test_sample_token_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, 4.0]])
    t = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert int(t[0]) == 1
    for seed in range(10):
        t = sample_token(logits, jax.random.PRNGKey(seed), temperature=1.0,
                         top_k=2)
        assert int(t[0]) in (1, 3)
