"""Serving engine: wave batching, greedy determinism, sampling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.serve.engine import (Request, ServeEngine, make_serve_step,
                                sample_token)
from repro.sharding import unbox

KEY = jax.random.PRNGKey(5)

CFG = ModelConfig(name="serve-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  attention_impl="naive", dtype="float32")


def _engine(slots=2, max_seq=32):
    api = model_api(CFG)
    params = unbox(api.init(KEY))
    return ServeEngine(api, params, slots=slots, max_seq=max_seq)


def test_wave_serving_completes():
    eng = _engine()
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=np.array([1 + uid, 2, 3], np.int32),
                           max_new_tokens=4))
    eng.run_until_done()
    assert len(eng.finished) == 5
    assert all(len(r.generated) == 4 for r in eng.finished)


def test_short_request_not_starved_by_long():
    """Length-aware packing: a short request queued behind a long one is
    grouped with its length peers instead of padding into the long wave's
    lockstep decode; admission stays FIFO within a bucket and the oldest
    request is always admitted (no starvation)."""
    eng = _engine(slots=2)
    long_a = Request(uid=0, prompt=np.arange(1, 13, dtype=np.int32),
                     max_new_tokens=12)
    short_b = Request(uid=1, prompt=np.array([1, 2], np.int32),
                      max_new_tokens=2)
    short_c = Request(uid=2, prompt=np.array([3, 4], np.int32),
                      max_new_tokens=2)
    long_d = Request(uid=3, prompt=np.arange(1, 13, dtype=np.int32),
                     max_new_tokens=12)
    for r in (long_a, short_b, short_c, long_d):
        eng.submit(r)
    eng.run_until_done()
    assert len(eng.finished) == 4
    assert all(len(r.generated) == r.max_new_tokens for r in eng.finished)
    # wave 1: the longs pack together (oldest request picks the bucket);
    # wave 2: the shorts share their own cheap wave
    assert eng.wave_log == [[0, 3], [1, 2]]


def test_fifo_within_bucket_and_oldest_first():
    """Uniform-length requests degrade to plain FIFO waves."""
    eng = _engine(slots=2)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=np.array([1 + uid, 2, 3], np.int32),
                           max_new_tokens=4))
    eng.run_until_done()
    assert eng.wave_log == [[0, 1], [2, 3], [4]]


def test_greedy_decode_deterministic():
    eng1 = _engine()
    eng2 = _engine()
    for eng in (eng1, eng2):
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=6))
        eng.run_until_done()
    assert eng1.finished[0].generated == eng2.finished[0].generated


def test_serve_step_sampled_path():
    """greedy=False must route through sample_token (the previously dead
    branch): temperature 0 reduces to the greedy argmax, temperature 1
    actually samples across seeds."""
    api = model_api(CFG)
    params = unbox(api.init(KEY))
    greedy_step = make_serve_step(api)
    argmax_step = make_serve_step(api, greedy=False, temperature=0.0)
    sampled_step = make_serve_step(api, greedy=False, temperature=1.0)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.int32(0)

    def cache():
        return unbox(api.init_cache(2, 8))

    n_greedy, logits, _ = greedy_step(params, cache(), tok, pos)
    assert n_greedy.shape == (2, 1)
    np.testing.assert_array_equal(
        np.asarray(n_greedy[:, 0]),
        np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)))
    n_zero, _, _ = argmax_step(params, cache(), tok, pos,
                               jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(n_zero), np.asarray(n_greedy))
    seen = {int(sampled_step(params, cache(), tok, pos,
                             jax.random.PRNGKey(s))[0][0, 0])
            for s in range(8)}
    assert len(seen) > 1


def test_sample_token_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, 4.0]])
    t = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert int(t[0]) == 1
    for seed in range(10):
        t = sample_token(logits, jax.random.PRNGKey(seed), temperature=1.0,
                         top_k=2)
        assert int(t[0]) in (1, 3)
