"""Serving engine: wave batching, greedy determinism, sampling, and the
deadline-aware (EDF + aging + shedding) admission mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.serve.engine import (Request, ServeEngine, make_serve_step,
                                sample_token)
from repro.sharding import unbox

KEY = jax.random.PRNGKey(5)

CFG = ModelConfig(name="serve-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  attention_impl="naive", dtype="float32")


def _engine(slots=2, max_seq=32):
    api = model_api(CFG)
    params = unbox(api.init(KEY))
    return ServeEngine(api, params, slots=slots, max_seq=max_seq)


def test_wave_serving_completes():
    eng = _engine()
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=np.array([1 + uid, 2, 3], np.int32),
                           max_new_tokens=4))
    eng.run_until_done()
    assert len(eng.finished) == 5
    assert all(len(r.generated) == 4 for r in eng.finished)


def test_short_request_not_starved_by_long():
    """Length-aware packing: a short request queued behind a long one is
    grouped with its length peers instead of padding into the long wave's
    lockstep decode; admission stays FIFO within a bucket and the oldest
    request is always admitted (no starvation)."""
    eng = _engine(slots=2)
    long_a = Request(uid=0, prompt=np.arange(1, 13, dtype=np.int32),
                     max_new_tokens=12)
    short_b = Request(uid=1, prompt=np.array([1, 2], np.int32),
                      max_new_tokens=2)
    short_c = Request(uid=2, prompt=np.array([3, 4], np.int32),
                      max_new_tokens=2)
    long_d = Request(uid=3, prompt=np.arange(1, 13, dtype=np.int32),
                     max_new_tokens=12)
    for r in (long_a, short_b, short_c, long_d):
        eng.submit(r)
    eng.run_until_done()
    assert len(eng.finished) == 4
    assert all(len(r.generated) == r.max_new_tokens for r in eng.finished)
    # wave 1: the longs pack together (oldest request picks the bucket);
    # wave 2: the shorts share their own cheap wave
    assert eng.wave_log == [[0, 3], [1, 2]]


def test_fifo_within_bucket_and_oldest_first():
    """Uniform-length requests degrade to plain FIFO waves."""
    eng = _engine(slots=2)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=np.array([1 + uid, 2, 3], np.int32),
                           max_new_tokens=4))
    eng.run_until_done()
    assert eng.wave_log == [[0, 1], [2, 3], [4]]


def test_edf_admission_reorders_by_deadline():
    """qos="edf": the tightest effective deadline picks the wave bucket,
    so a late-submitted tight pair overtakes an early loose long pair."""
    eng = _engine(slots=2)
    eng.qos = "edf"
    loose_a = Request(uid=0, prompt=np.arange(1, 13, dtype=np.int32),
                      max_new_tokens=12, deadline=1000.0)
    loose_b = Request(uid=1, prompt=np.arange(1, 13, dtype=np.int32),
                      max_new_tokens=12, deadline=900.0)
    tight_c = Request(uid=2, prompt=np.array([1, 2], np.int32),
                      max_new_tokens=2, deadline=50.0)
    tight_d = Request(uid=3, prompt=np.array([3, 4], np.int32),
                      max_new_tokens=2, deadline=40.0)
    for r in (loose_a, loose_b, tight_c, tight_d):
        eng.submit(r)
    eng.run_until_done()
    # tight bucket first, EDF order inside each bucket
    assert eng.wave_log == [[3, 2], [1, 0]]
    assert len(eng.finished) == 4
    assert all(r.slack is not None and r.slack >= 0 for r in eng.finished)


def test_edf_aging_credit_prevents_cross_bucket_starvation():
    """A long-bucket request facing an endless stream of tight newcomers
    must still be admitted once its aging credit outweighs the deadline
    gap (co-submitted peers age together; the credit is earned against
    requests that arrive later)."""
    eng = _engine(slots=1, max_seq=64)
    eng.qos = "edf"
    eng.aging_credit = 8.0
    eng.shed = False
    long_r = Request(uid=0, prompt=np.arange(1, 13, dtype=np.int32),
                     max_new_tokens=12, deadline=200.0)
    eng.submit(long_r)
    waves = 0
    uid = 1
    while not long_r.done and waves < 40:
        # keep one tight short request arriving per wave, always with a
        # nearer absolute deadline than the long request's
        eng.submit(Request(uid=uid, prompt=np.array([1, 2], np.int32),
                           max_new_tokens=2, deadline=eng.clock + 50.0))
        uid += 1
        eng._run_wave(eng._next_wave())
        waves += 1
    assert long_r.done, "long request starved despite aging credit"
    # bound: (deadline spread)/credit waves of aging + one wave of grace
    spread = 200.0 - 50.0
    assert long_r.waves_waited <= spread / 8.0 + 2


def test_edf_timeout_shed_to_dead_letter():
    """A request whose decode budget cannot fit before its deadline is
    shed at admission, not served late."""
    eng = _engine(slots=2)
    eng.qos = "edf"
    doomed = Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                     max_new_tokens=8, deadline=2.0)  # needs 8 steps
    fine = Request(uid=1, prompt=np.array([1, 2, 3], np.int32),
                   max_new_tokens=4, deadline=500.0)
    # exact fit: finish lands at clock + max_new (prefill+first token is
    # one tick) — must be served with zero slack, not shed
    exact = Request(uid=2, prompt=np.array([1, 2, 3], np.int32),
                    max_new_tokens=4, deadline=4.0)
    for r in (doomed, fine, exact):
        eng.submit(r)
    eng.run_until_done()
    assert [r.uid for r in eng.dead_letter] == [0]
    assert sorted(r.uid for r in eng.finished) == [1, 2]
    assert exact.slack == pytest.approx(0.0)
    stats = eng.qos_stats()
    assert stats["shed"] == 1
    assert stats["miss_rate"] == pytest.approx(1 / 3)


def test_mixed_prompt_pricing_uses_wave_padding_aware_cap():
    """Truncation-pricing regression: a short prompt co-batched into a
    long-prompt wave decodes in lockstep from the wave's padded position,
    so ``max_seq`` can never deliver its naive per-request budget
    (``max_seq - own_prompt``).  Pricing and timeout shedding must use
    the wave-padding-aware cap: the old formula stamped the short request
    a deadline bought with 14 tokens it could never consume, and shed it
    against that same phantom need."""
    from repro.core.tasks import token_deadline_budget
    eng = _engine(slots=2, max_seq=16)
    eng.qos = "edf"
    long_r = Request(uid=0, prompt=np.arange(1, 13, dtype=np.int32),
                     max_new_tokens=4, deadline=500.0)   # bucket 16
    short_r = Request(uid=1, prompt=np.array([1, 2], np.int32),
                      max_new_tokens=14)                 # bucket 16 too
    eng.submit(long_r)
    eng.submit(short_r)
    # bucket 16 fills max_seq: only the prefill token is guaranteed
    assert short_r.priced_tokens == 1
    assert short_r.deadline == pytest.approx(token_deadline_budget(2, 1))
    assert short_r.deadline < token_deadline_budget(2, 14)  # old pricing
    eng.run_until_done()
    # old shed test needed 14 ticks -> clock 0 + 14 > deadline 6: shed a
    # request the wave serves by tick 4 with slack to spare
    assert not eng.dead_letter
    assert sorted(r.uid for r in eng.finished) == [0, 1]
    for r in eng.finished:  # delivery never falls below the priced budget
        assert len(r.generated) >= min(r.priced_tokens, r.max_new_tokens)
    stats = eng.qos_stats()
    assert stats["short_changed"] == 0
    assert short_r.slack is not None and short_r.slack >= 0.0


def test_token_cap_tight_at_full_bucket():
    """The cap's floor is exact: a request whose bucket equals max_seq
    gets precisely its one guaranteed (prefill) token, and a half-bucket
    request keeps the remaining headroom."""
    eng = _engine(slots=1, max_seq=16)
    full = Request(uid=0, prompt=np.arange(1, 16, dtype=np.int32),
                   max_new_tokens=1)                     # bucket 16
    half = Request(uid=1, prompt=np.array([1, 2, 3], np.int32),
                   max_new_tokens=5)                     # bucket 8
    for r in (full, half):
        eng.submit(r)
    assert full.priced_tokens == 1
    assert half.priced_tokens == 5                       # cap 9 >= 5
    eng.run_until_done()
    assert len(full.generated) == 1
    assert len(half.generated) == 5
    assert eng.qos_stats()["short_changed"] == 0


def test_default_deadline_derived_from_token_budget():
    """submit() stamps a Table-5-style per-token budget when no explicit
    deadline is given (tasks.token_deadline_budget)."""
    from repro.core.tasks import token_deadline_budget
    eng = _engine()
    r = Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                max_new_tokens=5)
    eng.submit(r)
    assert r.deadline == pytest.approx(token_deadline_budget(3, 5))
    assert r.deadline > 1 + r.max_new_tokens  # feasible by construction


def test_fifo_mode_never_sheds_and_logs_no_deadline_pressure():
    """Default engine (qos="fifo") behaves exactly as before: no dead
    letters, finish ordering by bucket-FIFO."""
    eng = _engine(slots=2)
    tight = Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                    max_new_tokens=4, deadline=0.5)  # impossibly tight
    eng.submit(tight)
    eng.run_until_done()
    assert not eng.dead_letter
    assert len(eng.finished) == 1
    assert eng.qos_stats()["miss_rate"] == 1.0  # late, but served


def test_greedy_decode_deterministic():
    eng1 = _engine()
    eng2 = _engine()
    for eng in (eng1, eng2):
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=6))
        eng.run_until_done()
    assert eng1.finished[0].generated == eng2.finished[0].generated


def test_serve_step_sampled_path():
    """greedy=False must route through sample_token (the previously dead
    branch): temperature 0 reduces to the greedy argmax, temperature 1
    actually samples across seeds."""
    api = model_api(CFG)
    params = unbox(api.init(KEY))
    greedy_step = make_serve_step(api)
    argmax_step = make_serve_step(api, greedy=False, temperature=0.0)
    sampled_step = make_serve_step(api, greedy=False, temperature=1.0)
    tok = jnp.ones((2, 1), jnp.int32)
    pos = jnp.int32(0)

    def cache():
        return unbox(api.init_cache(2, 8))

    n_greedy, logits, _ = greedy_step(params, cache(), tok, pos)
    assert n_greedy.shape == (2, 1)
    np.testing.assert_array_equal(
        np.asarray(n_greedy[:, 0]),
        np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)))
    n_zero, _, _ = argmax_step(params, cache(), tok, pos,
                               jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(n_zero), np.asarray(n_greedy))
    seen = {int(sampled_step(params, cache(), tok, pos,
                             jax.random.PRNGKey(s))[0][0, 0])
            for s in range(8)}
    assert len(seen) > 1


def test_sample_token_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, 4.0]])
    t = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert int(t[0]) == 1
    for seed in range(10):
        t = sample_token(logits, jax.random.PRNGKey(seed), temperature=1.0,
                         top_k=2)
        assert int(t[0]) in (1, 3)
