"""Training loop, checkpoint/restore, fault tolerance, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.sharding import unbox
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batch_fn
from repro.train.fault_tolerance import (PreemptionGuard, StragglerDetector,
                                         HeartbeatRecord, elastic_restore,
                                         run_with_fault_tolerance)
from repro.train.loop import TrainHyper, init_train_state, make_train_step

KEY = jax.random.PRNGKey(11)

CFG = ModelConfig(name="train-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  attention_impl="naive")


def _setup(compression="none", micro=1):
    import dataclasses
    cfg = dataclasses.replace(CFG, use_grad_accum_microbatches=micro)
    api = model_api(cfg)
    hyper = TrainHyper(peak_lr=3e-3, warmup_steps=5, total_steps=200,
                       compression=compression)
    params = unbox(api.init(KEY))
    state = init_train_state(params, hyper)
    step = jax.jit(make_train_step(api, hyper))
    data = DataConfig(batch_size=4, seq_len=32, seed=1)
    return cfg, state, step, batch_fn(cfg, data)


def test_loss_decreases():
    cfg, state, step, bat = _setup()
    losses = []
    for i in range(40):
        state, m = step(state, bat(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[:5]


@pytest.mark.parametrize("compression", ["bf16", "int8_ef"])
def test_compressed_training_still_learns(compression):
    cfg, state, step, bat = _setup(compression=compression)
    losses = []
    for i in range(40):
        state, m = step(state, bat(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.4


def test_grad_accum_matches_full_batch():
    """2-microbatch grad accumulation == single-batch step (same batch)."""
    _, state1, step1, bat = _setup(micro=1)
    _, state2, step2, _ = _setup(micro=2)
    b = bat(0)
    s1, m1 = step1(state1, b)
    s2, m2 = step2(state2, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    # params should land close (not identical: loss normalization order)
    l1 = jax.tree_util.tree_leaves(s1.params)
    l2 = jax.tree_util.tree_leaves(s2.params)
    for a, b_ in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-2, atol=5e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg, state, step, bat = _setup()
    for i in range(3):
        state, _ = step(state, bat(i))
    path = ckpt.save_checkpoint(str(tmp_path), 3, state)
    assert ckpt.latest_checkpoint(str(tmp_path)) == path
    template = jax.tree_util.tree_map(np.zeros_like, jax.device_get(state))
    restored = ckpt.restore_checkpoint(path, template)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_equals_uninterrupted(tmp_path):
    """Crash at step 12, restore from ckpt, resume -> identical final loss."""
    cfg, state0, step, bat = _setup()

    # uninterrupted
    res_full = run_with_fault_tolerance(
        step, state0, bat, num_steps=20, ckpt_dir=str(tmp_path / "a"),
        ckpt_every=5)

    # interrupted at 12 (checkpoints at 5 and 10)
    _, state_b, step_b, _ = _setup()
    with pytest.raises(RuntimeError):
        run_with_fault_tolerance(
            step_b, state_b, bat, num_steps=20,
            ckpt_dir=str(tmp_path / "b"), ckpt_every=5, fail_at_step=12)
    template = jax.device_get(state_b)
    restored, start = elastic_restore(str(tmp_path / "b"), template)
    assert start == 10
    res_resumed = run_with_fault_tolerance(
        step_b, restored, bat, num_steps=20, ckpt_dir=str(tmp_path / "b"),
        ckpt_every=5, start_step=start)

    for a, b in zip(jax.tree_util.tree_leaves(res_full.final_state.params),
                    jax.tree_util.tree_leaves(res_resumed.final_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_preemption_guard_checkpoints(tmp_path):
    cfg, state, step, bat = _setup()
    guard = PreemptionGuard(install_handler=False)
    guard.preempted = True
    res = run_with_fault_tolerance(
        step, state, bat, num_steps=10, ckpt_dir=str(tmp_path),
        ckpt_every=100, guard=guard)
    assert res.interrupted and res.completed_steps == 0
    assert ckpt.latest_checkpoint(str(tmp_path)) is not None


def test_straggler_detection():
    det = StragglerDetector(n_hosts=4, threshold=1.5, window=8)
    import time
    now = time.time()
    for step in range(8):
        for h in range(4):
            dt = 1.0 if h != 2 else 2.5  # host 2 is slow
            det.record(HeartbeatRecord(h, step, dt, now))
    assert det.stragglers() == [2]
    assert det.dead_hosts(now=now + 120) == [0, 1, 2, 3]
    assert det.dead_hosts(now=now + 1) == []


def test_data_pipeline_determinism():
    cfg = CFG
    data = DataConfig(batch_size=4, seq_len=32, seed=3)
    b1 = batch_fn(cfg, data)(17)
    b2 = batch_fn(cfg, data)(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_fn(cfg, data)(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
