"""Training loop, checkpoint/restore, fault tolerance, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.sharding import unbox
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batch_fn
from repro.train.fault_tolerance import (PreemptionGuard, StragglerDetector,
                                         HeartbeatRecord, elastic_restore,
                                         run_with_fault_tolerance)
from repro.train.loop import TrainHyper, init_train_state, make_train_step

KEY = jax.random.PRNGKey(11)

CFG = ModelConfig(name="train-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  attention_impl="naive")


def _setup(compression="none", micro=1):
    import dataclasses
    cfg = dataclasses.replace(CFG, use_grad_accum_microbatches=micro)
    api = model_api(cfg)
    hyper = TrainHyper(peak_lr=3e-3, warmup_steps=5, total_steps=200,
                       compression=compression)
    params = unbox(api.init(KEY))
    state = init_train_state(params, hyper)
    step = jax.jit(make_train_step(api, hyper))
    data = DataConfig(batch_size=4, seq_len=32, seed=1)
    return cfg, state, step, batch_fn(cfg, data)


def test_loss_decreases():
    cfg, state, step, bat = _setup()
    losses = []
    for i in range(40):
        state, m = step(state, bat(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[:5]


@pytest.mark.parametrize("compression", ["bf16", "int8_ef"])
def test_compressed_training_still_learns(compression):
    cfg, state, step, bat = _setup(compression=compression)
    losses = []
    for i in range(40):
        state, m = step(state, bat(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.4


def test_grad_accum_matches_full_batch():
    """2-microbatch grad accumulation == single-batch step (same batch)."""
    _, state1, step1, bat = _setup(micro=1)
    _, state2, step2, _ = _setup(micro=2)
    b = bat(0)
    s1, m1 = step1(state1, b)
    s2, m2 = step2(state2, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    # params should land close (not identical: loss normalization order)
    l1 = jax.tree_util.tree_leaves(s1.params)
    l2 = jax.tree_util.tree_leaves(s2.params)
    for a, b_ in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-2, atol=5e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg, state, step, bat = _setup()
    for i in range(3):
        state, _ = step(state, bat(i))
    path = ckpt.save_checkpoint(str(tmp_path), 3, state)
    assert ckpt.latest_checkpoint(str(tmp_path)) == path
    template = jax.tree_util.tree_map(np.zeros_like, jax.device_get(state))
    restored = ckpt.restore_checkpoint(path, template)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_equals_uninterrupted(tmp_path):
    """Crash at step 12, restore from ckpt, resume -> identical final loss."""
    cfg, state0, step, bat = _setup()

    # uninterrupted
    res_full = run_with_fault_tolerance(
        step, state0, bat, num_steps=20, ckpt_dir=str(tmp_path / "a"),
        ckpt_every=5)

    # interrupted at 12 (checkpoints at 5 and 10)
    _, state_b, step_b, _ = _setup()
    with pytest.raises(RuntimeError):
        run_with_fault_tolerance(
            step_b, state_b, bat, num_steps=20,
            ckpt_dir=str(tmp_path / "b"), ckpt_every=5, fail_at_step=12)
    template = jax.device_get(state_b)
    restored, start = elastic_restore(str(tmp_path / "b"), template)
    assert start == 10
    res_resumed = run_with_fault_tolerance(
        step_b, restored, bat, num_steps=20, ckpt_dir=str(tmp_path / "b"),
        ckpt_every=5, start_step=start)

    for a, b in zip(jax.tree_util.tree_leaves(res_full.final_state.params),
                    jax.tree_util.tree_leaves(res_resumed.final_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_preemption_guard_checkpoints(tmp_path):
    cfg, state, step, bat = _setup()
    guard = PreemptionGuard(install_handler=False)
    guard.preempted = True
    res = run_with_fault_tolerance(
        step, state, bat, num_steps=10, ckpt_dir=str(tmp_path),
        ckpt_every=100, guard=guard)
    assert res.interrupted and res.completed_steps == 0
    assert ckpt.latest_checkpoint(str(tmp_path)) is not None


def test_straggler_detection():
    det = StragglerDetector(n_hosts=4, threshold=1.5, window=8)
    import time
    now = time.time()
    for step in range(8):
        for h in range(4):
            dt = 1.0 if h != 2 else 2.5  # host 2 is slow
            det.record(HeartbeatRecord(h, step, dt, now))
    assert det.stragglers() == [2]
    assert det.dead_hosts(now=now + 120) == [0, 1, 2, 3]
    assert det.dead_hosts(now=now + 1) == []


def test_data_pipeline_determinism():
    cfg = CFG
    data = DataConfig(batch_size=4, seq_len=32, seed=3)
    b1 = batch_fn(cfg, data)(17)
    b2 = batch_fn(cfg, data)(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_fn(cfg, data)(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


# ---------------------------------------------------------------------------
# durability satellites: checkpointer ordering, dtype manifest, clocks
# ---------------------------------------------------------------------------

def test_async_checkpointer_overlapping_saves_keep_order(tmp_path,
                                                         monkeypatch):
    """Overlapping saves must land in submission order and a stale step
    resubmitted while a newer one is in flight must lose — the on-disk
    ``latest_checkpoint`` can never go backwards."""
    import time as _time
    real_write = ckpt._write

    def slow_write(directory, step, names, host):
        _time.sleep(0.05)
        return real_write(directory, step, names, host)

    monkeypatch.setattr(ckpt, "_write", slow_write)
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(1, {"x": np.full(4, 1.0)})
    saver.save(2, {"x": np.full(4, 2.0)})  # overlaps save 1
    saver.save(1, {"x": np.full(4, 9.0)})  # stale resubmit: dropped
    saver.wait()
    path = ckpt.latest_checkpoint(str(tmp_path))
    assert ckpt.checkpoint_step(path) == 2
    _, arrays, _ = ckpt.load_checkpoint_arrays(path)
    np.testing.assert_array_equal(arrays[0], np.full(4, 2.0))
    # both steps were written, in order (step 1 not clobbered by the
    # stale resubmit, step 2 newest)
    assert ckpt.checkpoint_step(os.path.join(
        str(tmp_path), "step_00000001")) == 1


def test_async_checkpointer_callable_state(tmp_path):
    """A zero-arg callable defers even the host copy to the writer
    thread (the serving snapshot path for immutable device leaves)."""
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    payload = {"a": jnp.arange(6, dtype=jnp.float32), "b": np.arange(3)}
    saver.save(1, lambda: payload)
    saver.wait()
    restored = ckpt.restore_checkpoint(
        ckpt.latest_checkpoint(str(tmp_path)),
        {"a": np.zeros(6, np.float32), "b": np.zeros(3, np.int64)})
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(6, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(restored["b"]), np.arange(3))


@pytest.mark.parametrize("dtype,values", [
    ("bfloat16", [1.5, -2.0, 0.0, 3.25]),
    ("float16", [1.5, -2.0, 0.0, 3.25]),
    ("bool", [True, False, True, True]),
    ("int32", [1, -7, 0, 2**31 - 1]),
    ("float64", [1.0 / 3.0, -1e300, 0.0, 2.5]),
])
def test_checkpoint_dtype_roundtrip(tmp_path, dtype, values):
    """Non-float64 leaves must survive the manifest dtype path — bf16 in
    particular comes back from ``np.load`` as raw void bytes and is only
    recovered through the manifest's dtype record."""
    if dtype == "bfloat16":
        import ml_dtypes
        arr = np.asarray(values, ml_dtypes.bfloat16)
    else:
        arr = np.asarray(values, np.dtype(dtype))
    path = ckpt.save_checkpoint(str(tmp_path), 1, {"leaf": arr})
    _, arrays, names = ckpt.load_checkpoint_arrays(path)
    assert names == ["['leaf']"]
    assert arrays[0].dtype == arr.dtype
    np.testing.assert_array_equal(arrays[0], arr)
    restored = ckpt.restore_checkpoint(path, {"leaf": np.zeros_like(arr)})
    if dtype == "float64" and not jax.config.jax_enable_x64:
        # the template path goes through device_put, which truncates
        # float64 to float32 with x64 disabled — exact f64 scalars must
        # come from load_checkpoint_arrays (what launch.train does for
        # the model-selection best); pin the behavior so a silent change
        # doesn't invalidate that workaround
        np.testing.assert_array_equal(np.asarray(restored["leaf"]),
                                      arr.astype(np.float32))
    else:
        np.testing.assert_array_equal(np.asarray(restored["leaf"]), arr)


def test_straggler_detector_injected_clock():
    """With an injected clock the heartbeat timeout is fully
    deterministic — no ``time.time()`` in the loop (the serving layer
    injects its virtual clock this way)."""
    now = [0.0]
    det = StragglerDetector(n_hosts=2, dead_after_s=5.0,
                            clock=lambda: now[0])
    det.record(HeartbeatRecord(0, 0, 1.0, timestamp=0.0))
    det.record(HeartbeatRecord(1, 0, 1.0, timestamp=0.0))
    assert det.dead_hosts() == []
    now[0] = 4.0
    assert det.dead_hosts() == []
    now[0] = 6.0  # both silent past the deadline on the virtual clock
    assert det.dead_hosts() == [0, 1]
    det.record(HeartbeatRecord(1, 1, 1.0, timestamp=6.0))
    assert det.dead_hosts() == [0]


@pytest.mark.slow
def test_flexai_trainer_snapshot_resume_bit_exact(tmp_path):
    """Kill the FlexAI training run after 2 of 4 episodes and resume from
    the full-state snapshot: env steps, model-selection best and final
    weights must all match the uninterrupted 4-episode run bit-exactly
    (replay ring, PRNG key and counters ride in the snapshot)."""
    import re
    import subprocess
    import sys

    base = [sys.executable, "-m", "repro.launch.train", "--flexai",
            "--routes", "2", "--rate-scale", "0.005", "--eval-every", "2",
            "--seed", "0"]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")

    def run(args):
        r = subprocess.run(base + args, env=env, capture_output=True,
                           text=True, timeout=420)
        assert r.returncode == 0, f"train failed:\n{r.stdout}\n{r.stderr}"
        m = re.search(r"trained (\d+) env steps .* best_eval_stm=(\S+)",
                      r.stdout)
        assert m, r.stdout
        return int(m.group(1)), m.group(2)

    w_full = str(tmp_path / "full.npz")
    steps_full, best_full = run(["--episodes", "4", "--weights", w_full])

    snap = str(tmp_path / "snaps")
    run(["--episodes", "2", "--snapshot-dir", snap])
    w_res = str(tmp_path / "resumed.npz")
    steps_res, best_res = run(["--episodes", "2", "--snapshot-dir", snap,
                               "--resume", "--weights", w_res])

    assert best_res == best_full
    with np.load(w_full) as a, np.load(w_res) as b:
        assert sorted(a.files) == sorted(b.files)
        for k in a.files:
            np.testing.assert_array_equal(a[k], b[k])
